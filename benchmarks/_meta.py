"""Shared benchmark-record metadata.

Every bench JSON under ``results/`` carries the same ``meta`` block so
trajectories stay comparable across machines and device topologies — a
`bench_event_kernel.json` produced on one CPU device is a different
experiment from one produced on a TPU or under
``--xla_force_host_platform_device_count=8``, and the record must say so.
"""

from __future__ import annotations


def bench_metadata() -> dict:
    """Platform + device-count stamp for a bench JSON record."""
    import jax

    from repro.sim.backends.jax_batched import (resolve_async_dispatch,
                                                resolve_data_parallel,
                                                resolve_event_core)

    return {
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "data_parallel": resolve_data_parallel(),
        "event_core": resolve_event_core(),
        "async_dispatch": resolve_async_dispatch(),
        "jax_version": jax.__version__,
    }


def stamp(record: dict) -> dict:
    """Return a shallow copy of a bench record with the metadata block
    attached, so writers can ``json.dump(stamp(res), f)`` without the
    ``meta`` key leaking into dicts the caller still iterates."""
    return {**record, "meta": bench_metadata()}
