"""Factor analysis over the Fig. 5 campaign (the paper's §7 future-work
item: 'conducting an ANOVA analysis on the collected data to identify the
most relevant and influential factors').

Reads results/fig5_degradation.csv and reports main effects (mean
degradation per factor level) plus the selector x chunk interaction — a
fixed-effects decomposition appropriate for the factorial design."""

from __future__ import annotations

import csv
import os
from collections import defaultdict

RES = os.path.join(os.path.dirname(__file__), "..", "results")


def run():
    path = os.path.join(RES, "fig5_degradation.csv")
    if not os.path.exists(path):
        raise FileNotFoundError("run the degradation bench first")
    rows = list(csv.DictReader(open(path)))
    deg = [float(r["degradation_pct"]) for r in rows]
    grand = sum(deg) / len(deg)

    def effect(key_fn):
        groups = defaultdict(list)
        for r in rows:
            groups[key_fn(r)].append(float(r["degradation_pct"]))
        return {k: sum(v) / len(v) - grand for k, v in groups.items()}

    out = {
        "grand_mean": grand,
        "selector": effect(lambda r: r["selector"]),
        "chunk": effect(lambda r: r["chunk"]),
        "reward": effect(lambda r: r["reward"] or "expert"),
        "selector_x_chunk": effect(lambda r: f"{r['selector']}|{r['chunk']}"),
    }
    # variance explained (between-group share per factor)
    ss_tot = sum((d - grand) ** 2 for d in deg)
    shares = {}
    for factor in ("selector", "chunk", "reward"):
        groups = defaultdict(list)
        for r in rows:
            key = r[factor] if factor != "reward" else (r["reward"] or "expert")
            groups[key].append(float(r["degradation_pct"]))
        ss_f = sum(len(v) * (sum(v) / len(v) - grand) ** 2
                   for v in groups.values())
        shares[factor] = ss_f / max(ss_tot, 1e-12)
    out["variance_share"] = shares
    return out


def main() -> list:
    r = run()
    lines = [("anova_grand_mean_deg", r["grand_mean"], "pct")]
    for factor in ("selector", "chunk", "reward"):
        for level, eff in sorted(r[factor].items(), key=lambda kv: kv[1]):
            lines.append((f"anova_{factor}_{level}", eff,
                          f"main effect (pct vs grand mean)"))
        lines.append((f"anova_{factor}_variance_share",
                      r["variance_share"][factor] * 100, "% of SS_total"))
    return lines
