"""L2 transfer benchmark — step-plan autotuning on a real (reduced) model:
per-plan fixed baselines vs online selection, wall-clock per step."""

from __future__ import annotations

import csv
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_reduce
from repro.data import DataConfig, TokenPipeline
from repro.distributed import ExecutionPlan, StepAutoTuner, make_plan_builder
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, adamw_init

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

PLANS = [ExecutionPlan("mb1_remat", 1, True),
         ExecutionPlan("mb2_remat", 2, True),
         ExecutionPlan("mb4_remat", 4, True),
         ExecutionPlan("mb1_noremat", 1, False)]


def run(steps: int = 24, method: str = "ExhaustiveSel"):
    cfg = dataclasses.replace(smoke_reduce(get_config("llama3.2-3b")),
                              d_model=256, d_ff=512, n_layers=4,
                              vocab_size=1024)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=steps)
    data = DataConfig(vocab_size=1024, seq_len=128, global_batch=8, seed=0)
    pipe = TokenPipeline(data)
    build = make_plan_builder(cfg, opt_cfg)
    rows = []

    def fresh_state():
        p = init_params(cfg, jax.random.PRNGKey(0))
        return p, adamw_init(p, opt_cfg)

    # fixed plans
    for plan in PLANS:
        fn = build(plan)
        params, opt = fresh_state()
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        fn(params, opt, batch)  # warmup/compile
        t0 = time.perf_counter()
        for s in range(steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, opt, m = fn(params, opt, batch)
        jax.block_until_ready(m["loss"])
        rows.append((f"fixed_{plan.name}",
                     (time.perf_counter() - t0) / steps))

    # autotuned
    tuner = StepAutoTuner(PLANS, build, method=method)
    params, opt = fresh_state()
    times = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        (params, opt, m), plan, dt = tuner.step(params, opt, batch)
        times.append(dt)
    rows.append((f"autotune_{method}", sum(times) / steps))
    rows.append((f"autotune_{method}_postexplore",
                 sum(times[len(PLANS):]) / max(1, len(times) - len(PLANS))))
    return rows, tuner


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    rows, tuner = run()
    with open(os.path.join(OUT, "autotune.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["method", "s_per_step"])
        w.writerows(rows)
    return [(name, t * 1e6, f"plan={tuner.selected_plan}")
            for name, t in rows]
