"""Backend shoot-out: full portfolio sweep, Python event loop vs batched
vmapped JAX engine, per app-system pair.

Measures wall-clock for ``sweep_portfolio`` (12 algorithms x 2 chunk modes
x reps x T time-steps), checks that both backends elect the same Oracle,
and records everything to ``results/bench_backends.json`` (the BENCH
record the acceptance gate reads: speedup >= 5x on at least one pair).

``--smoke`` is the CI drift gate: tiny T on both backends through
``bench_cov`` plus an Oracle-agreement assertion — fails fast when the
engines diverge.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

def _stamp(record: dict) -> dict:
    """Platform + device-count metadata (benchmarks/_meta.py) so bench
    trajectories stay comparable across machines and meshes."""
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


PAIRS = (("mandelbrot", "broadwell"), ("stream", "cascadelake"),
         ("sphynx", "epyc"), ("tc", "epyc"))


def run(T: int = 16, reps: int = 3, pairs=PAIRS) -> dict:
    from repro.sim import sweep_portfolio

    out = {}
    for app, sysname in pairs:
        t0 = time.perf_counter()
        sp = sweep_portfolio(app, sysname, T=T, reps=reps, backend="python")
        t_py = time.perf_counter() - t0

        # first JAX call pays jit compilation; steady-state is what a
        # campaign of many cells sees, so warm up then measure
        sweep_portfolio(app, sysname, T=T, reps=reps, backend="jax")
        t0 = time.perf_counter()
        sj = sweep_portfolio(app, sysname, T=T, reps=reps, backend="jax")
        t_jax = time.perf_counter() - t0

        agree = float((sp.oracle_argmin() == sj.oracle_argmin()).mean())
        oracle_rel = float(abs(sp.oracle_total() - sj.oracle_total())
                           / sp.oracle_total())
        out[f"{app}/{sysname}"] = {
            "T": T, "reps": reps,
            "python_s": round(t_py, 4),
            "jax_warm_s": round(t_jax, 4),
            "speedup": round(t_py / max(t_jax, 1e-9), 2),
            "oracle_argmin_agreement": agree,
            "oracle_total_rel_diff": oracle_rel,
        }
    return out


def smoke() -> None:
    """CI gate: tiny-T cov on both backends + Oracle agreement on the
    well-separated TC/EPYC cell (40 % winner margin).  Writes the smoke
    record to ``results/bench_backends.json`` so the tier-1 job has an
    artifact to upload even without the full shoot-out."""
    from benchmarks.bench_cov import run as cov_run
    from repro.sim import sweep_portfolio

    record = {"mode": "smoke", "cov": {}}
    rows_py = cov_run(T=2, reps=1, backend="python")
    rows_jax = cov_run(T=2, reps=1, backend="jax")
    drift = []
    for (a, s, cp), (_, _, cj) in zip(rows_py, rows_jax):
        record["cov"][f"{a}/{s}"] = {"python": round(cp, 5),
                                     "jax": round(cj, 5)}
        # c.o.v. spans orders of magnitude across cells; backends must
        # land in the same regime
        if not (np.isfinite(cp) and np.isfinite(cj)) or \
                abs(np.log10(max(cj, 1e-9) / max(cp, 1e-9))) >= 0.35:
            drift.append((a, s, cp, cj))
        print(f"smoke cov {a}/{s}: python={cp:.3f} jax={cj:.3f}")
    sp = sweep_portfolio("tc", "epyc", T=4, reps=1, backend="python")
    sj = sweep_portfolio("tc", "epyc", T=4, reps=1, backend="jax")
    agree = bool((sp.oracle_argmin() == sj.oracle_argmin()).all())
    record["tc_epyc_oracle_argmin_agree"] = agree
    record["cov_drift"] = [list(map(str, d)) for d in drift]
    # the record must exist even when a gate below fails: it is the
    # artifact CI uploads with if: always() for triage
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_backends.json"), "w") as f:
        json.dump(_stamp(record), f, indent=2)
    assert not drift, f"python/jax cov drift: {drift}"
    assert agree, "backends disagree on the TC/EPYC Oracle"
    print("smoke: backends agree on the TC/EPYC T=4 Oracle")


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    res = run()
    with open(os.path.join(OUT, "bench_backends.json"), "w") as f:
        json.dump(_stamp(res), f, indent=2)
    rows = []
    for pair, r in res.items():
        rows.append((f"backends_{pair.replace('/', '_')}",
                     r["jax_warm_s"] * 1e6,
                     f"speedup={r['speedup']}x,"
                     f"agree={r['oracle_argmin_agreement']:.2f}"))
    best = max(r["speedup"] for r in res.values())
    rows.append(("backends_best_speedup", 0.0, f"{best}x"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # allow `python benchmarks/bench_backends.py` from the repo root
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
