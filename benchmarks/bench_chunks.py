"""Fig. 1 & Fig. 2 — chunk-size progression per scheduling algorithm for the
SPHYNX gravity loop (N = 1e6) on a 20-thread Broadwell node with the paper's
two chunk parameters (781 = expChunk, 3125)."""

from __future__ import annotations

import csv
import os

import numpy as np

from repro.core import ALGORITHM_NAMES, exp_chunk
from repro.sim import get_application, get_system, run_instance

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

NON_ADAPTIVE = ["STATIC", "SS", "GSS", "AutoLLVM", "TSS", "mFAC2"]   # Fig. 1
ADAPTIVE = ["AWF_B", "AWF_C", "AWF_D", "AWF_E", "mAF"]               # Fig. 2


def run(chunk_params=(781, 3125)) -> dict:
    app = get_application("sphynx")
    system = get_system("broadwell")
    profile = app.loops(0)[0]
    assert exp_chunk(profile.N, system.P) == 781   # the paper's anchor
    rows = {}
    for cp in chunk_params:
        for name in NON_ADAPTIVE + ADAPTIVE:
            alg = ALGORITHM_NAMES.index(name)
            r = run_instance(profile, system, alg, cp,
                             np.random.default_rng(0), record_chunks=True)
            rows[(name, cp)] = r.chunk_sizes
    return rows


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    rows = run()
    path = os.path.join(OUT, "fig1_fig2_chunk_progression.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["algorithm", "chunk_param", "chunk_id", "chunk_size"])
        for (name, cp), sizes in rows.items():
            for i, c in enumerate(sizes):
                w.writerow([name, cp, i, c])
    out = []
    for (name, cp), sizes in rows.items():
        out.append((f"chunks_{name}_cp{cp}", len(sizes),
                    f"first={sizes[0]},last={sizes[-1]}"))
    return out
