"""Fig. 4 — coefficient of variation of loop execution times across the
whole portfolio (every algorithm x chunk parameter) per app-system pair."""

from __future__ import annotations

import csv
import os

from repro.sim import APPLICATIONS, SYSTEMS, sweep_portfolio

OUT = os.path.join(os.path.dirname(__file__), "..", "results")


def run(T: int = 24, reps: int = 2, backend=None):
    rows = []
    for app in APPLICATIONS:
        for system in SYSTEMS:
            sweep = sweep_portfolio(app, system, T=T, reps=reps,
                                    backend=backend)
            rows.append((app, system, sweep.cov()))
    return rows


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    rows = run()
    with open(os.path.join(OUT, "fig4_cov.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["application", "system", "cov"])
        w.writerows(rows)
    return [(f"cov_{a}_{s}", 0.0, f"{c:.3f}") for a, s, c in rows]
