"""Fig. 5 — performance degradation (%) vs Oracle for every selection method
x chunk parameter x RL reward, per app-system pair.

Full fidelity (T = 500, all 18 pairs, 5 reps) takes hours on one CPU core;
the default here is a representative subset at T = 300 — override with
``python -m benchmarks.run --full``."""

from __future__ import annotations

import csv
import os

from repro.sim import APPLICATIONS, SYSTEMS, run_campaign_cell

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

DEFAULT_PAIRS = [("sphynx", "cascadelake"), ("stream", "cascadelake"),
                 ("tc", "epyc"), ("mandelbrot", "broadwell")]


def run(pairs=None, T: int = 300, reps: int = 2):
    pairs = pairs or DEFAULT_PAIRS
    rows = []
    cells = {}
    for app, system in pairs:
        cell = run_campaign_cell(app, system, T=T, reps=reps)
        cells[(app, system)] = cell
        for (sel, mode, reward), deg in sorted(cell.degradation().items()):
            total = cell.selector_runs[(sel, mode, reward)].total
            rows.append((app, system, sel, mode, reward or "", deg, total,
                         cell.oracle_total))
    return rows, cells


def main(full: bool = False) -> list:
    os.makedirs(OUT, exist_ok=True)
    pairs = ([(a, s) for a in APPLICATIONS for s in SYSTEMS]
             if full else None)
    rows, _ = run(pairs=pairs, T=500 if full else 300,
                  reps=3 if full else 2)
    with open(os.path.join(OUT, "fig5_degradation.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["application", "system", "selector", "chunk", "reward",
                    "degradation_pct", "total_s", "oracle_s"])
        w.writerows(rows)
    return [(f"deg_{a}_{s}_{sel}_{mode}{('_' + r) if r else ''}", t * 1e6,
             f"{d:+.1f}%")
            for a, s, sel, mode, r, d, t, _o in rows]
