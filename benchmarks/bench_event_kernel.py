"""Event-core shoot-out: vmapped ``lax.while_loop`` vs the fused Pallas
event-loop kernel on the lanes the kernel targets — STREAM-scale
SS/StaticSteal schedules (K ~ 6e4 chunks per instance), the slowest lanes
of the batched engine.

Both cores consume the identical shared precompute (same fold seeds, same
noise realization), so besides wall-clock the bench asserts **bit-equality**
of every makespan/LIB — the accuracy contract of
``repro.kernels.event_loop``.  Results go to
``results/bench_event_kernel.json`` with the platform recorded: on CPU the
Pallas core runs in interpret mode (a correctness vehicle, not a speed
claim — the default core stays ``while_loop`` there); on TPU the same call
compiles via Mosaic and lifts the per-iteration dispatch XLA leaves on the
table.

``--smoke`` is the CI gate: a reduced-K lane through both cores, asserting
bit-equality of the batch results and the serving what-if path, and
recording a smoke-sized JSON so the artifact is always uploaded.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

#: (lane name, alg, chunk_param, N, P, B) — alg 1 = SS, 5 = StaticSteal
LANES = (
    ("ss_K65536", 1, 64, 4_194_304, 20, 8),
    # N sized so the steal replay (own chunks + steal slack) stays inside
    # the 65536 buffer bucket
    ("staticsteal_K62504", 5, 64, 4_000_000, 20, 4),
    ("gss_K256", 2, 0, 1_048_576, 20, 32),
)


def _lane(alg, cp, N, P, B):
    import dataclasses

    from repro.sim import LoopProfile, get_system
    from repro.sim.backends import InstanceSpec

    system = dataclasses.replace(get_system("cascadelake"), P=P)
    profile = LoopProfile(name="u", N=N, memory_bound=0.3,
                          locality_sens=0.2, c_loc=64, unit=1e-8)
    specs = [InstanceSpec(0, alg, cp, (alg, cp, i)) for i in range(B)]
    return profile, system, specs


def _write(res: dict) -> None:
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp

    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_event_kernel.json"), "w") as f:
        json.dump(stamp(res), f, indent=2)


def run(lanes=LANES, reps: int = 3) -> dict:
    import jax

    from repro.sim.backends.jax_batched import JaxBatchedBackend

    # explicit kernel= so a REPRO_EVENT_CORE override can never turn the
    # shoot-out into pallas-vs-pallas
    cores = {"while_loop": JaxBatchedBackend(kernel="while_loop"),
             "pallas": JaxBatchedBackend(kernel="pallas")}
    out = {"platform": jax.default_backend(),
           "interpret": jax.default_backend() != "tpu",
           "lanes": {}}
    for name, alg, cp, N, P, B in lanes:
        if out["lanes"]:
            _write(out)          # checkpoint the lanes finished so far
        profile, system, specs = _lane(alg, cp, N, P, B)
        rec = {"alg": alg, "chunk_param": cp, "N": N, "P": P, "B": B}
        results = {}
        for core, bk in cores.items():
            bk.run_batch([profile], system, specs)       # compile + caches
            best = float("inf")
            for _ in range(reps):                        # min of reps: the
                t0 = time.perf_counter()                 # least-disturbed run
                results[core] = bk.run_batch([profile], system, specs)
                best = min(best, time.perf_counter() - t0)
            rec[f"{core}_s"] = round(best, 4)
        rec["K"] = int(results["pallas"].n_chunks[0])
        rec["speedup"] = round(rec["while_loop_s"]
                               / max(rec["pallas_s"], 1e-9), 2)
        rec["bitexact"] = bool(
            (results["while_loop"].loop_time
             == results["pallas"].loop_time).all()
            and (results["while_loop"].lib == results["pallas"].lib).all())
        assert rec["bitexact"], f"cores diverged on lane {name}"
        out["lanes"][name] = rec
    return out


def smoke() -> None:
    """CI gate: reduced-K lanes through BOTH cores — bit-equality of batch
    results and the serving what-if path, and a smoke-sized artifact."""
    from repro.sim.backends.jax_batched import JaxBatchedBackend

    res = run(lanes=(("ss_K4096_smoke", 1, 64, 262_144, 8, 4),
                     ("staticsteal_K4096_smoke", 5, 64, 262_144, 8, 2)))
    for name, rec in res["lanes"].items():
        assert rec["bitexact"], name
        print(f"smoke {name}: K={rec['K']} while_loop={rec['while_loop_s']}s "
              f"pallas={rec['pallas_s']}s bitexact={rec['bitexact']}")
    rng = np.random.default_rng(0)
    prefix = np.concatenate([[0.0], np.cumsum(rng.random(256) * 1e-3)])
    avail = rng.random(8) * 1e-3
    ww = JaxBatchedBackend(kernel="while_loop").what_if_wave(
        prefix, 8, avail, 2e-4, 1e-3, list(range(12)))
    wp = JaxBatchedBackend(kernel="pallas").what_if_wave(
        prefix, 8, avail, 2e-4, 1e-3, list(range(12)))
    assert (ww == wp).all(), "what-if wave diverged across event cores"
    print("smoke: what-if wave bit-identical across event cores")
    res["mode"] = "smoke"
    _write(res)


def main() -> list:
    res = run()
    res["mode"] = "full"
    _write(res)
    rows = []
    for name, rec in res["lanes"].items():
        rows.append((f"event_kernel_{name}", rec["pallas_s"] * 1e6,
                     f"K={rec['K']},speedup={rec['speedup']}x,"
                     f"bitexact={rec['bitexact']}"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # allow `python benchmarks/bench_event_kernel.py` from the repo root
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
