"""Fault-tolerance benchmark: recovery value and crash-safe resume.

Two CI gates over the fleet fault model
(:mod:`repro.sim.perturb` + :mod:`repro.serving.fleet.recovery`):

**recovery** — replay the bursty headline trace through a fleet that loses
a whole replica group mid-run (``ReplicaFailure`` window covering ~30% of
the trace).  With ``recovery=None`` the fleet runs the blind baseline:
routers keep dispatching into the failed group, interrupted work replays
there when it rejoins.  With a ``RecoveryPolicy`` (failure-aware routing +
migration + capped-backoff retries) the same what-if-priced router routes
around the outage and re-places interrupted work.  The gate: recovery ON
must beat recovery OFF on BOTH total makespan and p95 latency, with zero
dead-lettered requests — and both runs must satisfy the accounting
invariant (completed + dead-lettered == admitted) by construction.

**kill-resume** — launch the same faulty run in a child process journaling
wave-granularity snapshots (``RunJournal``), SIGKILL it mid-run, resume
from the surviving journal in-process, and require the resumed
``FleetReport`` to be **bit-identical** (every summary field and every
latency sample) to an uninterrupted run.  On the ``slow`` tier this is the
issue-level >=1M-request crash-safety gate.

Everything is recorded to ``results/bench_faults.json``.
"""

from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import tempfile
import time

OUT = os.path.join(os.path.dirname(__file__), "..", "results")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _stamp(record: dict) -> dict:
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


# fleet shape and headline bursty regime are shared with bench_fleet — the
# fault gates measure recovery value in exactly the routing benchmark's
# regime, not a bespoke one
try:
    from .bench_fleet import BURSTY, N_GROUPS, REPLICAS, WAVE_QUOTA
except ImportError:              # run as a script, not as benchmarks.*
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from bench_fleet import BURSTY, N_GROUPS, REPLICAS, WAVE_QUOTA

#: smoke sizes: the slow tier carries the issue-level >=1M-request gates,
#: tier1 replays the same regime at drift-check scale
SMOKE_N = {"tier1": 60_000, "slow": 1_000_000}

#: the outage: one whole replica group down for ~30% of the trace, placed
#: late (fractions of the trace duration, resolved per n) so the blind
#: baseline's rejoin-and-replay burst lands past the last arrival — the
#: recovery win then shows up in total makespan as well as in p95 (an
#: early outage's backlog re-drains before the trace ends on both sides)
FAIL_GROUP = 1
FAIL_WINDOW = (0.65, 0.95)

#: journal cadence for the kill-resume gate
JOURNAL_EVERY = 10


def _perturb(duration: float):
    from repro.sim.perturb import FleetPerturb, ReplicaFailure

    t0, t1 = (duration * f for f in FAIL_WINDOW)
    return FleetPerturb(failures=(
        ReplicaFailure(group=FAIL_GROUP, t0=t0, t1=t1),))


def _recovery():
    from repro.serving import RecoveryPolicy

    return RecoveryPolicy(max_retries=6)


def _fleet(trace, recovery, router: str = "whatif"):
    from repro.serving import AdmissionControl, FleetSimulator

    return FleetSimulator(n_groups=N_GROUPS, replicas_per_group=REPLICAS,
                          router=router, selector="SimPolicy", backend="jax",
                          admission=AdmissionControl(wave_quota=WAVE_QUOTA),
                          perturb=_perturb(trace.duration),
                          recovery=recovery)


def _trace(n: int, seed: int = 0):
    from repro.serving import make_trace

    return make_trace("bursty", n, seed=seed, **BURSTY)


def _run(trace, recovery, router="whatif", journal=None, resume=False,
         keep_latencies=False) -> dict:
    fleet = _fleet(trace, recovery, router)
    t0 = time.perf_counter()
    rep = fleet.run(trace, keep_latencies=keep_latencies, journal=journal,
                    resume=resume)
    s = rep.summary()
    s["wall_s"] = round(time.perf_counter() - t0, 2)
    return (s, rep) if keep_latencies else s


def _config(n: int) -> dict:
    return {"n_groups": N_GROUPS, "replicas_per_group": REPLICAS,
            "wave_quota": WAVE_QUOTA, "selector": "SimPolicy",
            "backend": "jax", "fail_group": FAIL_GROUP,
            "fail_window": list(FAIL_WINDOW), "n": n}


def _write(results: dict) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_faults.json"), "w") as f:
        json.dump(_stamp(results), f, indent=2)


# ---------------------------------------------------------------------------
# gate 1: recovery ON beats recovery OFF under a mid-run group outage
# ---------------------------------------------------------------------------

def recovery_gate(n: int, seed: int = 0) -> dict:
    trace = _trace(n, seed)
    on = _run(trace, _recovery())
    off = _run(trace, None)
    rec = {"on": on, "off": off}
    print(f"faults recovery n={n}: makespan on={on['makespan']:.3f}s "
          f"off={off['makespan']:.3f}s | p95 on={on['p95'] * 1e3:.1f}ms "
          f"off={off['p95'] * 1e3:.1f}ms | dead on="
          f"{on['recovery']['dead_lettered']}", flush=True)
    assert on["makespan"] < off["makespan"], \
        (f"recovery-enabled makespan {on['makespan']:.4f}s did not beat "
         f"recovery-off {off['makespan']:.4f}s")
    assert on["p95"] < off["p95"], \
        (f"recovery-enabled p95 {on['p95'] * 1e3:.2f}ms did not beat "
         f"recovery-off {off['p95'] * 1e3:.2f}ms")
    assert on["recovery"]["dead_lettered"] == 0, \
        (f"recovery run dead-lettered {on['recovery']['dead_lettered']} "
         f"requests under a transient outage")
    for name, s in rec.items():
        got = s["recovery"]["completed"] + s["recovery"]["dead_lettered"]
        assert got == n, \
            f"{name}: accounting broke — {got} accounted of {n} admitted"
    return rec


# ---------------------------------------------------------------------------
# gate 2: SIGKILL mid-run, resume from the journal, bit-equal report
# ---------------------------------------------------------------------------

def _child_main(journal_dir: str, n: int, seed: int) -> None:
    """Child-process entry for the kill gate: run journaled until killed."""
    from repro.serving import RunJournal

    trace = _trace(n, seed)
    journal = RunJournal(journal_dir, every=JOURNAL_EVERY, keep=3)
    _run(trace, _recovery(), journal=journal)


def _kill_child_mid_run(journal_dir: str, n: int, seed: int,
                        min_waves: int = 2, timeout: float = 600.0) -> int:
    """Launch the journaled run in a subprocess and SIGKILL it once the
    journal holds ``min_waves`` snapshots (a genuinely torn run, not a
    cooperative shutdown).  Returns the number of surviving snapshots."""
    from repro.serving import RunJournal

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--_child", journal_dir,
         "--n", str(n), "--seed", str(seed)],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)
    journal = RunJournal(journal_dir, every=JOURNAL_EVERY, keep=3)
    deadline = time.time() + timeout
    try:
        while time.time() < deadline:
            if len(journal.waves()) >= min_waves:
                proc.send_signal(signal.SIGKILL)
                break
            if proc.poll() is not None:   # finished before we killed it —
                break                     # resume still must reproduce it
            time.sleep(0.02)
        else:
            raise RuntimeError(f"child produced < {min_waves} journal "
                               f"snapshots within {timeout}s")
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait()
    waves = journal.waves()
    if not waves:
        raise RuntimeError("no journal snapshot survived the kill")
    return len(waves)


def kill_resume_gate(n: int, seed: int = 0) -> dict:
    import numpy as np

    trace = _trace(n, seed)
    ref_s, ref = _run(trace, _recovery(), keep_latencies=True)
    with tempfile.TemporaryDirectory() as d:
        jdir = os.path.join(d, "journal")
        snapshots = _kill_child_mid_run(jdir, n, seed)
        from repro.serving import RunJournal
        journal = RunJournal(jdir, every=JOURNAL_EVERY, keep=3)
        resumed_wave = journal.waves()[-1]
        res_s, res = _run(trace, _recovery(), journal=journal, resume=True,
                          keep_latencies=True)
    drop = ("wall_s",)
    a = {k: v for k, v in ref_s.items() if k not in drop}
    b = {k: v for k, v in res_s.items() if k not in drop}
    lat_equal = bool(np.array_equal(ref.latencies, res.latencies))
    print(f"faults kill-resume n={n}: killed with {snapshots} snapshots, "
          f"resumed from wave {resumed_wave}/{ref_s['waves']}, "
          f"bit-equal={'yes' if a == b and lat_equal else 'NO'}", flush=True)
    assert a == b, \
        ("resumed report diverged from the uninterrupted run: "
         + json.dumps({k: [a[k], b[k]] for k in a if a.get(k) != b.get(k)},
                      default=str))
    assert lat_equal, \
        "resumed per-request latencies diverged from the uninterrupted run"
    return {"uninterrupted": ref_s, "resumed": res_s,
            "killed_at_snapshots": snapshots,
            "resumed_from_wave": resumed_wave, "bit_equal": True}


# ---------------------------------------------------------------------------
# harness entries
# ---------------------------------------------------------------------------

def smoke(tier: str = "tier1") -> None:
    """CI fault-tolerance gate: recovery beats the blind baseline on both
    makespan and p95 under a mid-run group outage, and a SIGKILLed
    journaled run resumes bit-identically (>=1M requests on the slow
    tier)."""
    n = SMOKE_N.get(tier, SMOKE_N["tier1"])
    results = {"config": _config(n), "tier": tier}
    results["recovery"] = recovery_gate(n)
    _write(results)
    results["kill_resume"] = kill_resume_gate(n)
    _write(results)


def main() -> list:
    """Harness entry: the recovery comparison at reduced scale (CSV rows);
    ``smoke`` carries the asserting gates."""
    n = 40_000
    trace = _trace(n)
    rows = []
    for label, recovery in (("recovery_on", _recovery()),
                            ("recovery_off", None)):
        s = _run(trace, recovery)
        r = s["recovery"]
        rows.append((f"faults_{label}", s["wall_s"] * 1e6,
                     f"mk={s['makespan']:.3f}s,p95={s['p95'] * 1e3:.1f}ms,"
                     f"retries={r['retries']},dead={r['dead_lettered']}"))
    return rows


if __name__ == "__main__":
    import argparse

    # allow `python benchmarks/bench_faults.py` from anywhere
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.abspath(SRC))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tier", default="tier1", choices=["tier1", "slow"])
    ap.add_argument("--_child", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--n", type=int, default=SMOKE_N["tier1"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args._child:
        _child_main(args._child, args.n, args.seed)
    elif args.smoke:
        smoke(args.tier)
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
