"""Fleet-scale serving benchmark: trace-driven routing over
continuous-batching replica groups.

Replays arrival traces (Poisson / bursty MMPP / diurnal) through a
``FleetSimulator`` — G replica groups of R replicas, each group running its
own SimPolicy-selected chunk-self-scheduled dispatch — and compares routing
policies: ``round_robin`` and ``least_outstanding`` baselines against the
what-if-priced ``WhatIfRouter`` (one batched JAX ``what_if_routes`` pricing
call per admission wave).

The headline regime is the bursty trace with *average* utilization below
fleet capacity but burst-phase rates well above it: routing quality then
decides how burst backlogs drain, which is exactly where busy-state-blind
striping loses tail latency.  (Under sustained overload every router is
backlog-bound and the comparison washes out.)

``smoke(tier)`` is the CI gate: WhatIfRouter must beat round-robin on BOTH
total makespan and p95 latency on the bursty trace — at >=1M simulated
requests on the ``slow`` tier, a reduced replica of the same regime on
``tier1``.  Everything is recorded to ``results/bench_fleet.json``.

``bench_faults`` reuses this benchmark's fleet shape and bursty regime
(``N_GROUPS``/``REPLICAS``/``WAVE_QUOTA``/``BURSTY``) for its fault
injection, recovery-value, and crash-safe kill-resume gates.
"""

from __future__ import annotations

import json
import os
import time

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

def _stamp(record: dict) -> dict:
    """Platform + device-count metadata (benchmarks/_meta.py) so bench
    trajectories stay comparable across machines and meshes."""
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


#: fleet shape: 4 groups x 8 replicas (capacity ~3.4k req/s under the
#: default ReplicaCostModel)
N_GROUPS = 4
REPLICAS = 8
WAVE_QUOTA = 1024

#: headline bursty regime: mean rate ~2.5k req/s (util ~0.73) with MMPP
#: burst phases at 12k req/s — bursts overrun capacity, the average does not
BURSTY = dict(base_rate=2000.0, burst_factor=6.0, p_enter=0.015, p_exit=0.05)
SIDE_TRACES = {
    "poisson": dict(rate=2400.0),
    "diurnal": dict(base_rate=2000.0, amplitude=0.8, period=120.0),
}

ROUTERS = ("round_robin", "least_outstanding", "whatif")

#: smoke sizes: the slow tier carries the issue-level >=1M-request gate,
#: tier1 replays the same regime at drift-check scale
SMOKE_N = {"tier1": 120_000, "slow": 1_000_000}


def _fleet(router: str):
    from repro.serving import AdmissionControl, FleetSimulator

    return FleetSimulator(n_groups=N_GROUPS, replicas_per_group=REPLICAS,
                          router=router, selector="SimPolicy",
                          backend="jax",
                          admission=AdmissionControl(wave_quota=WAVE_QUOTA))


def _replay(trace, routers=ROUTERS) -> dict:
    out = {}
    for router in routers:
        fleet = _fleet(router)
        t0 = time.perf_counter()
        rep = fleet.run(trace)
        s = rep.summary()
        s["wall_s"] = round(time.perf_counter() - t0, 2)
        out[router] = s
    return out


def _trace(kind: str, n: int, seed: int = 0, **params):
    from repro.serving import make_trace

    return make_trace(kind, n, seed=seed, **params)


def _write(results: dict) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_fleet.json"), "w") as f:
        json.dump(_stamp(results), f, indent=2)


def _config(n_headline: int) -> dict:
    return {"n_groups": N_GROUPS, "replicas_per_group": REPLICAS,
            "wave_quota": WAVE_QUOTA, "selector": "SimPolicy",
            "backend": "jax", "n_headline": n_headline}


def run(n_headline: int = 1_000_000, n_side: int = 150_000,
        seed: int = 0, routers=ROUTERS) -> dict:
    """Full campaign: the >=1M-request bursty headline plus Poisson and
    diurnal side traces, every router, written to results/bench_fleet.json."""
    results = {"config": _config(n_headline), "traces": {}}
    specs = [("bursty", n_headline, BURSTY)]
    specs += [(k, n_side, p) for k, p in SIDE_TRACES.items()]
    for kind, n, params in specs:
        trace = _trace(kind, n, seed=seed, **params)
        entry = {"n": n, "params": params,
                 "mean_rate": round(trace.mean_rate, 1),
                 "routers": _replay(trace, routers)}
        results["traces"][kind] = entry
        _write(results)  # checkpoint after every trace
    return results


def smoke(tier: str = "tier1") -> None:
    """CI routing gate on the bursty trace: WhatIfRouter must beat
    round-robin on BOTH total makespan and p95 latency (>=1M requests on
    the slow tier), and throughput must track the offered rate."""
    n = SMOKE_N.get(tier, SMOKE_N["tier1"])
    trace = _trace("bursty", n, seed=0, **BURSTY)
    routers = _replay(trace, routers=("round_robin", "whatif"))
    results = {"config": _config(n), "tier": tier,
               "traces": {"bursty": {"n": n, "params": BURSTY,
                                     "mean_rate": round(trace.mean_rate, 1),
                                     "routers": routers}}}
    _write(results)
    rr, wi = routers["round_robin"], routers["whatif"]
    print(f"smoke fleet bursty n={n}: makespan rr={rr['makespan']:.3f}s "
          f"wi={wi['makespan']:.3f}s | p95 rr={rr['p95'] * 1e3:.1f}ms "
          f"wi={wi['p95'] * 1e3:.1f}ms", flush=True)
    assert wi["makespan"] < rr["makespan"], \
        (f"WhatIfRouter makespan {wi['makespan']:.4f}s did not beat "
         f"round-robin {rr['makespan']:.4f}s")
    assert wi["p95"] < rr["p95"], \
        (f"WhatIfRouter p95 {wi['p95'] * 1e3:.2f}ms did not beat "
         f"round-robin {rr['p95'] * 1e3:.2f}ms")
    for name, s in routers.items():
        assert s["throughput"] >= 0.9 * trace.mean_rate, \
            (f"{name} throughput {s['throughput']:.0f} req/s below 90% of "
             f"the offered {trace.mean_rate:.0f} req/s")


def main() -> list:
    """Harness entry: a reduced campaign (the CSV line per router per
    trace); ``run()`` is the full >=1M-request version."""
    res = run(n_headline=60_000, n_side=40_000)
    rows = []
    for kind, entry in res["traces"].items():
        for router, s in entry["routers"].items():
            rows.append((f"fleet_{kind}_{router}", s["wall_s"] * 1e6,
                         f"mk={s['makespan']:.3f}s,"
                         f"p95={s['p95'] * 1e3:.1f}ms,"
                         f"tput={s['throughput']:.0f}/s,"
                         f"lib={s['fleet_lib']:.2f}%"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # allow `python benchmarks/bench_fleet.py` from the repo root
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tier", default="tier1", choices=["tier1", "slow"])
    ap.add_argument("--full", action="store_true",
                    help="full >=1M-request campaign (minutes)")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.tier)
    elif args.full:
        run()
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
