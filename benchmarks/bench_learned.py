"""Learned-selection benchmark: train the contextual-bandit policy offline
on counterfactual transition logs, then judge it exactly like every other
selection method — Fig. 5 regret vs the Oracle — on held-out (app, system)
cells **never seen in training** (each app and each system appears in
training, just never that pairing: transfer, not memorization).

Gates (``--smoke`` runs a reduced version as the CI tier1 gate):

* LearnedPolicy beats mid-exploration QLearn AND RandomSel on held-out
  cells (zero live exploration is the whole point);
* LearnedHybrid regret <= HybridPolicy regret (the net's top-k window must
  not be worse than the expert ladder's);
* the distilled threshold ladder stays within its stated regret bound of
  the trained net on held-out transitions;
* (recorded, not gated) SimPolicy comparison + decide() latency both ways —
  the learned policy must not pay SimPolicy's per-decision what-if cost.

Everything is recorded to ``results/bench_learned.json``.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "..", "results")


def _stamp(record: dict) -> dict:
    """Platform + device-count metadata (benchmarks/_meta.py) so bench
    trajectories stay comparable across machines and meshes."""
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


#: training cells — every held-out app and system also appears here (the
#: *_het twins cover the held-out machine scale with different pe_speeds),
#: but never in the held-out pairing itself
TRAIN_CELLS = (("tc", "broadwell"), ("tc", "cascadelake"),
               ("tc", "epyc_het"), ("mandelbrot", "epyc"),
               ("mandelbrot", "broadwell"), ("hacc", "cascadelake"),
               ("hacc", "epyc_het"), ("hacc", "broadwell_het"),
               ("stream", "epyc"), ("lulesh", "broadwell_het"))

#: held-out (app, system) pairs — never logged, never trained on
HELDOUT_CELLS = (("tc", "epyc"), ("hacc", "broadwell"))

EVAL_SELECTORS = [("RandomSel", None), ("QLearn", "LT"), ("Hybrid", "LT"),
                  ("SimPolicy", "LT"), ("Learned", "LT"),
                  ("LearnedHybrid", "LT")]

#: the distillation's stated regret-vs-teacher bound (gated on held-out)
DISTILL_BOUND = 0.15


def _tag(sel, reward):
    return f"{sel}+{reward}" if reward else sel


def _collect(cells, T: int, seed: int = 0, perturbed: bool = True):
    """Counterfactual transition log over ``cells`` (plus PE-slowdown
    twins of each cell for perturbation-telemetry coverage)."""
    from repro.sim import (CellSpec, ReplayBatch, TransitionLogger,
                           get_system, pe_slowdown_spec)

    tl = TransitionLogger()
    specs = [CellSpec(app=a, system=s, selector="ExpertSel")
             for a, s in cells]
    if perturbed:
        for a, s in cells:
            P = get_system(s).P
            specs.append(CellSpec(
                app=a, system=s, selector="ExpertSel",
                perturb=pe_slowdown_spec(P, frac=0.25, factor=6.0,
                                         t0=T // 4, t1=(3 * T) // 4)))
    ReplayBatch(specs, T=T, seed=seed, translog=tl).run()
    return tl.arrays()


def _train(cells, T: int, n_steps: int, hidden: int = 32, seed: int = 0):
    """Train on ``cells``; returns (state, train arrays, result dict)."""
    from repro.runtime.policy_trainer import (PolicyTrainerConfig,
                                              train_policy_state)

    arrays = _collect(cells, T=T, seed=seed)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        state, result = train_policy_state(
            arrays, ckpt_dir,
            cfg=PolicyTrainerConfig(ckpt_dir=ckpt_dir, n_steps=n_steps,
                                    hidden=hidden, seed=seed))
    return state, arrays, result


def _heldout_regret(state, cells, T: int, reps: int,
                    selectors=EVAL_SELECTORS, seed: int = 0) -> dict:
    """Fig. 5 degradation per selector on ``cells``, with the trained state
    installed as the process default so learned lanes pick it up."""
    from repro.core import set_default_state
    from repro.sim import run_campaign

    set_default_state(state)
    try:
        res = run_campaign(list(cells), T=T, reps=reps, selectors=selectors,
                           chunk_modes=("default",), seed=seed)
    finally:
        set_default_state(None)
    out = {}
    for (app, sysname), cell in res.items():
        deg = cell.degradation()
        out[f"{app}/{sysname}"] = {
            _tag(sel, reward): round(deg[(sel, "default", reward)], 2)
            for sel, reward in selectors}
    return out


def _mean_regret(per_cell: dict, tag: str) -> float:
    return float(np.mean([r[tag] for r in per_cell.values()]))


def _distill(state, train_arrays, heldout_cells, T: int, seed: int = 0):
    """Fit the interpretable ladder on the training transitions, verify its
    regret vs the teacher net on held-out transitions."""
    from repro.core.learned import (distill_ladder, mlp_forward,
                                    params_from_state)

    ladder = distill_ladder(state, train_arrays["features"],
                            regret_bound=DISTILL_BOUND)
    held = _collect(heldout_cells, T=T, seed=seed, perturbed=False)
    X, costs = held["features"], np.asarray(held["costs"], np.float64)
    params = params_from_state(state["params"])
    net_pick = np.argmin(mlp_forward(params, X.astype(np.float32)), axis=1)
    lad_pick = ladder.predict(X)
    rows = np.arange(len(costs))
    net_cost = float(costs[rows, net_pick].sum())
    lad_cost = float(costs[rows, lad_pick].sum())
    return ladder, {
        "teacher_agreement": round(ladder.teacher_agreement, 4),
        "n_leaves": ladder.n_leaves,
        "heldout_cost_ratio": round(lad_cost / net_cost, 4),
        "regret_bound": DISTILL_BOUND,
        "rules": ladder.describe(),
    }


def decision_latency(state, n: int = 200) -> dict:
    """us per ``decide()``: the learned forward vs SimPolicy's what-if
    pricing (cold = the batched pricing call; warm = cache hit)."""
    from repro.core import LoopFeaturizer, SimPolicy, make_policy
    from repro.sim import LoopWhatIf, get_application, get_system

    profile = get_application("tc").loops(0)[0]
    system = get_system("epyc")
    out = {}

    fz = LoopFeaturizer(system)
    fz.set_context(profile, 0)
    learned = make_policy("Learned", featurizer=fz, state=state)
    t0 = time.perf_counter()
    learned.decide()
    out["Learned_cold"] = round((time.perf_counter() - t0) * 1e6, 2)
    t0 = time.perf_counter()
    for _ in range(n):
        learned.decide()
    out["Learned_warm"] = round((time.perf_counter() - t0) / n * 1e6, 2)

    whatif = LoopWhatIf(system)
    whatif.set_context(profile, 0)
    sim = SimPolicy(whatif, reward="LT")
    t0 = time.perf_counter()
    sim.decide()
    out["SimPolicy_cold"] = round((time.perf_counter() - t0) * 1e6, 2)
    t0 = time.perf_counter()
    for _ in range(n):
        sim.decide()
    out["SimPolicy_warm"] = round((time.perf_counter() - t0) / n * 1e6, 2)
    return out


def run(T: int = 40, reps: int = 2, n_steps: int = 600) -> dict:
    state, train_arrays, result = _train(TRAIN_CELLS, T=T, n_steps=n_steps)
    per_cell = _heldout_regret(state, HELDOUT_CELLS, T=T, reps=reps)
    _, distilled = _distill(state, train_arrays, HELDOUT_CELLS, T=T)
    return {
        "train": {"cells": [f"{a}/{s}" for a, s in TRAIN_CELLS], "T": T,
                  "n_steps": n_steps,
                  "transitions": int(len(train_arrays["features"])),
                  "final_loss": round(result["losses"][-1], 6),
                  "train_regret": round(result["train_regret"], 6)},
        "heldout_regret_pct": per_cell,
        "distilled": distilled,
        "decision_latency_us": decision_latency(state),
    }


def smoke() -> None:
    """CI gate: reduced train -> held-out-regret -> distill loop.  On cells
    never seen in training, LearnedPolicy must beat mid-exploration QLearn
    and RandomSel, LearnedHybrid must not regress vs HybridPolicy, and the
    distilled ladder must honour its stated regret bound vs the net."""
    train_cells = (("tc", "broadwell"), ("tc", "cascadelake"),
                   ("tc", "epyc_het"), ("mandelbrot", "epyc"),
                   ("hacc", "epyc_het"), ("hacc", "cascadelake"))
    heldout = (("tc", "epyc"),)
    state, train_arrays, _ = _train(train_cells, T=12, n_steps=250,
                                    hidden=24)
    per_cell = _heldout_regret(state, heldout, T=16, reps=1)
    reg = per_cell["tc/epyc"]
    print(f"smoke learned tc/epyc T=16 heldout regret: "
          f"learned={reg['Learned+LT']}% qlearn={reg['QLearn+LT']}% "
          f"random={reg['RandomSel']}% hybrid={reg['Hybrid+LT']}% "
          f"learnedhybrid={reg['LearnedHybrid+LT']}% "
          f"sim={reg['SimPolicy+LT']}%")
    assert reg["Learned+LT"] < reg["QLearn+LT"], \
        (f"LearnedPolicy regret {reg['Learned+LT']}% did not beat "
         f"mid-exploration QLearn {reg['QLearn+LT']}%")
    assert reg["Learned+LT"] < reg["RandomSel"], \
        (f"LearnedPolicy regret {reg['Learned+LT']}% did not beat "
         f"RandomSel {reg['RandomSel']}%")
    assert reg["LearnedHybrid+LT"] <= reg["Hybrid+LT"] + 1e-9, \
        (f"LearnedHybrid regret {reg['LearnedHybrid+LT']}% worse than "
         f"HybridPolicy {reg['Hybrid+LT']}%")
    _, distilled = _distill(state, train_arrays, heldout, T=12)
    ratio = distilled["heldout_cost_ratio"]
    print(f"smoke learned distill: heldout cost ratio {ratio} "
          f"(bound {1 + DISTILL_BOUND}), "
          f"{distilled['n_leaves']} rules")
    assert ratio <= 1.0 + DISTILL_BOUND, \
        (f"distilled ladder heldout cost ratio {ratio} exceeds its stated "
         f"bound {1 + DISTILL_BOUND}")


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    res = run()
    with open(os.path.join(OUT, "bench_learned.json"), "w") as f:
        json.dump(_stamp(res), f, indent=2)
    rows = []
    for pair, reg in res["heldout_regret_pct"].items():
        rows.append((f"learned_{pair.replace('/', '_')}",
                     reg["Learned+LT"],
                     f"qlearn={reg['QLearn+LT']}%,"
                     f"random={reg['RandomSel']}%,"
                     f"sim={reg['SimPolicy+LT']}%,"
                     f"learnedhybrid={reg['LearnedHybrid+LT']}%"))
    d = res["distilled"]
    rows.append(("learned_distill_ratio", d["heldout_cost_ratio"],
                 f"agreement={d['teacher_agreement']},"
                 f"leaves={d['n_leaves']}"))
    lat = res["decision_latency_us"]
    rows.append(("learned_decide_warm_us", lat["Learned_warm"],
                 f"sim_warm={lat['SimPolicy_warm']}us,"
                 f"sim_cold={lat['SimPolicy_cold']}us"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # allow `python benchmarks/bench_learned.py` from the repo root
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
