"""Perturbation & drift benchmark: reactive re-pricing vs frozen surrogates.

Replays campaign cells under declarative :class:`PerturbationSpec` scenarios
(``repro.sim.perturb``) and compares the frozen sim-assisted policies against
their reactive counterparts:

* ``pe_slowdown`` — 20% of the machine's PEs drop to 1/8 speed mid-run.
  The frozen ``SimPolicy`` keeps trusting a surrogate calibrated against the
  nominal machine; ``ReactiveSim`` corrects candidate prices from the
  measured/predicted fidelity ratio (PageHinkley-gated EMA), and ``AwareSim``
  runs the two-pass adaptive-surrogate scheme (clean pass, AWF/mAF weight
  re-estimation, perturbed re-simulation).
* ``drift`` — the workload's load imbalance sharpens mid-run
  (``WorkloadDrift(kind="cov")``); ``ReactiveHybrid`` re-prices and
  re-prunes its RL action window when the reward stream shifts,
  ``SimHybrid`` keeps the stale pruning.
* ``clean`` — the bit-equality contract: an *empty* ``PerturbationSpec``
  must replay bit-identically to ``perturb=None`` (perturbation-off runs
  equal the goldens by construction).

``smoke(tier)`` is the CI gate: on the perturbed cells the reactive policies
must beat their frozen counterparts, and the clean contract must hold
bit-exactly.  ``tier1`` runs the slowdown scenario at drift-check scale;
``slow`` adds the drift scenario, longer horizons, and repeats the headline
on the batched JAX backend.  Everything is recorded to
``results/bench_perturb.json``.
"""

from __future__ import annotations

import json
import os
import time

OUT = os.path.join(os.path.dirname(__file__), "..", "results")


def _stamp(record: dict) -> dict:
    """Platform + device-count metadata (benchmarks/_meta.py) so bench
    trajectories stay comparable across machines and meshes."""
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


#: the canonical perturbed cell: hacc/broadwell (a near-uniform loop where
#: the frozen surrogate confidently picks STATIC-ish schedules — exactly
#: what a PE slowdown punishes hardest)
APP, SYSTEM, P = "hacc", "broadwell", 20

#: scenario shapes: (T, perturbation onset)
SIZES = {"tier1": (40, 10), "slow": (120, 30)}


def _cell(selector: str, T: int, perturb=None, backend: str = "python",
          seed: int = 0) -> dict:
    from repro.sim import run_selector

    t0 = time.perf_counter()
    run = run_selector(APP, SYSTEM, selector, T=T, seed=seed,
                       backend=backend, reward="LT", perturb=perturb)
    return {"total": run.total,
            "wall_s": round(time.perf_counter() - t0, 2)}


def _slowdown_scenario(T: int, onset: int, backend: str = "python") -> dict:
    """Frozen vs reactive vs two-pass-aware SimPolicy under a mid-run PE
    slowdown, with ExpertSel as the simulator-free reference."""
    from repro.sim import pe_slowdown_spec

    pz = pe_slowdown_spec(P, frac=0.2, factor=8.0, t0=onset)
    out = {"spec": {"frac": 0.2, "factor": 8.0, "t0": onset},
           "T": T, "backend": backend, "policies": {}}
    for sel in ("SimPolicy", "ReactiveSim", "AwareSim", "ExpertSel"):
        out["policies"][sel] = {
            "perturbed": _cell(sel, T, perturb=pz, backend=backend),
            "clean": _cell(sel, T, backend=backend)}
    return out


def _drift_scenario(T: int, onset: int, backend: str = "python") -> dict:
    """Frozen vs reactive SimHybrid under a mid-run cov-sharpening drift
    (total work preserved; the pruned RL window goes stale)."""
    from repro.sim import drift_spec

    dz = drift_spec("cov", t0=onset, factor=1.8)
    out = {"spec": {"kind": "cov", "factor": 1.8, "t0": onset},
           "T": T, "backend": backend, "app": "tc", "policies": {}}
    from repro.sim import run_selector
    for sel in ("SimHybrid", "ReactiveHybrid"):
        t0 = time.perf_counter()
        run = run_selector("tc", SYSTEM, sel, T=T, seed=0, backend=backend,
                           reward="LT", perturb=dz)
        out["policies"][sel] = {
            "perturbed": {"total": run.total,
                          "wall_s": round(time.perf_counter() - t0, 2)}}
    return out


def _clean_contract(T: int, backend: str = "python") -> dict:
    """Empty PerturbationSpec vs perturb=None: must be bit-equal."""
    from repro.sim import PerturbationSpec, run_selector

    base = run_selector(APP, SYSTEM, "ExpertSel", T=T, seed=0,
                        backend=backend)
    empty = run_selector(APP, SYSTEM, "ExpertSel", T=T, seed=0,
                         backend=backend, perturb=PerturbationSpec())
    return {"T": T, "backend": backend, "total": base.total,
            "bit_equal": bool(base.total == empty.total
                              and base.history == empty.history)}


def _write(results: dict) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_perturb.json"), "w") as f:
        json.dump(_stamp(results), f, indent=2)


def run(tier: str = "slow") -> dict:
    T, onset = SIZES.get(tier, SIZES["tier1"])
    results = {"config": {"app": APP, "system": SYSTEM, "tier": tier,
                          "T": T, "onset": onset},
               "clean_contract": _clean_contract(min(T, 12)),
               "pe_slowdown": _slowdown_scenario(T, onset)}
    _write(results)  # checkpoint before the slow-tier extras
    if tier == "slow":
        results["pe_slowdown_jax"] = _slowdown_scenario(T, onset,
                                                        backend="jax")
        results["drift"] = _drift_scenario(T, onset)
        _write(results)
    return results


def smoke(tier: str = "tier1") -> None:
    """CI perturbation gate: reactive beats frozen on the perturbed cell,
    perturbation-off replays are bit-equal to the goldens."""
    results = run(tier)
    assert results["clean_contract"]["bit_equal"], \
        "empty PerturbationSpec is not bit-equal to perturb=None"
    pol = results["pe_slowdown"]["policies"]
    frozen = pol["SimPolicy"]["perturbed"]["total"]
    reactive = pol["ReactiveSim"]["perturbed"]["total"]
    aware = pol["AwareSim"]["perturbed"]["total"]
    print(f"smoke perturb tier={tier}: frozen={frozen:.1f}s "
          f"reactive={reactive:.1f}s aware={aware:.1f}s", flush=True)
    assert reactive < frozen, \
        (f"ReactiveSim {reactive:.2f}s did not beat frozen SimPolicy "
         f"{frozen:.2f}s under the PE slowdown")
    assert aware < frozen, \
        (f"AwareSim {aware:.2f}s did not beat frozen SimPolicy "
         f"{frozen:.2f}s under the PE slowdown")
    # clean cells: the reactive machinery must cost ~nothing when idle
    f0 = pol["SimPolicy"]["clean"]["total"]
    r0 = pol["ReactiveSim"]["clean"]["total"]
    assert abs(r0 - f0) < 0.05 * f0, \
        (f"ReactiveSim clean total {r0:.2f}s drifted >5% from frozen "
         f"{f0:.2f}s")
    if tier == "slow":
        jx = results["pe_slowdown_jax"]["policies"]
        assert jx["ReactiveSim"]["perturbed"]["total"] < \
            jx["SimPolicy"]["perturbed"]["total"], \
            "ReactiveSim did not beat frozen SimPolicy on the JAX backend"
        dr = results["drift"]["policies"]
        assert dr["ReactiveHybrid"]["perturbed"]["total"] <= \
            1.02 * dr["SimHybrid"]["perturbed"]["total"], \
            "ReactiveHybrid regressed vs frozen SimHybrid under cov drift"


def main() -> list:
    """Harness entry: CSV rows for the tier1-sized scenario set."""
    res = run("tier1")
    rows = []
    for sel, entry in res["pe_slowdown"]["policies"].items():
        for mode in ("perturbed", "clean"):
            s = entry[mode]
            rows.append((f"perturb_slowdown_{sel}_{mode}",
                         s["wall_s"] * 1e6, f"total={s['total']:.2f}s"))
    cc = res["clean_contract"]
    rows.append(("perturb_clean_contract", 0.0,
                 f"bit_equal={cc['bit_equal']}"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # allow `python benchmarks/bench_perturb.py` from the repo root
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tier", default="tier1", choices=["tier1", "slow"])
    args = ap.parse_args()
    if args.smoke:
        smoke(args.tier)
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
