"""Lockstep selector-replay shoot-out: sequential per-cell replays on the
reference engine vs one multi-lane ``ReplayBatch`` on the JAX backend.

This is the end-to-end Fig. 5 campaign bottleneck PR 2 left behind: the
portfolio sweep already batches through ``run_batch``, but ``run_selector``
stepped one cell at a time.  Per app-system pair the full selector grid
(7 selectors x 2 chunk modes = 14 lanes) replays both ways; the speedup and
a cross-engine selection-agreement score land in
``results/bench_replay.json``.

``--smoke`` is the CI acceptance gate: tiny T, asserts the lockstep JAX
replay is >= 3x faster than the sequential reference on at least one pair,
and still writes the JSON record (uploaded as a workflow artifact).
"""

from __future__ import annotations

import json
import os
import time


OUT = os.path.join(os.path.dirname(__file__), "..", "results")

PAIRS = (("sphynx", "epyc"), ("tc", "epyc"), ("lulesh", "cascadelake"),
         ("mandelbrot", "broadwell"))

#: the smoke gate from ISSUE/ROADMAP: lockstep must beat sequential by this
#: factor on at least one app-system pair
SMOKE_SPEEDUP = 3.0


def _selection_agreement(runs_a, runs_b) -> float:
    """Fraction of (lane, loop, instance) selections on which the two
    replays agree — a coarse cross-engine drift signal (RL exploration
    phases are deterministic, so large grids score high even though late
    exploit-phase picks may differ with the noise realization)."""
    same = total = 0
    for ra, rb in zip(runs_a, runs_b):
        for nm in ra.history:
            for ha, hb in zip(ra.history[nm], rb.history[nm]):
                same += int(ha[0] == hb[0])
                total += 1
    return same / max(total, 1)


def run(T: int = 16, seed: int = 0, pairs=PAIRS) -> dict:
    from repro.sim import (CHUNK_MODES, CellSpec, ReplayBatch, SELECTOR_GRID,
                           run_selector_sequential)

    out = {}
    for app, sysname in pairs:
        # checkpoint BEFORE the pair runs: a killed process still leaves
        # the pairs finished so far (bench_fleet's per-trace convention)
        if out:
            _write(out)
        lanes = [CellSpec(app, sysname, sel, mode, reward)
                 for mode in CHUNK_MODES for sel, reward in SELECTOR_GRID]

        t0 = time.perf_counter()
        seq = [run_selector_sequential(s.app, s.system, s.selector,
                                       chunk_mode=s.chunk_mode,
                                       reward=s.reward, T=T, seed=seed,
                                       backend="python")
               for s in lanes]
        t_py = time.perf_counter() - t0

        # first JAX call pays jit compilation; a campaign of many cells sees
        # the steady state, so warm up then measure
        ReplayBatch(lanes, T=T, seed=seed, backend="jax").run()
        t0 = time.perf_counter()
        batched = ReplayBatch(lanes, T=T, seed=seed, backend="jax").run()
        t_jax = time.perf_counter() - t0

        out[f"{app}/{sysname}"] = {
            "T": T, "lanes": len(lanes),
            "sequential_python_s": round(t_py, 4),
            "lockstep_jax_warm_s": round(t_jax, 4),
            "speedup": round(t_py / max(t_jax, 1e-9), 2),
            "selection_agreement": round(
                _selection_agreement(batched, seq), 4),
            "total_rel_diff_max": round(max(
                abs(b.total - s.total) / max(s.total, 1e-12)
                for b, s in zip(batched, seq)), 4),
        }
    return out


def _write(res: dict) -> None:
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp

    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_replay.json"), "w") as f:
        json.dump(stamp(res), f, indent=2)


def smoke() -> None:
    """CI gate: two pairs at tiny T; the lockstep JAX replay must be >=
    SMOKE_SPEEDUP x faster than sequential on at least one of them."""
    res = run(T=8, pairs=(("sphynx", "epyc"), ("tc", "epyc")))
    _write(res)
    best = max(r["speedup"] for r in res.values())
    for pair, r in res.items():
        print(f"smoke replay {pair}: seq={r['sequential_python_s']}s "
              f"lockstep={r['lockstep_jax_warm_s']}s "
              f"speedup={r['speedup']}x agree={r['selection_agreement']}")
    assert best >= SMOKE_SPEEDUP, \
        f"lockstep replay speedup {best}x < {SMOKE_SPEEDUP}x gate"
    print(f"smoke: lockstep replay {best}x >= {SMOKE_SPEEDUP}x")


def main() -> list:
    res = run()
    _write(res)
    rows = []
    for pair, r in res.items():
        rows.append((f"replay_{pair.replace('/', '_')}",
                     r["lockstep_jax_warm_s"] * 1e6,
                     f"speedup={r['speedup']}x,"
                     f"agree={r['selection_agreement']:.2f}"))
    best = max(r["speedup"] for r in res.values())
    rows.append(("replay_best_speedup", 0.0, f"{best}x"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # allow `python benchmarks/bench_replay.py` from the repo root
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
