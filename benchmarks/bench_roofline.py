"""§Roofline — three-term roofline per (arch x shape x mesh) from the
compiled dry-run artifacts (results/dryrun_all.jsonl).

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16 / chip)
    memory     = HLO_bytes / HBM_bw                (819 GB/s / chip)
    collective = wire_bytes / ICI_bw               (50 GB/s / chip)

(all terms per-chip; the dry-run records per-device quantities, so dividing
by per-chip peaks is the instructed `X / (chips * peak)` with the global
numerators pre-divided.)

MODEL_FLOPS = 6*N_active*tokens (train), 2*N_active*tokens (prefill),
2*N_active*batch (decode).  roofline_fraction = the MFU upper bound implied
by the dominant term — the §Perf score.
"""

from __future__ import annotations

import csv
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link / chip

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
DEFAULT_IN = os.path.join(RESULTS, "dryrun_all.jsonl")

SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}
SHAPE_KIND = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}


def model_flops(rec: Dict) -> float:
    kind = SHAPE_KIND[rec["shape"]]
    tokens = SHAPE_TOKENS[rec["shape"]]
    n = rec["active_params"]
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    return mult * n * tokens


def analyze_record(rec: Dict) -> Optional[Dict]:
    if "skipped" in rec or "error" in rec:
        return None
    chips = rec["devices"]
    compute = rec["flops_per_device"] / PEAK_FLOPS
    memory = rec["bytes_per_device"] / HBM_BW
    coll = rec["collective_total"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec) / chips
    useful = mf / max(rec["flops_per_device"], 1.0)
    bound = max(terms.values())
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "hbm_per_device_gb": (rec["memory"]["peak_bytes"] or 0) / 1e9,
    }


def load(path: str = DEFAULT_IN) -> List[Dict]:
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def _rows(path):
    rows, skipped = [], 0
    for rec in load(path):
        a = analyze_record(rec)
        if a is None:
            skipped += 1
        else:
            rows.append(a)
    return rows, skipped


def main() -> list:
    out = []
    for tag, fname in (("", "dryrun_all.jsonl"),
                       ("opt_", "dryrun_optimized.jsonl")):
        path = os.path.join(RESULTS, fname)
        if not os.path.exists(path):
            continue
        rows, skipped = _rows(path)
        csv_path = os.path.join(RESULTS, f"roofline_{tag or 'base_'}.csv"
                                .replace("_.csv", ".csv"))
        with open(csv_path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        for r in rows:
            if r["mesh"] != "16x16":
                continue   # roofline table is single-pod (per instructions)
            out.append((f"roofline_{tag}{r['arch']}_{r['shape']}",
                        max(r["compute_s"], r["memory_s"],
                            r["collective_s"]) * 1e6,
                        f"dom={r['dominant']},"
                        f"frac={r['roofline_fraction']:.3f},"
                        f"useful={r['useful_flops_ratio']:.2f}"))
        out.append((f"roofline_{tag}skipped_cells", float(skipped),
                    "long_500k rule"))
    return out
