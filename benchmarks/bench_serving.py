"""L3 transfer benchmark — chunk-self-scheduled request dispatch over replica
groups: fixed algorithms vs the selection methods on a heavy-tailed request
stream (the serving analogue of Fig. 5).

``smoke()`` is the CI sanity gate on a reduced stream: the selection methods
must not collapse (each stays within ``SMOKE_VS_BEST_FIXED`` of the best
fixed portfolio algorithm).  Results are recorded to
``results/bench_serving.json`` (the bench-wide ``results/*.json``
convention); the legacy ``serving_dispatch.csv`` is kept for the plotting
scripts.
"""

from __future__ import annotations

import csv
import json
import os

from repro.core import ALGORITHM_NAMES
from repro.data import synthetic_requests
from repro.serving import DispatchSimulator

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

def _stamp(record: dict) -> dict:
    """Platform + device-count metadata (benchmarks/_meta.py) so bench
    trajectories stay comparable across machines and meshes."""
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


SELECTORS = [("RandomSel", None), ("ExhaustiveSel", None),
             ("QLearn", "LT"), ("QLearn", "LIB"),
             ("SARSA", "LT"), ("Hybrid", "LT"), ("Hybrid", "p95")]

#: smoke gate: max tolerated makespan ratio of any selection method vs the
#: best fixed algorithm on the reduced stream (measured <=1.10; the margin
#: absorbs the exploration overhead of the learned methods at small T)
SMOKE_VS_BEST_FIXED = 1.35


def run(n_requests: int = 40 * 256, replicas: int = 16, seed: int = 0,
        selectors=SELECTORS):
    reqs = synthetic_requests(n_requests, seed=seed, heavy_tail=1.15)
    rows = []
    # fixed portfolio baselines
    for alg in range(12):
        sim = DispatchSimulator(replicas, selector="Fixed",
                                selector_kw={"algorithm": alg}, seed=seed)
        sim.run(reqs)
        s = sim.summary()
        rows.append((f"fixed_{ALGORITHM_NAMES[alg]}", s["total_makespan"],
                     s["mean_lib"]))
    # selection methods
    for sel, reward in selectors:
        sim = DispatchSimulator(replicas, selector=sel,
                                reward=reward or "LT", seed=seed)
        sim.run(reqs)
        s = sim.summary()
        rows.append((f"{sel}{('_' + reward) if reward else ''}",
                     s["total_makespan"], s["mean_lib"]))
    return rows


def _results(rows, n_fixed: int = 12) -> dict:
    best_fixed = min(r[1] for r in rows[:n_fixed])
    return {
        "best_fixed_makespan_s": round(best_fixed, 6),
        "methods": {name: {"total_makespan_s": round(mk, 6),
                           "mean_lib_pct": round(lib, 2),
                           "vs_best_fixed": round(mk / best_fixed, 4)}
                    for name, mk, lib in rows},
    }


def smoke() -> None:
    """CI dispatch gate (reduced stream): no selection method may collapse
    past SMOKE_VS_BEST_FIXED of the best fixed portfolio algorithm."""
    rows = run(n_requests=8 * 256, replicas=8,
               selectors=[("QLearn", "LT"), ("Hybrid", "LT")])
    res = _results(rows)
    worst = max((m["vs_best_fixed"], name)
                for name, m in res["methods"].items()
                if not name.startswith("fixed_"))
    print(f"smoke serving: worst selector vs best fixed = "
          f"{worst[0]:.3f}x ({worst[1]})")
    assert worst[0] <= SMOKE_VS_BEST_FIXED, \
        (f"{worst[1]} makespan {worst[0]:.3f}x best fixed exceeds the "
         f"{SMOKE_VS_BEST_FIXED}x gate")


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    rows = run()
    with open(os.path.join(OUT, "bench_serving.json"), "w") as f:
        json.dump(_stamp(_results(rows)), f, indent=2)
    with open(os.path.join(OUT, "serving_dispatch.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["method", "total_makespan_s", "mean_lib_pct"])
        w.writerows(rows)
    best_fixed = min(r[1] for r in rows[:12])
    return [(f"serve_{name}", mk * 1e6,
             f"lib={lib:.1f}%,vs_best_fixed={mk / best_fixed:.3f}")
            for name, mk, lib in rows]
