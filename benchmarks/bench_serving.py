"""L3 transfer benchmark — chunk-self-scheduled request dispatch over replica
groups: fixed algorithms vs the selection methods on a heavy-tailed request
stream (the serving analogue of Fig. 5)."""

from __future__ import annotations

import csv
import os

import numpy as np

from repro.core import ALGORITHM_NAMES
from repro.data import synthetic_requests
from repro.serving import DispatchSimulator

OUT = os.path.join(os.path.dirname(__file__), "..", "results")


def run(n_requests: int = 40 * 256, replicas: int = 16, seed: int = 0):
    reqs = synthetic_requests(n_requests, seed=seed, heavy_tail=1.15)
    rows = []
    # fixed portfolio baselines
    for alg in range(12):
        sim = DispatchSimulator(replicas, selector="Fixed",
                                selector_kw={"algorithm": alg}, seed=seed)
        sim.run(reqs)
        s = sim.summary()
        rows.append((f"fixed_{ALGORITHM_NAMES[alg]}", s["total_makespan"],
                     s["mean_lib"]))
    # selection methods
    for sel, reward in [("RandomSel", None), ("ExhaustiveSel", None),
                        ("QLearn", "LT"), ("QLearn", "LIB"),
                        ("SARSA", "LT"), ("Hybrid", "LT"),
                        ("Hybrid", "p95")]:
        sim = DispatchSimulator(replicas, selector=sel,
                                reward=reward or "LT", seed=seed)
        sim.run(reqs)
        s = sim.summary()
        rows.append((f"{sel}{('_' + reward) if reward else ''}",
                     s["total_makespan"], s["mean_lib"]))
    return rows


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    rows = run()
    with open(os.path.join(OUT, "serving_dispatch.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["method", "total_makespan_s", "mean_lib_pct"])
        w.writerows(rows)
    best_fixed = min(r[1] for r in rows[:12])
    return [(f"serve_{name}", mk * 1e6,
             f"lib={lib:.1f}%,vs_best_fixed={mk / best_fixed:.3f}")
            for name, mk, lib in rows]
