"""Mesh-sharded campaign scaling: per-device-count curves for the three
batched surfaces the campaign mesh covers — the lockstep selector replay
(``ReplayBatch``), the fixed-algorithm portfolio sweep (``run_batch``) and
the fleet what-if pricing (``what_if_routes``) — plus the async
double-buffered dispatch toggle on the replay loop.

Every device count replays the *same* seeded workload, so besides
wall-clock the bench asserts **bit-equality** against the single-device
path: lanes are embarrassingly parallel and ``shard_map`` must not change
a single campaign statistic (the contract of ``tests/test_shard.py``).

On a real accelerator host the curve is the point of the record; on CPU,
``--xla_force_host_platform_device_count=8`` carves virtual devices out of
one physical socket, so *speedup is not expected* — the CI gate
(``--smoke``) is bit-equality plus no pathological regression, and the JSON
lands in ``results/bench_shard.json`` with platform + device-count
metadata so trajectories from different topologies are never conflated.

Run standalone (forces 8 virtual devices on CPU when XLA_FLAGS is unset):

    PYTHONPATH=src python benchmarks/bench_shard.py [--smoke]
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

#: CI no-regression bound: sharded wall-clock over single-device wall-clock
#: on virtual (same-socket) devices.  Generous because 8 virtual CPU
#: devices share one thread pool — the gate catches pathological sharding
#: overhead (resharding, host gathers), not scheduling jitter.
SMOKE_REGRESSION = 2.0
#: workloads faster than this single-device are excluded from the ratio
#: gate: a ~1 ms what-if dispatch is pure fixed resharding overhead on
#: virtual devices and flips the ratio on scheduler noise alone (they stay
#: bit-equality gated)
SMOKE_MIN_SECONDS = 0.05

REPLAY_PAIR = ("tc", "epyc")


def _stamp(record: dict) -> dict:
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


def _write(res: dict) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_shard.json"), "w") as f:
        json.dump(_stamp(res), f, indent=2)


def _device_counts():
    import jax

    n = jax.device_count()
    counts = sorted({d for d in (1, 2, 4, 8, n) if 1 <= d <= n})
    return n, counts


def _replay_workload(bk, T: int, warm: bool = True):
    from repro.sim import CHUNK_MODES, CellSpec, ReplayBatch, SELECTOR_GRID

    lanes = [CellSpec(*REPLAY_PAIR, sel, mode, reward)
             for mode in CHUNK_MODES for sel, reward in SELECTOR_GRID]
    if warm:
        ReplayBatch(lanes, T=T, seed=0, backend=bk).run()
    t0 = time.perf_counter()
    runs = ReplayBatch(lanes, T=T, seed=0, backend=bk).run()
    dt = time.perf_counter() - t0
    return dt, [(r.total, r.history) for r in runs]


def _portfolio_workload(bk, T: int, reps: int, warm: bool = True):
    from repro.sim import sweep_portfolio

    if warm:
        sweep_portfolio("mandelbrot", "broadwell", T=T, reps=reps, backend=bk)
    t0 = time.perf_counter()
    sweep = sweep_portfolio("mandelbrot", "broadwell", T=T, reps=reps,
                            backend=bk)
    dt = time.perf_counter() - t0
    key = sorted(sweep.runs, key=str)
    return dt, [sweep.runs[k].times for k in key]


def _routes_workload(bk, n_req: int, warm: bool = True):
    rng = np.random.default_rng(11)
    prefixes = [np.concatenate([[0.0],
                                np.cumsum(rng.random(n_req + 13 * i) * 1e-3)])
                for i in range(4)]
    avails = [rng.random(8) * 1e-3 for _ in range(4)]
    cands = [(s, a, cp) for s in range(4) for a in (0, 2, 4, 6)
             for cp in (0, 16)]
    if warm:
        bk.what_if_routes(prefixes, 8, avails, 2e-4, 1e-3, cands)
    t0 = time.perf_counter()
    prices = bk.what_if_routes(prefixes, 8, avails, 2e-4, 1e-3, cands)
    dt = time.perf_counter() - t0
    return dt, prices


def _equal(a, b) -> bool:
    if isinstance(a, np.ndarray):
        return bool(np.array_equal(a, b))
    if isinstance(a, (list, tuple)):
        return len(a) == len(b) and all(_equal(x, y) for x, y in zip(a, b))
    return a == b


def run(T: int = 8, reps: int = 3, n_req: int = 4096) -> dict:
    import jax

    from repro.sim.backends.jax_batched import JaxBatchedBackend

    n, counts = _device_counts()
    out = {"device_counts": counts, "T": T,
           "platform": jax.default_backend(), "workloads": {}}
    workloads = {
        "lockstep_replay": lambda bk: _replay_workload(bk, T),
        "portfolio_sweep": lambda bk: _portfolio_workload(bk, T, reps),
        "what_if_routes": lambda bk: _routes_workload(bk, n_req),
    }
    for name, fn in workloads.items():
        rec = {}
        ref = None
        for d in counts:
            bk = JaxBatchedBackend(data_parallel=d)
            dt, result = fn(bk)
            if ref is None:
                ref = result
                rec["d1_s"] = round(dt, 4)
            rec.setdefault("seconds", {})[str(d)] = round(dt, 4)
            rec.setdefault("bitexact", {})[str(d)] = _equal(result, ref)
            assert rec["bitexact"][str(d)], \
                f"{name} diverged from single-device at data_parallel={d}"
        base = rec["seconds"][str(counts[0])]
        rec["scaling"] = {k: round(base / max(v, 1e-9), 2)
                          for k, v in rec["seconds"].items()}
        out["workloads"][name] = rec
        _write(out)              # checkpoint after every workload
    # async double-buffered dispatch on the lockstep replay loop, widest mesh
    sync_bk = JaxBatchedBackend(data_parallel=n, async_dispatch=False)
    async_bk = JaxBatchedBackend(data_parallel=n, async_dispatch=True)
    dt_sync, r_sync = _replay_workload(sync_bk, T)
    dt_async, r_async = _replay_workload(async_bk, T)
    assert _equal(r_sync, r_async), "async dispatch changed replay results"
    out["async_dispatch"] = {"devices": n, "sync_s": round(dt_sync, 4),
                             "async_s": round(dt_async, 4),
                             "speedup": round(dt_sync / max(dt_async, 1e-9),
                                              2)}
    _write(out)
    return out


def smoke() -> None:
    """CI gate (forced-8-virtual-device lane): every sharded surface must be
    bit-equal to the single-device path, and the widest mesh must not
    regress wall-clock beyond ``SMOKE_REGRESSION`` x single-device (virtual
    CPU devices share the socket, so *speedup* is not gated — scaling
    curves are the record, equality is the contract)."""
    res = run(T=4, reps=2, n_req=1024)
    res["mode"] = "smoke"
    _write(res)
    worst = 0.0
    for name, rec in res["workloads"].items():
        assert all(rec["bitexact"].values()), f"{name} not bit-equal"
        widest = str(res["device_counts"][-1])
        ratio = rec["seconds"][widest] / max(rec["seconds"]["1"], 1e-9)
        gated = rec["seconds"]["1"] >= SMOKE_MIN_SECONDS
        if gated:
            worst = max(worst, ratio)
        print(f"smoke shard {name}: d1={rec['seconds']['1']}s "
              f"d{widest}={rec['seconds'][widest]}s "
              f"ratio={ratio:.2f} gated={gated} bitexact=True")
    ad = res["async_dispatch"]
    print(f"smoke shard async_dispatch: sync={ad['sync_s']}s "
          f"async={ad['async_s']}s speedup={ad['speedup']}x")
    if len(res["device_counts"]) > 1:
        assert worst <= SMOKE_REGRESSION, \
            (f"sharded path regressed {worst:.2f}x > "
             f"{SMOKE_REGRESSION}x vs single device")
        print(f"smoke: sharded bit-equal, worst ratio {worst:.2f}x <= "
              f"{SMOKE_REGRESSION}x")
    else:
        print("smoke: single device only — bit-equality/async gates ran, "
              "scaling skipped")


def main() -> list:
    res = run()
    res["mode"] = "full"
    _write(res)
    rows = []
    for name, rec in res["workloads"].items():
        widest = str(res["device_counts"][-1])
        rows.append((f"shard_{name}", rec["seconds"][widest] * 1e6,
                     f"devices={widest},scale={rec['scaling'][widest]}x,"
                     f"bitexact={all(rec['bitexact'].values())}"))
    ad = res["async_dispatch"]
    rows.append(("shard_async_dispatch", ad["async_s"] * 1e6,
                 f"speedup={ad['speedup']}x"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # must precede the first jax import: virtual devices only form at boot
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
