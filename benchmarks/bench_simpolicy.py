"""Simulation-assisted selection benchmark: regret-vs-Oracle and decision
latency for SimPolicy / SimHybrid against the paper's selection methods on
Fig. 5 cells.

Regret is the Fig. 5 degradation ((T_method - T_oracle) / T_oracle); the
latency microbench measures ``decide()`` wall-clock — SimPolicy pays one
batched candidate-pricing call (amortized by the what-if cache on repeated
contexts) where the learned methods pay a table lookup.

``--smoke`` is the CI regret gate on the tiny-T tc/epyc cell: SimPolicy
must beat RandomSel and stay within ``SMOKE_REGRET_PCT`` of the Oracle.
Everything is recorded to ``results/bench_simpolicy.json``.
"""

from __future__ import annotations

import json
import os
import time


OUT = os.path.join(os.path.dirname(__file__), "..", "results")

def _stamp(record: dict) -> dict:
    """Platform + device-count metadata (benchmarks/_meta.py) so bench
    trajectories stay comparable across machines and meshes."""
    try:
        from ._meta import stamp
    except ImportError:          # run as a script, not as benchmarks.*
        from _meta import stamp
    return stamp(record)


PAIRS = (("tc", "epyc"), ("mandelbrot", "broadwell"))

SELECTORS = [("RandomSel", None), ("ExpertSel", None), ("QLearn", "LT"),
             ("Hybrid", "LT"), ("SimPolicy", "LT"), ("SimHybrid", "LT")]

#: smoke gate: max tolerated SimPolicy regret vs Oracle on the tiny-T cell
#: (measured ~0 %; the margin absorbs single-rep noise on the Oracle side)
SMOKE_REGRET_PCT = 15.0


def _tag(sel, reward):
    return f"{sel}+{reward}" if reward else sel


def run(T: int = 40, reps: int = 2, pairs=PAIRS) -> dict:
    from repro.sim import run_campaign

    res = run_campaign(list(pairs), T=T, reps=reps, selectors=SELECTORS,
                       chunk_modes=("default",))
    out = {}
    for (app, sysname), cell in res.items():
        deg = cell.degradation()
        out[f"{app}/{sysname}"] = {
            "T": T, "reps": reps,
            "oracle_total_s": round(cell.oracle_total, 6),
            "regret_pct": {
                _tag(sel, reward): round(deg[(sel, "default", reward)], 2)
                for sel, reward in SELECTORS},
        }
    return out


def decision_latency(n: int = 200) -> dict:
    """us per ``decide()``: learned/expert methods vs SimPolicy (cold = the
    batched pricing call; warm = what-if cache hit on a repeated context)."""
    from repro.core import SimPolicy, make_policy
    from repro.sim import LoopWhatIf, get_application, get_system

    profile = get_application("tc").loops(0)[0]
    system = get_system("epyc")
    out = {}
    for name in ("QLearn", "ExpertSel", "Hybrid"):
        policy = make_policy(name, reward="LT") if name != "ExpertSel" \
            else make_policy(name)
        t0 = time.perf_counter()
        for _ in range(n):
            policy.decide()
        out[name] = round((time.perf_counter() - t0) / n * 1e6, 2)

    whatif = LoopWhatIf(system)
    whatif.set_context(profile, 0)
    policy = SimPolicy(whatif, reward="LT")
    t0 = time.perf_counter()
    policy.decide()
    out["SimPolicy_cold"] = round((time.perf_counter() - t0) * 1e6, 2)
    t0 = time.perf_counter()
    for _ in range(n):
        policy.decide()
    out["SimPolicy_warm"] = round((time.perf_counter() - t0) / n * 1e6, 2)
    return out


def smoke() -> None:
    """CI regret gate (tiny-T tc/epyc, single rep): SimPolicy must beat
    RandomSel and stay within SMOKE_REGRET_PCT of the Oracle."""
    from repro.sim import run_campaign

    res = run_campaign([("tc", "epyc")], T=6, reps=1,
                       selectors=[("RandomSel", None), ("SimPolicy", "LT")],
                       chunk_modes=("default",))
    deg = res[("tc", "epyc")].degradation()
    sim = deg[("SimPolicy", "default", "LT")]
    rnd = deg[("RandomSel", "default", None)]
    print(f"smoke simpolicy tc/epyc T=6: regret sim={sim:.2f}% "
          f"random={rnd:.2f}%")
    assert sim < rnd, \
        f"SimPolicy regret {sim:.2f}% did not beat RandomSel {rnd:.2f}%"
    assert sim <= SMOKE_REGRET_PCT, \
        f"SimPolicy regret {sim:.2f}% above the {SMOKE_REGRET_PCT}% gate"


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    res = run()
    res["decision_latency_us"] = decision_latency()
    with open(os.path.join(OUT, "bench_simpolicy.json"), "w") as f:
        json.dump(_stamp(res), f, indent=2)
    rows = []
    for pair, r in res.items():
        if pair == "decision_latency_us":
            continue
        reg = r["regret_pct"]
        rows.append((f"simpolicy_{pair.replace('/', '_')}", 0.0,
                     f"regret_sim={reg['SimPolicy+LT']}%,"
                     f"hybrid={reg['Hybrid+LT']}%,"
                     f"qlearn={reg['QLearn+LT']}%"))
    lat = res["decision_latency_us"]
    rows.append(("simpolicy_decide_warm", lat["SimPolicy_warm"],
                 f"cold={lat['SimPolicy_cold']}us,"
                 f"qlearn={lat['QLearn']}us"))
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    # allow `python benchmarks/bench_simpolicy.py` from the repo root
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in main():
            print(f"{row[0]},{row[1]:.3f},{row[2]}")
