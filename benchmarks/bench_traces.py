"""Figs. 7 & 8 — per-instance selections + selection-share pies for STREAM
on Cascade-Lake (no expChunk) and SPHYNX on EPYC (expChunk)."""

from __future__ import annotations

import csv
import os

from repro.sim import run_selector

OUT = os.path.join(os.path.dirname(__file__), "..", "results")

SCENARIOS = [
    # (figure, app, system, chunk_mode)   — the paper's two showcases
    ("fig7", "stream", "cascadelake", "default"),
    ("fig8", "sphynx", "epyc", "expChunk"),
]
SELECTORS = [("ExhaustiveSel", None), ("ExpertSel", None),
             ("QLearn", "LT"), ("QLearn", "LIB"),
             ("SARSA", "LT"), ("SARSA", "LIB")]


def run(T: int = 300):
    out = {}
    for fig, app, system, mode in SCENARIOS:
        for sel, reward in SELECTORS:
            r = run_selector(app, system, sel, chunk_mode=mode,
                             reward=reward, T=T)
            loop = list(r.history)[0]
            out[(fig, app, system, sel, reward)] = (
                r.history[loop], r.selection_shares(loop), r.total)
    return out


def main() -> list:
    os.makedirs(OUT, exist_ok=True)
    data = run()
    path = os.path.join(OUT, "fig7_fig8_traces.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["figure", "app", "system", "selector", "reward",
                    "instance", "algorithm", "loop_time_s", "lib_pct"])
        for (fig, app, system, sel, reward), (hist, shares, total) in \
                data.items():
            for t, (a, lt, lib) in enumerate(hist):
                w.writerow([fig, app, system, sel, reward or "", t, a,
                            f"{lt:.6f}", f"{lib:.2f}"])
    rows = []
    for (fig, app, system, sel, reward), (hist, shares, total) in data.items():
        top = max(shares.items(), key=lambda kv: kv[1])
        rows.append((f"{fig}_{sel}{('_' + reward) if reward else ''}",
                     total * 1e6, f"top={top[0]}:{top[1]:.0%}"))
    return rows
