"""Benchmark harness — one bench per paper table/figure plus the framework
benches.  Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--full]

Benches:
    chunks       Fig. 1 & 2  chunk-size progressions
    cov          Fig. 4      c.o.v. per app-system pair
    degradation  Fig. 5      selector degradation vs Oracle
    traces       Figs. 7 & 8 per-instance selection traces
    serving      L3          chunk-scheduled dispatch vs selectors
    autotune     L2          step-plan selection on a real model
    roofline     §Roofline   three-term roofline per dry-run cell
    backends     §Backends   portfolio sweep: python vs batched JAX engine
    event_kernel §Backends   while_loop vs fused Pallas event core
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full-fidelity Fig. 5 campaign (hours)")
    args = ap.parse_args()

    from . import (bench_anova, bench_autotune, bench_backends, bench_chunks,
                   bench_cov, bench_degradation, bench_event_kernel,
                   bench_replay, bench_roofline, bench_serving, bench_traces)
    benches = {
        "chunks": bench_chunks.main,
        "cov": bench_cov.main,
        "degradation": lambda: bench_degradation.main(full=args.full),
        "anova": bench_anova.main,
        "traces": bench_traces.main,
        "serving": bench_serving.main,
        "autotune": bench_autotune.main,
        "roofline": bench_roofline.main,
        "backends": bench_backends.main,
        "replay": bench_replay.main,
        "event_kernel": bench_event_kernel.main,
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows = fn()
            for row in rows:
                print(f"{row[0]},{row[1]:.3f},{row[2]}")
            print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except FileNotFoundError as e:
            print(f"bench_{name}_wall,0,SKIPPED({e})", flush=True)
        except Exception as e:
            failures += 1
            print(f"bench_{name}_wall,0,FAILED({type(e).__name__}: {e})",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
