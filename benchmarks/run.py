"""Benchmark harness — one bench per paper table/figure plus the framework
benches.  Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--full]
    PYTHONPATH=src python -m benchmarks.run --smoke [--tier tier1|slow|all]

Benches:
    chunks       Fig. 1 & 2  chunk-size progressions
    cov          Fig. 4      c.o.v. per app-system pair
    degradation  Fig. 5      selector degradation vs Oracle
    traces       Figs. 7 & 8 per-instance selection traces
    serving      L3          chunk-scheduled dispatch vs selectors
    autotune     L2          step-plan selection on a real model
    roofline     §Roofline   three-term roofline per dry-run cell
    backends     §Backends   portfolio sweep: python vs batched JAX engine
    replay       §Backends   lockstep multi-cell replay vs sequential
    event_kernel §Backends   while_loop vs fused Pallas event core
    simpolicy    §SimAS      simulation-assisted selection regret + latency
    perturb      §Perturb    reactive re-pricing vs frozen under perturbations
    fleet        §Fleet      trace-driven routing over replica groups
    faults       §Faults     failure recovery value + crash-safe kill-resume
    shard        §Mesh       per-device-count scaling of the sharded lanes
    learned      §Learned    offline-trained policy: held-out regret + distill

``--smoke`` is the single CI entry point: it runs every registered smoke
gate for the requested tier and ALWAYS writes ``results/smoke_summary.json``
(per-gate status, duration, error) before exiting non-zero on any failure —
the summary is the triage artifact CI uploads with ``if: always()``.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
import traceback

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

#: every CI smoke gate: name -> (module, tier | tuple-of-tiers).  tier1
#: gates are fast drift checks run next to the unit tests; slow gates ride
#: the campaign-scale job; a tuple runs the gate on every listed tier (the
#: gate's ``smoke(tier)`` sizes itself when its signature takes the tier).
SMOKE_GATES = {
    "backends": ("bench_backends", "tier1"),
    "simpolicy": ("bench_simpolicy", "tier1"),
    "serving": ("bench_serving", "tier1"),
    "perturb": ("bench_perturb", ("tier1", "slow")),
    "fleet": ("bench_fleet", ("tier1", "slow")),
    "faults": ("bench_faults", ("tier1", "slow")),
    "learned": ("bench_learned", "tier1"),
    "replay": ("bench_replay", "slow"),
    "event_kernel": ("bench_event_kernel", "slow"),
    # its CI job boots with XLA_FLAGS=--xla_force_host_platform_device_count=8
    # so the mesh has lanes to shard over; sized to available devices
    # otherwise (bit-equality still gated on one device)
    "shard": ("bench_shard", "shard"),
}


def run_smoke(tier: str) -> int:
    """Run every registered smoke gate for ``tier`` ("all" runs everything);
    ``results/smoke_summary.json`` is rewritten after EVERY gate so a
    killed process (OOM, job timeout) still leaves the partial record the
    ``if: always()`` artifact upload exists for.  Returns the number of
    failed gates."""
    import importlib

    summary = {"tier": tier, "gates": {}}
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "smoke_summary.json")

    def flush_summary():
        with open(path, "w") as f:
            json.dump(summary, f, indent=2)

    failures = 0
    for name, (module, gate_tier) in SMOKE_GATES.items():
        tiers = (gate_tier,) if isinstance(gate_tier, str) else gate_tier
        rec = {"tier": "+".join(tiers)}
        run_tier = tier if tier != "all" else tiers[0]
        if tier != "all" and tier not in tiers:
            rec["status"] = "skipped"
            summary["gates"][name] = rec
            flush_summary()
            continue
        rec["status"] = "running"       # visible if this gate kills the job
        summary["gates"][name] = rec
        flush_summary()
        t0 = time.perf_counter()
        try:
            smoke_fn = importlib.import_module(f"benchmarks.{module}").smoke
            if "tier" in inspect.signature(smoke_fn).parameters:
                smoke_fn(tier=run_tier)  # tier-sized gates (e.g. fleet)
            else:
                smoke_fn()
            rec["status"] = "ok"
        except Exception as e:
            failures += 1
            rec["status"] = "failed"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc(limit=8)
        rec["seconds"] = round(time.perf_counter() - t0, 3)
        flush_summary()
        print(f"smoke gate {name}: {rec['status']} "
              f"({rec['seconds']}s)", flush=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full-fidelity Fig. 5 campaign (hours)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the registered CI smoke gates and write "
                         "results/smoke_summary.json")
    ap.add_argument("--tier", default="all",
                    choices=["tier1", "slow", "shard", "all"],
                    help="which smoke gates to run (with --smoke)")
    args = ap.parse_args()

    if args.smoke:
        sys.exit(1 if run_smoke(args.tier) else 0)

    from . import (bench_anova, bench_autotune, bench_backends, bench_chunks,
                   bench_cov, bench_degradation, bench_event_kernel,
                   bench_faults, bench_fleet, bench_learned, bench_perturb,
                   bench_replay, bench_roofline, bench_serving, bench_shard,
                   bench_simpolicy, bench_traces)
    benches = {
        "chunks": bench_chunks.main,
        "cov": bench_cov.main,
        "degradation": lambda: bench_degradation.main(full=args.full),
        "anova": bench_anova.main,
        "traces": bench_traces.main,
        "serving": bench_serving.main,
        "autotune": bench_autotune.main,
        "roofline": bench_roofline.main,
        "backends": bench_backends.main,
        "replay": bench_replay.main,
        "event_kernel": bench_event_kernel.main,
        "simpolicy": bench_simpolicy.main,
        "perturb": bench_perturb.main,
        "fleet": bench_fleet.main,
        "faults": bench_faults.main,
        "shard": bench_shard.main,
        "learned": bench_learned.main,
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows = fn()
            for row in rows:
                print(f"{row[0]},{row[1]:.3f},{row[2]}")
            print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except FileNotFoundError as e:
            print(f"bench_{name}_wall,0,SKIPPED({e})", flush=True)
        except Exception as e:
            failures += 1
            print(f"bench_{name}_wall,0,FAILED({type(e).__name__}: {e})",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
