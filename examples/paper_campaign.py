"""Reproduce the paper's factorial campaign (Table 2 / Fig. 5) at a chosen
scale and print the degradation-vs-Oracle table.

    PYTHONPATH=src python examples/paper_campaign.py                 # subset
    PYTHONPATH=src python examples/paper_campaign.py --apps all --T 500

All requested cells run through ONE ``run_campaign`` call: the portfolio
sweeps batch per cell, and every cell's selector lanes replay in lockstep
(``--selector-backend jax`` batches the replays too; the default keeps them
on the reference engine for exact per-chunk telemetry).

``--selectors sim`` (or setting ``REPRO_SIM_POLICY``) adds the
simulation-assisted lanes — SimPolicy (candidate pricing in a noise-free
simulator, zero live exploration) and SimHybrid (RL over the simulator's
top-k) — priced on ``--sim-backend``.
"""

import argparse

from repro.core import resolve_sim_policy
from repro.sim import (APPLICATIONS, EXTENDED_SELECTOR_GRID, SELECTOR_GRID,
                       SIM_SELECTOR_GRID, SYSTEMS, run_campaign)

GRIDS = {"paper": SELECTOR_GRID, "extended": EXTENDED_SELECTOR_GRID,
         "sim": SIM_SELECTOR_GRID}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", default="sphynx,stream")
    ap.add_argument("--systems", default="cascadelake")
    ap.add_argument("--T", type=int, default=300)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--backend", default=None,
                    help="simulation backend for the portfolio sweeps "
                         "(python | jax; default REPRO_SIM_BACKEND)")
    ap.add_argument("--selector-backend", default="python",
                    help="backend for the lockstep selector replays "
                         "(python = exact telemetry; jax = batched lanes)")
    ap.add_argument("--selectors", default=None,
                    choices=sorted(GRIDS),
                    help="selector grid: paper | extended (+Hybrid) | sim "
                         "(+SimPolicy/SimHybrid); default: sim when "
                         "REPRO_SIM_POLICY is set, else paper")
    ap.add_argument("--sim-backend", default=None,
                    help="backend pricing the sim-assisted candidate sets "
                         "(default: --selector-backend)")
    args = ap.parse_args()
    if args.selectors is None:
        # resolve_sim_policy validates the env spelling (a typo raises)
        args.selectors = "sim" if resolve_sim_policy() else "paper"

    apps = (list(APPLICATIONS) if args.apps == "all"
            else args.apps.split(","))
    systems = (list(SYSTEMS) if args.systems == "all"
               else args.systems.split(","))
    cells = [(app, system) for app in apps for system in systems]

    results = run_campaign(cells, T=args.T, reps=args.reps,
                           backend=args.backend,
                           selector_backend=args.selector_backend,
                           selectors=GRIDS[args.selectors],
                           sim_backend=args.sim_backend)
    for (app, system), cell in results.items():
        print(f"\n=== {app} on {system} ===   "
              f"Oracle={cell.oracle_total:.2f}s  "
              f"c.o.v.={cell.sweep.cov():.3f}")
        for k, d in sorted(cell.degradation().items(),
                           key=lambda kv: kv[1]):
            sel, mode, reward = k
            r = cell.selector_runs[k]
            shares = r.selection_shares()
            top = max(shares, key=shares.get) if shares else "-"
            tag = f"{sel}+{reward}" if reward else sel
            print(f"  {tag:15s} {mode:9s} {d:+7.1f}%   mostly->{top}")


if __name__ == "__main__":
    main()
