"""Quickstart: the paper's core loop on the structured selection API.

1. build the 12-algorithm portfolio and inspect chunk schedules;
2. run one simulated SPHYNX loop instance per algorithm;
3. drive selection through ``SelectionService.instance`` (Decision in,
   Observation out) and compare every method — including the §6 Hybrid
   (expert-seeded RL) — against Oracle;
4. persist the learned Q-table and warm-start a second service from it
   (paper §5: the 28.8 % exploration cost drops to zero on re-runs).

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.core import ALGORITHM_NAMES, SelectionService, exp_chunk
from repro.sim import (get_application, get_system, run_instance,
                       run_selector, sweep_portfolio)


def main():
    app = get_application("sphynx")
    system = get_system("cascadelake")
    profile = app.loops(0)[0]
    cp = exp_chunk(profile.N, system.P)
    print(f"SPHYNX gravity loop: N={profile.N:,} iterations, "
          f"P={system.P} threads, expChunk={cp}")

    print("\n-- one loop instance per scheduling algorithm (expChunk) --")
    for alg, name in enumerate(ALGORITHM_NAMES):
        r = run_instance(profile, system, alg, cp, np.random.default_rng(0))
        print(f"  {name:12s} {r.loop_time * 1e3:7.1f} ms   "
              f"LIB={r.lib:5.1f}%   chunks={r.n_chunks}")

    T = 200
    print(f"\n-- online selection over {T} time-steps (expChunk) --")
    sweep = sweep_portfolio("sphynx", "cascadelake", T=T, reps=1)
    oracle = sweep.oracle_times()[:T].sum()
    for sel, reward in [("ExhaustiveSel", None), ("ExpertSel", None),
                        ("QLearn", "LT"), ("QLearn", "LIB"),
                        ("SARSA", "LT"), ("Hybrid", "LT"),
                        ("Hybrid", "LT+LIB"), ("RandomSel", None)]:
        run = run_selector("sphynx", "cascadelake", sel, reward=reward,
                           chunk_mode="expChunk", T=T)
        deg = (run.total - oracle) / oracle * 100
        shares = run.selection_shares()
        top = max(shares, key=shares.get)
        tag = f"{sel}+{reward}" if reward else sel
        print(f"  {tag:15s} total={run.total:7.2f}s  vs Oracle {deg:+6.1f}%  "
              f"mostly->{top}")
    print(f"  {'Oracle':15s} total={oracle:7.2f}s")

    print("\n-- warm start: persist the Q-table, skip the learning phase --")
    store = tempfile.mkdtemp(prefix="repro_qtables_")
    rng = np.random.default_rng(7)
    with SelectionService("QLearn", reward="LT", store_dir=store) as svc:
        for t in range(180):
            with svc.instance("gravity") as inst:
                res = run_instance(profile, system, inst.action, cp, rng)
                inst.report(loop_time=res.loop_time, lib=res.lib)
        cold = svc.policy("gravity")
        print(f"  cold run : {cold.learning_steps} exploration instances, "
              f"now exploiting {ALGORITHM_NAMES[cold.decide().action]}")
    svc2 = SelectionService("QLearn", reward="LT", store_dir=store)
    warm = svc2.policy("gravity")
    d = warm.decide()
    print(f"  warm run : restored from {store}; learning={warm.learning}, "
          f"first decision -> {ALGORITHM_NAMES[d.action]} "
          f"(phase={d.phase})")


if __name__ == "__main__":
    main()
