"""Quickstart: the paper's core loop in 60 lines.

1. build the 12-algorithm portfolio and inspect chunk schedules;
2. run one simulated SPHYNX loop instance per algorithm;
3. let Q-Learn (LT reward, explore-first) select online and compare against
   Oracle and ExhaustiveSel.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import ALGORITHM_NAMES, exp_chunk, make_selector
from repro.sim import (get_application, get_system, run_instance,
                       run_selector, sweep_portfolio)


def main():
    app = get_application("sphynx")
    system = get_system("cascadelake")
    profile = app.loops(0)[0]
    cp = exp_chunk(profile.N, system.P)
    print(f"SPHYNX gravity loop: N={profile.N:,} iterations, "
          f"P={system.P} threads, expChunk={cp}")

    print("\n-- one loop instance per scheduling algorithm (expChunk) --")
    for alg, name in enumerate(ALGORITHM_NAMES):
        r = run_instance(profile, system, alg, cp, np.random.default_rng(0))
        print(f"  {name:12s} {r.loop_time * 1e3:7.1f} ms   "
              f"LIB={r.lib:5.1f}%   chunks={r.n_chunks}")

    T = 200
    print(f"\n-- online selection over {T} time-steps (expChunk) --")
    sweep = sweep_portfolio("sphynx", "cascadelake", T=T, reps=1)
    oracle = sweep.oracle_times()[:T].sum()
    for sel, reward in [("ExhaustiveSel", None), ("ExpertSel", None),
                        ("QLearn", "LT"), ("QLearn", "LIB"),
                        ("SARSA", "LT"), ("RandomSel", None)]:
        run = run_selector("sphynx", "cascadelake", sel, reward=reward,
                           chunk_mode="expChunk", T=T)
        deg = (run.total - oracle) / oracle * 100
        shares = run.selection_shares()
        top = max(shares, key=shares.get)
        tag = f"{sel}+{reward}" if reward else sel
        print(f"  {tag:15s} total={run.total:7.2f}s  vs Oracle {deg:+6.1f}%  "
              f"mostly->{top}")
    print(f"  {'Oracle':15s} total={oracle:7.2f}s")


if __name__ == "__main__":
    main()
