"""End-to-end serving driver (the paper's kind of system: a runtime that
selects scheduling algorithms online).

Part 1 — LIVE: a reduced llama-family model decodes real tokens under
continuous batching (jitted serve_step, KV cache, slot refill).

Part 2 — SCALE: 16 replica groups serve a heavy-tailed request stream;
the dispatcher self-schedules request chunks with the 12-algorithm portfolio
and each selection method picks the algorithm online (LT/LIB from measured
wave times).  Compare against the fixed-algorithm baselines.

    PYTHONPATH=src python examples/serve.py [--requests 4096]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_reduce
from repro.core import ALGORITHM_NAMES
from repro.data import synthetic_requests
from repro.models import decode_step, init_decode_cache, init_params
from repro.serving import (ContinuousBatcher, DispatchSimulator,
                           ReplicaCostModel)


def live_part():
    print("== live continuous batching (reduced llama3.2 family) ==")
    cfg = dataclasses.replace(smoke_reduce(get_config("llama3.2-3b")),
                              n_layers=2, vocab_size=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    SLOTS, MAXLEN = 8, 256
    cache = init_decode_cache(cfg, SLOTS, MAXLEN)
    serve = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    reqs = synthetic_requests(32, seed=0, mean_prompt=8, mean_gen=24)
    batcher = ContinuousBatcher(serve, None, SLOTS)
    batcher.submit(reqs)
    toks = jnp.zeros((SLOTS,), jnp.int32)
    stats = batcher.run(params, cache, toks, max_steps=220)
    print(f"  decoded {stats['tokens']} tokens in {stats['wall']:.2f}s "
          f"({stats['tokens_per_s']:.0f} tok/s), "
          f"completed {stats['completed']}/32 requests")
    # calibrate the replica cost model from the measured step
    per_tok = stats["wall"] / max(stats["tokens"], 1)
    print(f"  calibrated per-token cost: {per_tok * 1e6:.0f} us")
    return per_tok


def scale_part(n_requests: int, per_tok: float):
    print("\n== chunk-self-scheduled dispatch over 16 replica groups ==")
    reqs = synthetic_requests(n_requests, seed=7, heavy_tail=1.15)
    cost = ReplicaCostModel(per_token=per_tok / 50)  # replica group >> 1 dev
    rows = []
    for alg in (0, 1, 2, 6):
        sim = DispatchSimulator(16, selector="Fixed",
                                selector_kw={"algorithm": alg},
                                cost_model=cost)
        sim.run(reqs)
        s = sim.summary()
        rows.append((f"fixed {ALGORITHM_NAMES[alg]}", s))
    for sel, reward in [("ExhaustiveSel", None), ("QLearn", "LT"),
                        ("QLearn", "LIB"), ("SARSA", "LT"),
                        ("Hybrid", "LT"), ("Hybrid", "p95")]:
        sim = DispatchSimulator(16, selector=sel, reward=reward or "LT",
                                cost_model=cost)
        sim.run(reqs)
        tag = f"{sel}+{reward}" if reward else sel
        rows.append((tag, sim.summary()))
    best = min(s["total_makespan"] for _, s in rows)
    for name, s in rows:
        print(f"  {name:18s} makespan={s['total_makespan']:8.3f}s  "
              f"mean LIB={s['mean_lib']:5.1f}%  "
              f"(x{s['total_makespan'] / best:.2f} of best)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4096)
    args = ap.parse_args()
    per_tok = live_part()
    scale_part(args.requests, per_tok)


if __name__ == "__main__":
    main()
