"""Fault-tolerant training driver with online step-plan selection.

Trains a llama-family model (default ~20M params; --big for ~110M) for a few
hundred steps on CPU with:

* the StepAutoTuner choosing the execution plan per step (the paper's
  technique at step granularity — ExhaustiveSel by default, --method QLearn),
* async atomic checkpoints + injected node failures + replay,
* deterministic data (restart-equivalent by construction).

    PYTHONPATH=src python examples/train_small.py --steps 120
"""

import argparse
import dataclasses
import shutil

from repro.configs import get_config, smoke_reduce
from repro.configs.base import ModelConfig
from repro.data import DataConfig
from repro.distributed import ExecutionPlan, StepAutoTuner, make_plan_builder
from repro.optim.adamw import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

PLANS = [ExecutionPlan("mb1_remat", 1, True),
         ExecutionPlan("mb2_remat", 2, True),
         ExecutionPlan("mb4_remat", 4, True),
         ExecutionPlan("mb1_noremat", 1, False)]


def build_cfg(big: bool) -> ModelConfig:
    base = smoke_reduce(get_config("llama3.2-3b"))
    if big:   # ~110M params
        return dataclasses.replace(base, n_layers=12, d_model=768,
                                   n_heads=12, n_kv_heads=4, head_dim=64,
                                   d_ff=2048, vocab_size=32768)
    return dataclasses.replace(base, n_layers=4, d_model=256, n_heads=4,
                               n_kv_heads=2, head_dim=64, d_ff=768,
                               vocab_size=8192)     # ~7M params (1-core CPU;
                               # --big for the 110M-parameter run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--big", action="store_true", help="~110M params")
    ap.add_argument("--method", default="ExhaustiveSel")
    ap.add_argument("--failure-rate", type=float, default=0.02)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt, ignore_errors=True)
    cfg = build_cfg(args.big)
    print(f"model: {cfg.n_params() / 1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                          global_batch=4, seed=0)
    tuner = StepAutoTuner(PLANS, make_plan_builder(cfg, opt_cfg),
                          method=args.method)
    trainer = Trainer(cfg, opt_cfg, data_cfg,
                      TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=20,
                                    failure_rate=args.failure_rate),
                      autotuner=tuner)
    trainer.install_preemption_handler()
    out = trainer.train(args.steps)

    losses = out["losses"]
    print(f"\nsteps={out['final_step']} restarts={out['restarts']} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    from collections import Counter
    plans = Counter(m["plan"] for m in trainer.metrics_log)
    print("plan selections:", dict(plans))
    print("selected plan after exploration:", tuner.selected_plan)


if __name__ == "__main__":
    main()
