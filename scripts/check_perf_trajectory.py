#!/usr/bin/env python
"""Perf-trajectory gate: fresh nightly bench results vs the committed ones.

For every ``results/bench_*.json`` with a headline-metric registry entry,
compare the freshly-produced working-tree file against the version
committed at a git ref (default HEAD).  A headline metric drifting more
than ``WARN`` (10%) emits a GitHub ``::warning::``; more than ``FAIL``
(2x, i.e. 100% relative change) fails the job.  Boolean invariants
(bit-exactness flags) must never flip to false.

Results produced on a different platform are not comparable — every bench
stamps ``meta`` (``benchmarks/_meta.py``) and files whose ``meta.platform``
or ``meta.device_count`` differ from the baseline are skipped, so a laptop
re-run never trips a gate calibrated on CI timings.

    PYTHONPATH=src python scripts/check_perf_trajectory.py [--ref HEAD] \\
        [--results results]
"""

import argparse
import json
import os
import subprocess
import sys

WARN = 0.10      # >10% drift on a headline metric -> ::warning::
FAIL = 1.00      # >2x (100% relative change) -> job failure
FLOOR = 1e-3     # denominator floor so near-zero baselines don't explode

#: headline metrics per bench file: dotted paths, ``*`` matches any key
REGISTRY = {
    "bench_replay.json": ["*.speedup"],
    "bench_fleet.json": ["traces.*.mean_rate"],
    "bench_faults.json": ["recovery.on.throughput",
                          "recovery.off.throughput",
                          "recovery.on.p99"],
    "bench_shard.json": ["workloads.*.d1_s"],
    "bench_event_kernel.json": ["lanes.*.while_loop_s"],
    "bench_backends.json": ["cov.*.jax"],
    "bench_learned.json": ["decision_latency_us.Learned_warm",
                           "distilled.teacher_agreement"],
}

#: boolean invariants that must never flip to false
INVARIANTS = {
    "bench_faults.json": ["kill_resume.bit_equal"],
    "bench_event_kernel.json": ["lanes.*.bitexact"],
}


def _walk(node, parts, prefix=""):
    """Expand a dotted path (with ``*`` wildcards) into (label, value)."""
    if not parts:
        yield prefix.rstrip("."), node
        return
    head, rest = parts[0], parts[1:]
    if not isinstance(node, dict):
        return
    keys = sorted(node) if head == "*" else ([head] if head in node else [])
    for k in keys:
        yield from _walk(node[k], rest, f"{prefix}{k}.")


def _metrics(record, paths):
    out = {}
    for path in paths:
        for label, val in _walk(record, path.split(".")):
            if isinstance(val, (int, float)) and not isinstance(val, bool):
                out[label] = float(val)
    return out


def _load_committed(ref, relpath):
    try:
        blob = subprocess.run(["git", "show", f"{ref}:{relpath}"],
                              capture_output=True, check=True)
    except subprocess.CalledProcessError:
        return None
    try:
        return json.loads(blob.stdout)
    except json.JSONDecodeError:
        return None


def _platform_key(record):
    meta = record.get("meta")
    if not isinstance(meta, dict):
        return None
    return (meta.get("platform"), meta.get("device_count"))


def check_file(name, fresh, base):
    """Returns (warnings, failures) message lists for one bench file."""
    warns, fails = [], []
    for label, old in sorted(_metrics(base, REGISTRY[name]).items()):
        new = _metrics(fresh, REGISTRY[name]).get(label)
        if new is None:
            warns.append(f"{name}:{label} missing from fresh results")
            continue
        rel = abs(new - old) / max(abs(old), FLOOR)
        line = (f"{name}:{label} {old:g} -> {new:g} "
                f"({100 * rel:+.1f}% drift)")
        if rel > FAIL:
            fails.append(line)
        elif rel > WARN:
            warns.append(line)
    for path in INVARIANTS.get(name, ()):
        for label, val in _walk(fresh, path.split(".")):
            if val is False:
                fails.append(f"{name}:{label} invariant flipped to false")
    return warns, fails


def main():
    ap = argparse.ArgumentParser(
        description="compare fresh bench results vs committed baselines")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the baseline results")
    ap.add_argument("--results", default="results",
                    help="directory with the freshly-produced json files")
    args = ap.parse_args()

    n_checked, warns, fails = 0, [], []
    for name in sorted(REGISTRY):
        path = os.path.join(args.results, name)
        if not os.path.exists(path):
            print(f"skip  {name}: no fresh results")
            continue
        with open(path) as f:
            fresh = json.load(f)
        base = _load_committed(args.ref, f"results/{name}")
        if base is None:
            print(f"skip  {name}: no committed baseline at {args.ref}")
            continue
        if _platform_key(fresh) != _platform_key(base):
            print(f"skip  {name}: platform stamp differs "
                  f"({_platform_key(base)} -> {_platform_key(fresh)})")
            continue
        w, x = check_file(name, fresh, base)
        warns += w
        fails += x
        n_checked += 1
        print(f"check {name}: "
              f"{len(_metrics(base, REGISTRY[name]))} metrics, "
              f"{len(w)} warnings, {len(x)} failures")

    for msg in warns:
        print(f"::warning::perf trajectory: {msg}")
    for msg in fails:
        print(f"::error::perf trajectory: {msg}")
    print(f"perf trajectory: {n_checked} files checked, "
          f"{len(warns)} warnings, {len(fails)} failures")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
