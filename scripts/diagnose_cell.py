#!/usr/bin/env python
"""Hillclimb profiler: compile one cell and print the top contributors —
collectives and data-movement instructions by (bytes x trips).  This is the
'profile' of the dry-run methodology (lowered IR, not wall clock)."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
from repro.launch import dryrun  # noqa: E402
from repro.launch.hlo_analysis import (HloAnalyzer, _bytes_of,  # noqa: E402
                                       collective_wire, COLL_KINDS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moe-groups", type=int, default=1)
    ap.add_argument("--attn-remat", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    # monkey-patch run_cell to keep the compiled object
    import repro.launch.dryrun as dr
    hlo_holder = {}
    orig = jax.stages.Lowered.compile

    def capture(self, *a, **k):
        c = orig(self, *a, **k)
        hlo_holder["hlo"] = c.as_text()
        return c
    jax.stages.Lowered.compile = capture
    res = dr.run_cell(args.arch, args.shape, args.multi,
                      microbatches=args.microbatches,
                      moe_groups=args.moe_groups,
                      attn_remat=args.attn_remat)
    print({k: v for k, v in res.items()
           if k in ("flops_per_device", "bytes_per_device",
                    "collective_total")})
    print("bytes_by:", {k: f"{v/1e9:.0f}GB"
                        for k, v in res.get("bytes_by_category", {}).items()})

    hlo = hlo_holder["hlo"]
    an = HloAnalyzer(hlo, 512 if args.multi else 256)

    # per-instruction contributions with trip multipliers
    contrib = []

    def walk(comp, mult, stack=()):
        if comp in stack:
            return
        for ins in an.comps.get(comp, []):
            if ins.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                               ins.line)
                trips = int(mt.group(1)) if mt else 1
                if mb:
                    walk(mb.group(1), mult * trips, stack + (comp,))
                continue
            if ins.op in ("call", "conditional", "custom-call"):
                for mr in re.finditer(r"(?:to_apply=|calls=)%?([\w\.\-]+)",
                                      ins.line):
                    walk(mr.group(1), mult, stack + (comp,))
                continue
            sz = _bytes_of(ins.rtype)
            is_coll = any(ins.op.startswith(k) for k in COLL_KINDS)
            if is_coll or ins.op in ("copy", "transpose", "reshape",
                                     "concatenate", "broadcast", "slice",
                                     "pad", "gather", "scatter", "sort",
                                     "fusion", "dynamic-slice",
                                     "dynamic-update-slice"):
                meta = re.search(r'op_name="([^"]+)"', ins.line)
                contrib.append((sz * mult, ins.op, ins.rtype[:48],
                                (meta.group(1)[-90:] if meta else "")))
    walk(an.entry, 1.0)
    contrib.sort(reverse=True)
    print(f"\ntop {args.top} data-movement/collective instructions "
          f"(bytes x trips):")
    for sz, op, rt, meta in contrib[:args.top]:
        print(f"  {sz/1e9:9.1f}GB  {op:22s} {rt:48s} {meta}")


if __name__ == "__main__":
    main()
