#!/usr/bin/env python
"""Mass-produce counterfactual transition logs for offline policy training.

One shard per (application, system, scenario) cell of the campaign grid —
including the ``*_het`` heterogeneous systems and ``PerturbationSpec``
drift scenarios — each written atomically, so a killed run resumes by
skipping shards that already exist (``--force`` regenerates).

    PYTHONPATH=src python scripts/gen_translog.py --out data/translog \\
        --apps tc mandelbrot hacc --systems broadwell epyc_het -T 40

Every shard row carries the priced cost of all 12 portfolio algorithms for
its exact (profile, chunk-param, perturbation) context, logged by a
:class:`repro.sim.translog.TransitionLogger` riding a lockstep replay.
Feed the shards to ``repro.runtime.policy_trainer`` (see
``benchmarks/bench_learned.py`` for the train → evaluate → distill loop).
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim import (APPLICATIONS, HETERO_SYSTEMS, SYSTEMS,  # noqa: E402
                       CellSpec, ReplayBatch, TransitionLogger, get_system,
                       drift_spec, noise_burst_spec, pe_slowdown_spec)

#: perturbation scenarios per cell: clean, a mid-run PE slowdown, a noise
#: burst, and a workload drift — the telemetry regimes the net must cover
def _scenarios(P: int, T: int):
    t0, t1 = T // 4, (3 * T) // 4
    return {
        "clean": None,
        "peslow": pe_slowdown_spec(P, frac=0.25, factor=6.0, t0=t0, t1=t1),
        "noise": noise_burst_spec(factor=8.0, t0=t0, t1=t1),
        "drift": drift_spec("cov", t0=t0, factor=2.0),
    }


def main():
    ap = argparse.ArgumentParser(
        description="generate counterfactual translog shards")
    ap.add_argument("--out", default="data/translog",
                    help="output directory for npz shards")
    ap.add_argument("--apps", nargs="*", default=sorted(APPLICATIONS),
                    help="applications (default: all)")
    ap.add_argument("--systems", nargs="*",
                    default=sorted(SYSTEMS) + sorted(HETERO_SYSTEMS),
                    help="systems (default: all, incl. *_het)")
    ap.add_argument("--scenarios", nargs="*",
                    choices=["clean", "peslow", "noise", "drift"],
                    default=["clean", "peslow", "noise", "drift"])
    ap.add_argument("-T", type=int, default=40,
                    help="time steps per cell (default 40)")
    ap.add_argument("--selector", default="ExpertSel",
                    help="behaviour selector driving the lanes (costs are "
                    "counterfactual, so any selector yields the same "
                    "training signal)")
    ap.add_argument("--stride", type=int, default=1,
                    help="log every k-th step only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default=None,
                    help="replay backend (python|jax)")
    ap.add_argument("--force", action="store_true",
                    help="regenerate shards that already exist")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    total_rows = 0
    for app in args.apps:
        for sysname in args.systems:
            P = get_system(sysname).P
            scen = _scenarios(P, args.T)
            for tag in args.scenarios:
                path = os.path.join(args.out,
                                    f"{app}__{sysname}__{tag}.npz")
                if os.path.exists(path) and not args.force:
                    print(f"skip  {path} (exists)")
                    continue
                t0 = time.perf_counter()
                tl = TransitionLogger(sim_backend=args.backend,
                                      stride=args.stride)
                spec = CellSpec(app=app, system=sysname,
                                selector=args.selector, perturb=scen[tag])
                ReplayBatch([spec], T=args.T, seed=args.seed,
                            backend=args.backend, translog=tl).run()
                tl.save(path)
                total_rows += len(tl)
                print(f"wrote {path}: {len(tl)} rows "
                      f"({time.perf_counter() - t0:.1f}s)")
    print(f"total: {total_rows} transitions under {args.out}/")


if __name__ == "__main__":
    main()
