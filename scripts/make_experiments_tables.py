#!/usr/bin/env python
"""Regenerate the EXPERIMENTS.md dry-run/roofline tables from the sweep
JSONLs (baseline + optimized)."""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.bench_roofline import analyze_record  # noqa: E402

RES = os.path.join(os.path.dirname(__file__), "..", "results")


def load(name):
    path = os.path.join(RES, name)
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path)]


def dryrun_table(recs):
    out = ["| arch | shape | mesh | HLO TFLOP/dev | HLO TB/dev | "
           "wire GB/dev (ag/ar/a2a/cp) | HBM peak GB/dev |",
           "|---|---|---|---:|---:|---|---:|"]
    for r in recs:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| SKIP (sub-quadratic rule) | — |")
            continue
        c = r["collective_wire_bytes_per_device"]
        coll = "/".join(f"{c[k] / 1e9:.0f}"
                        for k in ("all-gather", "all-reduce", "all-to-all",
                                  "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['flops_per_device'] / 1e12:,.1f} "
            f"| {r['bytes_per_device'] / 1e12:.2f} | {coll} "
            f"| {r['memory']['peak_bytes'] / 1e9:.1f} |")
    return "\n".join(out)


def roofline_table(recs, base=None):
    base_map = {}
    if base:
        for r in base:
            if r.get("mesh") == "16x16" and "skipped" not in r:
                a = analyze_record(r)
                base_map[(a["arch"], a["shape"])] = a
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | roofline frac" +
           (" | vs baseline bound |" if base else " |"),
           "|---|---|---:|---:|---:|---|---:|---:|" + ("---:|" if base else "")]
    for r in recs:
        if r.get("mesh") != "16x16" or "skipped" in r:
            continue
        a = analyze_record(r)
        bound = max(a["compute_s"], a["memory_s"], a["collective_s"])
        row = (f"| {a['arch']} | {a['shape']} | {a['compute_s']:.2f} "
               f"| {a['memory_s']:.2f} | {a['collective_s']:.2f} "
               f"| {a['dominant']} | {a['useful_flops_ratio']:.2f} "
               f"| {a['roofline_fraction']:.4f} |")
        if base:
            b = base_map.get((a["arch"], a["shape"]))
            if b:
                b_bound = max(b["compute_s"], b["memory_s"],
                              b["collective_s"])
                row += f" {b_bound / bound:.2f}x |"
            else:
                row += " — |"
        out.append(row)
    return "\n".join(out)


if __name__ == "__main__":
    base = load("dryrun_all.jsonl")
    opt = load("dryrun_optimized.jsonl")
    with open(os.path.join(RES, "tables.md"), "w") as f:
        f.write("## Dry-run (baseline sweep)\n\n")
        f.write(dryrun_table(base))
        f.write("\n\n## Roofline (baseline, single-pod)\n\n")
        f.write(roofline_table(base))
        if opt:
            f.write("\n\n## Dry-run (optimized sweep)\n\n")
            f.write(dryrun_table(opt))
            f.write("\n\n## Roofline (optimized, single-pod; last column = "
                    "baseline bound / optimized bound)\n\n")
            f.write(roofline_table(opt, base))
    print("wrote results/tables.md")
