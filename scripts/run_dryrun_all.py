#!/usr/bin/env python
"""Drive the full dry-run sweep: one subprocess per (arch x shape x mesh)
cell (isolation against OOM / crash; resumable).  Appends JSON lines to
results/dryrun_all.jsonl and skips cells already present.

``--backend`` exports ``REPRO_SIM_BACKEND`` to every subprocess, so any
simulation the cells consult (autotune what-ifs, dispatch planning) runs on
the chosen engine without threading a flag through each layer."""

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import ARCH_NAMES, SHAPES  # noqa: E402
from repro.sim.backends import BACKEND_ENV, backend_names  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   os.environ.get("DRYRUN_OUT", "dryrun_all.jsonl"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None, choices=backend_names(),
                    help="simulation backend for the spawned cells")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    done = set()
    if os.path.exists(OUT):
        with open(OUT) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
    env = dict(os.environ, PYTHONPATH="src")
    if args.backend:
        env[BACKEND_ENV] = args.backend
    cells = [(a, s, m) for a in ARCH_NAMES for s in SHAPES
             for m in ("single", "multi")]
    for arch, shape, mesh in cells:
        mesh_name = "2x16x16" if mesh == "multi" else "16x16"
        if (arch, shape, mesh_name) in done:
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=os.path.join(os.path.dirname(__file__), ".."),
                           timeout=1800)
        line = None
        for ln in r.stdout.strip().splitlines():
            if ln.startswith("{"):
                line = ln
        if line is None:
            line = json.dumps({"arch": arch, "shape": shape,
                               "mesh": mesh_name,
                               "error": (r.stderr or "no output")[-400:]})
        with open(OUT, "a") as f:
            f.write(line + "\n")
        print(line[:160], flush=True)


if __name__ == "__main__":
    main()
