#!/usr/bin/env python
"""Drive the full dry-run sweep: one subprocess per (arch x shape x mesh)
cell (isolation against OOM / crash; resumable).  Appends JSON lines to
results/dryrun_all.jsonl and skips cells already present.

``--backend`` exports ``REPRO_SIM_BACKEND`` to every subprocess, so any
simulation the cells consult (autotune what-ifs, dispatch planning) runs on
the chosen engine without threading a flag through each layer.

``--campaign`` switches to the Fig. 5 factorial sweep instead: every
(application x system) cell runs through the lockstep ``run_campaign``
engine on the chosen backend, appending one resumable JSON line per cell to
results/campaign_all.jsonl."""

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import ARCH_NAMES, SHAPES  # noqa: E402
from repro.sim.backends import BACKEND_ENV, backend_names  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   os.environ.get("DRYRUN_OUT", "dryrun_all.jsonl"))
CAMPAIGN_OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                            "campaign_all.jsonl")


def run_campaign_sweep(backend, selector_backend, T, reps):
    """Fig. 5 cells through the lockstep replay engine, one JSON line
    appended per (app, system) cell as soon as it completes (a crash loses
    at most the cell in flight).  Cells already present *with the same
    (T, reps, backends)* are skipped, so smoke runs and full sweeps can
    share one results file without masking each other."""
    from repro.sim import APPLICATIONS, SYSTEMS, run_campaign

    bk = backend or os.environ.get(BACKEND_ENV, "python")
    params = {"T": T, "reps": reps, "backend": bk,
              "selector_backend": selector_backend}
    done = set()
    if os.path.exists(CAMPAIGN_OUT):
        with open(CAMPAIGN_OUT) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["app"], r["system"], r["T"], r["reps"],
                              r["backend"], r.get("selector_backend")))
                except Exception:
                    pass
    cells = [(a, s) for a in APPLICATIONS for s in SYSTEMS
             if (a, s, T, reps, bk, selector_backend) not in done]
    if not cells:
        print("campaign: all cells present")
        return
    for app, system in cells:
        cell = run_campaign([(app, system)], T=T, reps=reps, backend=backend,
                            selector_backend=selector_backend)[(app, system)]
        line = json.dumps({
            "app": app, "system": system, **params,
            "oracle_total": cell.oracle_total,
            "cov": cell.sweep.cov(),
            "degradation": {
                f"{sel}|{mode}|{reward or ''}": d
                for (sel, mode, reward), d in cell.degradation().items()},
        })
        with open(CAMPAIGN_OUT, "a") as f:
            f.write(line + "\n")
        print(line[:160], flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None, choices=backend_names(),
                    help="simulation backend for the spawned cells")
    ap.add_argument("--campaign", action="store_true",
                    help="run the Fig. 5 campaign sweep instead of the "
                         "dry-run grid")
    ap.add_argument("--selector-backend", default="python",
                    choices=backend_names(),
                    help="backend for the lockstep selector replays "
                         "(--campaign only; default python = exact "
                         "per-chunk telemetry for the adaptive algorithms)")
    ap.add_argument("--T", type=int, default=50,
                    help="campaign time-steps per cell (--campaign only)")
    ap.add_argument("--reps", type=int, default=2,
                    help="campaign portfolio reps (--campaign only)")
    args = ap.parse_args()
    if args.campaign:
        os.makedirs(os.path.dirname(CAMPAIGN_OUT), exist_ok=True)
        run_campaign_sweep(args.backend, args.selector_backend, args.T,
                           args.reps)
        return
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    done = set()
    if os.path.exists(OUT):
        with open(OUT) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
    env = dict(os.environ, PYTHONPATH="src")
    if args.backend:
        env[BACKEND_ENV] = args.backend
    cells = [(a, s, m) for a in ARCH_NAMES for s in SHAPES
             for m in ("single", "multi")]
    for arch, shape, mesh in cells:
        mesh_name = "2x16x16" if mesh == "multi" else "16x16"
        if (arch, shape, mesh_name) in done:
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=os.path.join(os.path.dirname(__file__), ".."),
                           timeout=1800)
        line = None
        for ln in r.stdout.strip().splitlines():
            if ln.startswith("{"):
                line = ln
        if line is None:
            line = json.dumps({"arch": arch, "shape": shape,
                               "mesh": mesh_name,
                               "error": (r.stderr or "no output")[-400:]})
        with open(OUT, "a") as f:
            f.write(line + "\n")
        print(line[:160], flush=True)


if __name__ == "__main__":
    main()
