"""repro — scheduling-algorithm selection for JAX/TPU (paper: 'A Comparative
Study of OpenMP Scheduling Algorithm Selection Strategies', CS.DC 2025)."""

__version__ = "1.0.0"
