from .manager import CheckpointManager
