"""Sharded, asynchronous, elastic checkpointing.

Design (scaled-down but structurally faithful to a multi-host manager):

* every array leaf is saved as its own ``.npy`` under a per-step directory —
  on a real pod each host writes only the shards it owns (here: the single
  process writes everything, preserving the layout);
* writes go to ``<step>.tmp`` and are atomically renamed — a preempted save
  can never corrupt the latest checkpoint (commit = directory rename);
* saves can run on a background thread (``async_save``); ``wait()`` joins;
* **elastic restore**: arrays are loaded as host numpy and re-placed with the
  *current* mesh's NamedSharding — restoring a 16x16 checkpoint onto a
  2x16x16 (or 1-device test) mesh is the normal path, not a special case;
* retention: keep the newest ``keep`` steps, GC the rest.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy's .npy format only carries built-in dtypes; custom 2-byte ml_dtypes
# (bfloat16, fp8) are stored as uint views and re-viewed on restore
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        # join any in-flight async save first: a failure on the background
        # thread must re-raise here, not vanish (and two writers must never
        # race on the step directories / GC)
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree)

    def async_save(self, step: int, tree) -> None:
        """Device->host copy happens synchronously (consistent snapshot);
        serialization + fsync + rename happen on a background thread.
        An exception raised by the background write is re-raised by the
        NEXT ``wait()`` / ``save()`` / ``async_save()`` call — callers that
        never join again would otherwise lose checkpoints silently."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                self._write(step, host_tree)
            except BaseException as e:   # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, host_tree) -> str:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for key, leaf in _flatten(host_tree):
            fn = key.replace("/", "__") + ".npy"
            arr = np.asarray(leaf)
            logical = str(arr.dtype)
            if logical in _VIEW_DTYPES:
                arr = arr.view(_VIEW_DTYPES[logical])
            np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
            manifest[key] = {"file": fn, "shape": list(arr.shape),
                             "dtype": logical}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "arrays": manifest,
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedSharding for elastic re-placement onto the current mesh."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["arrays"]
        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else None
        leaves = []
        for i, (key, leaf) in enumerate(flat_like):
            entry = manifest.get(key)
            if entry is None:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = np.load(os.path.join(d, entry["file"]))
            if entry["dtype"] in _VIEW_DTYPES:
                arr = arr.view(getattr(ml_dtypes, entry["dtype"]))
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {want}")
            if flat_sh is not None:
                leaves.append(jax.device_put(arr, flat_sh[i][1]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)
