"""repro.configs — assigned architectures (+ paper campaign config)."""

from importlib import import_module
from typing import Dict

from .base import (ModelConfig, ShapeConfig, SHAPES, applicable,
                   smoke_reduce)

_ARCH_MODULES = {
    "qwen3-32b": "qwen3_32b",
    "granite-8b": "granite_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "llama3.2-3b": "llama3_2_3b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-2.7b": "mamba2_2p7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "grok-1-314b": "grok_1_314b",
    "whisper-small": "whisper_small",
}

ARCH_NAMES = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choices: {ARCH_NAMES}")
    mod = import_module(f".{_ARCH_MODULES[arch]}", __name__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_NAMES}


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "applicable",
           "smoke_reduce", "ARCH_NAMES", "get_config", "all_configs"]
