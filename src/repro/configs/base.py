"""Config system: architectures and input shapes.

Every assigned architecture is a ``ModelConfig`` (exact dims from the
assignment table) plus a ``smoke()`` reduction of the same family for
CPU tests.  Shapes are the four assigned input-shape cells; ``applicable``
encodes the long_500k sub-quadratic skip rule (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple



@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qk_norm: bool = False
    mrope: bool = False           # qwen2-vl M-RoPE
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (Zamba2): one shared attention block applied every `attn_every`
    # SSM layers (shared parameters, Zamba-style)
    attn_every: int = 0
    # enc-dec (Whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0
    frontend: Optional[str] = None   # "audio" | "vision" stub
    sub_quadratic: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    # training memory policy for the big dry-run configs
    moment_dtype: str = "float32"
    remat: bool = True

    @property
    def ssm_nheads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_headdim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def n_params(self) -> int:
        """Total parameter count (embedding + layers + head)."""
        d, dff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        att = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.n_heads * self.head_dim * d
        mlp = 3 * d * dff
        norm = 2 * d
        if self.family == "dense":
            per_layer = att + mlp + norm
            return emb + head + self.n_layers * per_layer + d
        if self.family == "moe":
            expert_mlp = self.n_experts * 3 * d * dff
            router = d * self.n_experts
            per_layer = att + expert_mlp + router + norm
            return emb + head + self.n_layers * per_layer + d
        if self.family == "ssm":
            di, st = self.d_inner, self.ssm_state
            nh = self.ssm_nheads
            in_proj = d * (2 * di + 2 * st + nh)
            per_layer = in_proj + self.ssm_conv * (di + 2 * st) + di * d + nh + nh + d
            return emb + head + self.n_layers * per_layer + d
        if self.family == "hybrid":
            di, st = self.d_inner, self.ssm_state
            nh = self.ssm_nheads
            in_proj = d * (2 * di + 2 * st + nh)
            ssm_layer = in_proj + self.ssm_conv * (di + 2 * st) + di * d + nh + nh + d
            shared_attn = att + mlp + norm
            return emb + head + self.n_layers * ssm_layer + shared_attn + d
        if self.family == "encdec":
            enc_layer = att + mlp + norm
            dec_layer = att + att + mlp + 3 * d   # self + cross + mlp
            return (emb + head + self.encoder_layers * enc_layer
                    + self.n_layers * dec_layer + 2 * d)
        raise ValueError(self.family)

    def active_params(self) -> int:
        """Activated parameters per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params()
        d, dff = self.d_model, self.d_ff
        dense_share = self.n_params() - self.n_layers * self.n_experts * 3 * d * dff
        return dense_share + self.n_layers * self.experts_per_token * 3 * d * dff


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Shape-applicability rule. long_500k requires sub-quadratic mixing."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (O(L^2))"
    return True, ""


def smoke_reduce(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else 64,
        ssm_chunk=32,
        attn_every=1 if cfg.attn_every else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 64) if cfg.encoder_seq else 0,
        param_dtype="float32",
        moment_dtype="float32",
    )
