"""mamba2-2.7b — attention-free SSD [arXiv:2405.21060; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2,
    sub_quadratic=True, tie_embeddings=True, param_dtype="bfloat16")
