"""qwen2-vl-72b — VLM backbone, M-RoPE [arXiv:2409.12191; hf].

The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings merged into the token stream; the backbone (this config) applies
M-RoPE 3D rotary sections."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=29568, vocab_size=152064,
    head_dim=128, mrope=True, rope_theta=1e6, frontend="vision",
    param_dtype="bfloat16", moment_dtype="bfloat16")
