"""whisper-small — enc-dec audio backbone [arXiv:2212.04356; unverified].

Conv audio frontend is a STUB: input_specs() provides precomputed
log-mel frame embeddings (1500 frames) for the encoder."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=51865,
    head_dim=64, encoder_layers=12, encoder_seq=1500,
    frontend="audio", param_dtype="bfloat16")
