"""zamba2-7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242; unverified].

81 Mamba2 layers; one *shared* (parameter-tied) attention+MLP block applied
after every 9th SSM layer (9 applications; Zamba-style weight sharing)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab_size=32000,
    head_dim=112, ssm_state=64, ssm_headdim=64, ssm_expand=2,
    attn_every=9, sub_quadratic=True, param_dtype="bfloat16")
