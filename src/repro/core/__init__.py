"""repro.core — the paper's contribution: scheduling-algorithm portfolio and
automated (expert-, RL-based, and hybrid) selection through one structured
policy API (``Observation`` / ``Decision`` / ``SelectionPolicy``)."""

from .portfolio import (ALGORITHM_NAMES, N_ALGORITHMS, ADAPTIVE_SET,
                        ChunkAlgorithm, alg_index, exp_chunk,
                        apply_chunk_floor, make_algorithm, make_portfolio)
from .metrics import (percent_load_imbalance, execution_imbalance,
                      coefficient_of_variation)
from .rewards import (RewardTracker, REWARD_POSITIVE, REWARD_NEUTRAL,
                      REWARD_NEGATIVE, REWARD_TYPES)
from .api import (Observation, Decision, SelectionPolicy, register_reward,
                  get_reward, reward_names)
from .agents import QLearnAgent, SarsaAgent, explore_first_sequence
from .drift import PageHinkley
from .selectors import (FixedPolicy, OraclePolicy, RandomPolicy,
                        ExhaustivePolicy, ExpertPolicy, RLPolicy,
                        QLearnPolicy, SarsaPolicy, HybridPolicy,
                        make_policy, POLICY_NAMES,
                        # deprecated scalar shims
                        Selector, FixedSel, OracleSel, RandomSel,
                        ExhaustiveSel, ExpertSel, QLearnSel, SarsaSel,
                        make_selector, SELECTOR_NAMES)
from .simpolicy import (Candidate, SimAssistedHybrid, SimPolicy,
                        SimUnavailable, SIM_POLICY_ENV, SIM_POLICY_NAMES,
                        is_sim_policy, resolve_sim_policy)
from .learned import (DistilledLadder, FEATURE_NAMES, LEARNED_POLICY_NAMES,
                      LEARNED_STATE_ENV, LearnedHybrid, LearnedPolicy,
                      LoopFeaturizer, N_FEATURES, distill_ladder,
                      is_learned_policy, make_learned_state,
                      resolve_default_state, set_default_state)
from .service import RegionInstance, SelectionService
from .persistence import (AgentStatsLogger, save_agent, load_agent,
                          save_policy_state, load_policy_state,
                          system_fingerprint, warm_start)

__all__ = [
    "ALGORITHM_NAMES", "N_ALGORITHMS", "ADAPTIVE_SET", "ChunkAlgorithm",
    "alg_index", "exp_chunk", "apply_chunk_floor", "make_algorithm",
    "make_portfolio", "percent_load_imbalance", "execution_imbalance",
    "coefficient_of_variation", "RewardTracker", "REWARD_POSITIVE",
    "REWARD_NEUTRAL", "REWARD_NEGATIVE", "REWARD_TYPES",
    # structured selection API
    "Observation", "Decision", "SelectionPolicy", "register_reward",
    "get_reward", "reward_names", "FixedPolicy", "OraclePolicy",
    "RandomPolicy", "ExhaustivePolicy", "ExpertPolicy", "RLPolicy",
    "QLearnPolicy", "SarsaPolicy", "HybridPolicy", "make_policy",
    "POLICY_NAMES", "RegionInstance", "SelectionService",
    # simulation-assisted selection (SimAS-style)
    "Candidate", "SimPolicy", "SimAssistedHybrid", "SimUnavailable",
    "SIM_POLICY_ENV", "SIM_POLICY_NAMES", "is_sim_policy",
    "resolve_sim_policy", "PageHinkley",
    # offline-trained learned selection
    "LearnedPolicy", "LearnedHybrid", "LoopFeaturizer", "DistilledLadder",
    "distill_ladder", "FEATURE_NAMES", "N_FEATURES", "LEARNED_POLICY_NAMES",
    "LEARNED_STATE_ENV", "is_learned_policy", "make_learned_state",
    "set_default_state", "resolve_default_state",
    # agents + persistence
    "QLearnAgent", "SarsaAgent", "explore_first_sequence",
    "AgentStatsLogger", "save_agent", "load_agent", "save_policy_state",
    "load_policy_state", "system_fingerprint", "warm_start",
    # deprecated scalar shims
    "Selector", "FixedSel", "OracleSel", "RandomSel", "ExhaustiveSel",
    "ExpertSel", "QLearnSel", "SarsaSel", "make_selector", "SELECTOR_NAMES",
]
