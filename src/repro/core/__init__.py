"""repro.core — the paper's contribution: scheduling-algorithm portfolio and
automated (expert- and RL-based) selection methods."""

from .portfolio import (ALGORITHM_NAMES, N_ALGORITHMS, ADAPTIVE_SET,
                        ChunkAlgorithm, alg_index, exp_chunk,
                        apply_chunk_floor, make_algorithm, make_portfolio)
from .metrics import (percent_load_imbalance, execution_imbalance,
                      coefficient_of_variation)
from .rewards import (RewardTracker, REWARD_POSITIVE, REWARD_NEUTRAL,
                      REWARD_NEGATIVE, REWARD_TYPES)
from .agents import QLearnAgent, SarsaAgent, explore_first_sequence
from .selectors import (Selector, FixedSel, OracleSel, RandomSel,
                        ExhaustiveSel, ExpertSel, QLearnSel, SarsaSel,
                        make_selector, SELECTOR_NAMES)
from .service import SelectionService
from .persistence import (AgentStatsLogger, save_agent, load_agent,
                          warm_start)

__all__ = [
    "ALGORITHM_NAMES", "N_ALGORITHMS", "ADAPTIVE_SET", "ChunkAlgorithm",
    "alg_index", "exp_chunk", "apply_chunk_floor", "make_algorithm",
    "make_portfolio", "percent_load_imbalance", "execution_imbalance",
    "coefficient_of_variation", "RewardTracker", "REWARD_POSITIVE",
    "REWARD_NEUTRAL", "REWARD_NEGATIVE", "REWARD_TYPES", "QLearnAgent",
    "SarsaAgent", "explore_first_sequence", "Selector", "FixedSel",
    "OracleSel", "RandomSel", "ExhaustiveSel", "ExpertSel", "QLearnSel",
    "SarsaSel", "make_selector", "SELECTOR_NAMES", "SelectionService",
    "AgentStatsLogger", "save_agent", "load_agent", "warm_start",
]
