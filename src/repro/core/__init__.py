"""repro.core — the paper's contribution: scheduling-algorithm portfolio and
automated (expert-, RL-based, and hybrid) selection through one structured
policy API (``Observation`` / ``Decision`` / ``SelectionPolicy``)."""

from .portfolio import (ALGORITHM_NAMES, N_ALGORITHMS, ADAPTIVE_SET,
                        ChunkAlgorithm, alg_index, exp_chunk,
                        apply_chunk_floor, make_algorithm, make_portfolio)
from .metrics import (percent_load_imbalance, execution_imbalance,
                      coefficient_of_variation)
from .rewards import (RewardTracker, REWARD_POSITIVE, REWARD_NEUTRAL,
                      REWARD_NEGATIVE, REWARD_TYPES)
from .api import (Observation, Decision, SelectionPolicy, register_reward,
                  get_reward, reward_names)
from .agents import QLearnAgent, SarsaAgent, explore_first_sequence
from .drift import PageHinkley
from .selectors import (FixedPolicy, OraclePolicy, RandomPolicy,
                        ExhaustivePolicy, ExpertPolicy, RLPolicy,
                        QLearnPolicy, SarsaPolicy, HybridPolicy,
                        make_policy, POLICY_NAMES,
                        # deprecated scalar shims
                        Selector, FixedSel, OracleSel, RandomSel,
                        ExhaustiveSel, ExpertSel, QLearnSel, SarsaSel,
                        make_selector, SELECTOR_NAMES)
from .simpolicy import (Candidate, SimAssistedHybrid, SimPolicy,
                        SimUnavailable, SIM_POLICY_ENV, SIM_POLICY_NAMES,
                        is_sim_policy, resolve_sim_policy)
from .service import RegionInstance, SelectionService
from .persistence import (AgentStatsLogger, save_agent, load_agent,
                          save_policy_state, load_policy_state,
                          system_fingerprint, warm_start)

__all__ = [
    "ALGORITHM_NAMES", "N_ALGORITHMS", "ADAPTIVE_SET", "ChunkAlgorithm",
    "alg_index", "exp_chunk", "apply_chunk_floor", "make_algorithm",
    "make_portfolio", "percent_load_imbalance", "execution_imbalance",
    "coefficient_of_variation", "RewardTracker", "REWARD_POSITIVE",
    "REWARD_NEUTRAL", "REWARD_NEGATIVE", "REWARD_TYPES",
    # structured selection API
    "Observation", "Decision", "SelectionPolicy", "register_reward",
    "get_reward", "reward_names", "FixedPolicy", "OraclePolicy",
    "RandomPolicy", "ExhaustivePolicy", "ExpertPolicy", "RLPolicy",
    "QLearnPolicy", "SarsaPolicy", "HybridPolicy", "make_policy",
    "POLICY_NAMES", "RegionInstance", "SelectionService",
    # simulation-assisted selection (SimAS-style)
    "Candidate", "SimPolicy", "SimAssistedHybrid", "SimUnavailable",
    "SIM_POLICY_ENV", "SIM_POLICY_NAMES", "is_sim_policy",
    "resolve_sim_policy", "PageHinkley",
    # agents + persistence
    "QLearnAgent", "SarsaAgent", "explore_first_sequence",
    "AgentStatsLogger", "save_agent", "load_agent", "save_policy_state",
    "load_policy_state", "system_fingerprint", "warm_start",
    # deprecated scalar shims
    "Selector", "FixedSel", "OracleSel", "RandomSel", "ExhaustiveSel",
    "ExpertSel", "QLearnSel", "SarsaSel", "make_selector", "SELECTOR_NAMES",
]
