"""Tabular model-free RL agents (paper §3.4-3.5): Q-Learn and SARSA.

State  = currently selected scheduling algorithm (12 states)
Action = next scheduling algorithm            (12 actions)
→ 144 state-action pairs, Q-table initialized to 0.

Explore-first policy: before exploiting, visit *every* (state, action)
transition once — an Eulerian circuit over the complete digraph with
self-loops on 12 nodes (144 edges → 144 learning loop-instances, i.e. 28.8 %
of a 500-step run, exactly the paper's figure).

Updates (Eqs. 9-10):

    SARSA:   Q(s,a) += alpha * (r + gamma * Q(s',a')        - Q(s,a))
    Q-Learn: Q(s,a) += alpha * (r + gamma * max_a' Q(s',a') - Q(s,a))

alpha = gamma = 0.5 by default; alpha decays by ``alpha_decay`` after the
learning phase (KMP_RL_ALPHA_DECAY = 0.05).  The paper does not specify the
decay operator; we default to the subtractive reading with a floor, and make
it configurable (see DESIGN.md §8).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .portfolio import N_ALGORITHMS
from .rewards import RewardTracker


def explore_first_sequence(n: int = N_ALGORITHMS, start: int = 0) -> List[int]:
    """Eulerian circuit on the complete digraph with self-loops on ``n`` nodes.

    Returns the sequence of *actions* (length n*n) such that, starting from
    ``start``, every ordered pair (state, action) — including self-pairs — is
    visited exactly once.  Hierholzer's algorithm; deterministic.
    """
    # remaining out-edges per node, popped in descending order so that the
    # walk tends to return to the start node last.
    out = {u: list(range(n)) for u in range(n)}
    stack = [start]
    circuit: List[int] = []
    while stack:
        u = stack[-1]
        if out[u]:
            v = out[u].pop()
            stack.append(v)
        else:
            circuit.append(stack.pop())
    circuit.reverse()          # node sequence of length n*n + 1, starts at `start`
    assert circuit[0] == start and len(circuit) == n * n + 1
    return circuit[1:]         # the actions taken from each successive state


@dataclass
class TabularAgent:
    """Shared machinery for Q-Learn / SARSA over the portfolio."""

    n_actions: int = N_ALGORITHMS
    alpha: float = 0.5
    gamma: float = 0.5
    alpha_decay: float = 0.05
    alpha_min: float = 0.0
    decay_mode: str = "subtractive"  # or "multiplicative"
    reward: RewardTracker = field(default_factory=RewardTracker)
    initial_state: int = 0

    def __post_init__(self) -> None:
        self.q = np.zeros((self.n_actions, self.n_actions), dtype=np.float64)
        self.state = self.initial_state
        self._explore = explore_first_sequence(self.n_actions,
                                               start=self.initial_state)
        self._t = 0  # loop-instance counter

    # -- policy -------------------------------------------------------------
    @property
    def learning(self) -> bool:
        return self._t < len(self._explore)

    @property
    def learning_steps(self) -> int:
        return len(self._explore)

    def select(self) -> int:
        """Action for the next loop instance."""
        if self.learning:
            return self._explore[self._t]
        return self._greedy(self.state)

    def _greedy(self, s: int) -> int:
        row = self.q[s]
        return int(np.argmax(row))  # first max wins ties (portfolio order)

    # -- learning -------------------------------------------------------------
    def observe(self, action: int, x: float) -> None:
        """Reward observation ``x`` (LT seconds or LIB %) for the instance just
        executed with ``action``; performs the TD update and advances state."""
        r = self.reward.reward(x)
        s, a = self.state, action
        s_next = action  # the executed algorithm becomes the new state
        target = r + self.gamma * self._bootstrap(s_next)
        self.q[s, a] += self.alpha * (target - self.q[s, a])
        self.state = s_next
        was_learning = self.learning
        self._t += 1
        if not was_learning and self.alpha_decay > 0.0:
            if self.decay_mode == "subtractive":
                self.alpha = max(self.alpha_min, self.alpha - self.alpha_decay)
            else:
                self.alpha = max(self.alpha_min,
                                 self.alpha * (1.0 - self.alpha_decay))

    def _bootstrap(self, s_next: int) -> float:  # pragma: no cover
        raise NotImplementedError


class QLearnAgent(TabularAgent):
    """Eq. 10 — off-policy: bootstrap with max_a' Q(s', a')."""

    def _bootstrap(self, s_next: int) -> float:
        return float(self.q[s_next].max())


class SarsaAgent(TabularAgent):
    """Eq. 9 — on-policy: bootstrap with Q(s', a') for the action the current
    policy would take in s' (greedy / next explore-first action)."""

    def _bootstrap(self, s_next: int) -> float:
        t_next = self._t + 1
        if t_next < len(self._explore):
            a_next = self._explore[t_next]
        else:
            a_next = self._greedy(s_next)
        return float(self.q[s_next, a_next])
