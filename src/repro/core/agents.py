"""Tabular model-free RL agents (paper §3.4-3.5): Q-Learn and SARSA.

State  = currently selected scheduling algorithm (12 states)
Action = next scheduling algorithm            (12 actions)
→ 144 state-action pairs, Q-table initialized to 0.

Explore-first policy: before exploiting, visit *every* (state, action)
transition once — an Eulerian circuit over the complete digraph with
self-loops on 12 nodes (144 edges → 144 learning loop-instances, i.e. 28.8 %
of a 500-step run, exactly the paper's figure).

Updates (Eqs. 9-10):

    SARSA:   Q(s,a) += alpha * (r + gamma * Q(s',a')        - Q(s,a))
    Q-Learn: Q(s,a) += alpha * (r + gamma * max_a' Q(s',a') - Q(s,a))

alpha = gamma = 0.5 by default; alpha decays by ``alpha_decay`` after the
learning phase (KMP_RL_ALPHA_DECAY = 0.05).  The paper does not specify the
decay operator; we default to the subtractive reading with a floor, and make
it configurable (see DESIGN.md §8).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np

from .portfolio import N_ALGORITHMS
from .rewards import RewardTracker


def explore_first_sequence(n: int = N_ALGORITHMS, start: int = 0) -> List[int]:
    """Eulerian circuit on the complete digraph with self-loops on ``n`` nodes.

    Returns the sequence of *actions* (length n*n) such that, starting from
    ``start``, every ordered pair (state, action) — including self-pairs — is
    visited exactly once.  Hierholzer's algorithm; deterministic.
    """
    # remaining out-edges per node, popped in descending order so that the
    # walk tends to return to the start node last.
    out = {u: list(range(n)) for u in range(n)}
    stack = [start]
    circuit: List[int] = []
    while stack:
        u = stack[-1]
        if out[u]:
            v = out[u].pop()
            stack.append(v)
        else:
            circuit.append(stack.pop())
    circuit.reverse()          # node sequence of length n*n + 1, starts at `start`
    assert circuit[0] == start and len(circuit) == n * n + 1
    return circuit[1:]         # the actions taken from each successive state


@dataclass
class TabularAgent:
    """Shared machinery for Q-Learn / SARSA over the portfolio."""

    n_actions: int = N_ALGORITHMS
    alpha: float = 0.5
    gamma: float = 0.5
    alpha_decay: float = 0.05
    alpha_min: float = 0.0
    decay_mode: str = "subtractive"  # or "multiplicative"
    reward: RewardTracker = field(default_factory=RewardTracker)
    initial_state: int = 0

    def __post_init__(self) -> None:
        self.q = np.zeros((self.n_actions, self.n_actions), dtype=np.float64)
        self.state = self.initial_state
        self._explore = explore_first_sequence(self.n_actions,
                                               start=self.initial_state)
        self._t = 0  # loop-instance counter

    # -- policy -------------------------------------------------------------
    @property
    def learning(self) -> bool:
        return self._t < len(self._explore)

    @property
    def learning_steps(self) -> int:
        return len(self._explore)

    def select(self) -> int:
        """Action for the next loop instance."""
        if self.learning:
            return self._explore[self._t]
        return self._greedy(self.state)

    def _greedy(self, s: int) -> int:
        row = self.q[s]
        return int(np.argmax(row))  # first max wins ties (portfolio order)

    # -- learning -------------------------------------------------------------
    def observe(self, action: int, x: float) -> None:
        """Reward observation ``x`` (LT seconds or LIB %) for the instance just
        executed with ``action``; performs the TD update and advances state."""
        r = self.reward.reward(x)
        s, a = self.state, action
        s_next = action  # the executed algorithm becomes the new state
        target = r + self.gamma * self._bootstrap(s_next)
        self.q[s, a] += self.alpha * (target - self.q[s, a])
        self.state = s_next
        was_learning = self.learning
        self._t += 1
        if not was_learning and self.alpha_decay > 0.0:
            if self.decay_mode == "subtractive":
                self.alpha = max(self.alpha_min, self.alpha - self.alpha_decay)
            else:
                self.alpha = max(self.alpha_min,
                                 self.alpha * (1.0 - self.alpha_decay))

    def _bootstrap(self, s_next: int) -> float:  # pragma: no cover
        raise NotImplementedError

    # -- persistence (paper §5 warm start) ------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable snapshot: Q-table, reward extrema, position."""
        lo, hi = self.reward.extrema
        return {
            "kind": type(self).__name__,
            "n_actions": self.n_actions,
            "alpha": self.alpha, "gamma": self.gamma,
            "alpha_decay": self.alpha_decay,
            "initial_state": int(self.initial_state),
            "state": int(self.state),
            "instances": self._t,
            "q": np.asarray(self.q).tolist(),
            "reward_min": None if not np.isfinite(lo) else lo,
            "reward_max": None if not np.isfinite(hi) else hi,
            "reward_count": self.reward.count,
        }

    def load_state_dict(self, rec: dict, *, skip_learning: bool = True
                        ) -> None:
        """Restore a ``state_dict`` snapshot.

        With ``skip_learning`` (the paper-§5 warm start) the agent resumes
        at the snapshot's instance count: a fully-trained snapshot skips the
        whole explore-first phase (28.8 % cost → 0), while a snapshot saved
        *mid-learning* resumes exploration where it stopped rather than
        freezing a near-empty Q-table into greedy exploitation forever.
        With ``skip_learning=False`` the explore-first phase is replayed
        from scratch over the restored table."""
        # validate everything into locals first: a truncated/hand-edited
        # record must leave the agent untouched, not half-restored
        q = np.asarray(rec["q"], dtype=np.float64)
        if q.shape != self.q.shape:
            raise ValueError(f"stored Q-table shape {q.shape} does not match "
                             f"agent shape {self.q.shape}")
        state = int(rec["state"])
        alpha = float(rec["alpha"])
        t = int(rec.get("instances", len(self._explore))) if skip_learning \
            else 0
        # the explore-first Eulerian circuit depends on the start node; a
        # mid-learning snapshot must resume on the circuit it was saved on
        initial_state = int(rec.get("initial_state", self.initial_state))
        reward_min = rec.get("reward_min")
        reward_max = rec.get("reward_max") if reward_min is not None else None
        reward_count = int(rec.get("reward_count", 1))

        self.q = q
        self.state = state
        self.alpha = alpha
        if initial_state != self.initial_state:
            self.initial_state = initial_state
            self._explore = explore_first_sequence(self.n_actions,
                                                   start=initial_state)
        if reward_min is not None:
            self.reward._min = reward_min
            self.reward._max = reward_max
            self.reward.count = reward_count
        self._t = t


class QLearnAgent(TabularAgent):
    """Eq. 10 — off-policy: bootstrap with max_a' Q(s', a')."""

    def _bootstrap(self, s_next: int) -> float:
        return float(self.q[s_next].max())


class SarsaAgent(TabularAgent):
    """Eq. 9 — on-policy: bootstrap with Q(s', a') for the action the current
    policy would take in s' (greedy / next explore-first action)."""

    def _bootstrap(self, s_next: int) -> float:
        t_next = self._t + 1
        if t_next < len(self._explore):
            a_next = self._explore[t_next]
        else:
            a_next = self._greedy(s_next)
        return float(self.q[s_next, a_next])
