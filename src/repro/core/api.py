"""Unified selection API — the structured surface every consumer speaks.

The paper's selection methods (§3.2-3.5) were originally driven through a
scalar ``select() / observe(action, loop_time, lib)`` protocol.  That
protocol cannot express the paper's two most valuable extensions:

* §6's *combination* of expert knowledge with RL-based learning (the fuzzy
  ladder seeding/bounding the Q-agent's exploration), and
* §5's Q-table persistence ("eliminating the learning phase of RL-based
  methods") flowing automatically through the per-region service.

This module is the redesign: three small, composable pieces.

``Observation``
    Everything a region instance can report back — loop time, percent load
    imbalance (Eq. 8), serving-centric signals (throughput, tail latency),
    raw per-PE finish times, and the instance index.

``Decision``
    What a policy hands the caller — the portfolio (or plan) index, an
    optional chunk parameter, a confidence score, and the policy phase
    (``expert`` / ``explore`` / ``exploit`` / ``monitor``).

``SelectionPolicy``
    The protocol: ``decide() -> Decision`` before the instance runs,
    ``feedback(decision, observation)`` after.  Policies optionally expose
    ``state_dict() / load_state_dict()`` so ``SelectionService`` can persist
    and warm-start them (paper §5).

Reward functions are pluggable through a registry: a *reward signal* is any
callable ``Observation -> float`` (lower is better) registered with
``@register_reward``.  The Eq. 11 three-level mapping (``RewardTracker``)
is applied on top of the extracted signal, so LT / LIB generalize to
composite and serving-centric rewards (p95 tail latency, LT+LIB blends,
negated throughput) without touching the agents.

Concrete policies live in :mod:`repro.core.selectors`; build them by name
with ``make_policy`` (re-exported here for convenience).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .metrics import percent_load_imbalance

__all__ = [
    "Observation", "Decision", "SelectionPolicy",
    "register_reward", "get_reward", "reward_names", "RewardFn",
    "make_policy",
]


# ---------------------------------------------------------------------------
# structured observations and decisions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Observation:
    """One region instance's measured outcome.

    Only ``loop_time`` is mandatory; every other field is an optional,
    richer signal a consumer may report (the serving dispatcher reports
    throughput/tail latency and raw per-replica times; the simulator
    reports loop time and LIB).
    """

    loop_time: float                      # seconds (LT, paper §3.5)
    lib: float = 0.0                      # percent load imbalance (Eq. 8)
    throughput: Optional[float] = None    # work units per second
    tail_latency: Optional[float] = None  # p95-style latency signal
    pe_times: Optional[Sequence[float]] = None  # per-PE finish times
    instance: int = -1                    # region instance index (-1 unknown)

    @classmethod
    def from_pe_times(cls, pe_times: Sequence[float], **kw) -> "Observation":
        """Build an observation from raw per-PE finish times: loop time is
        the makespan, LIB follows Eq. 8."""
        times = np.asarray(pe_times, dtype=np.float64)
        kw.setdefault("loop_time", float(times.max()))
        kw.setdefault("lib", percent_load_imbalance(times))
        kw.setdefault("tail_latency", float(np.percentile(times, 95)))
        return cls(pe_times=tuple(float(t) for t in times), **kw)

    @classmethod
    def batch(cls, loop_times, libs=None) -> List["Observation"]:
        """Vectorized construction from a batched backend result: one
        observation per lane instance, in array order (the lockstep replay's
        learn phase scatters these back to each lane's policy).  ``instance``
        is left unset (-1); the region service stamps its own counter when
        the observation is reported."""
        lt = np.asarray(loop_times, dtype=np.float64)
        lb = np.zeros_like(lt) if libs is None \
            else np.asarray(libs, dtype=np.float64)
        return [cls(loop_time=float(t), lib=float(b))
                for t, b in zip(lt, lb)]


@dataclass(frozen=True)
class Decision:
    """A policy's choice for the next region instance."""

    action: int                       # portfolio / plan index
    chunk_param: Optional[int] = None  # chunk parameter, None = caller's
    confidence: float = 1.0           # 0 (guessing) .. 1 (committed)
    phase: str = "exploit"            # expert | explore | exploit | monitor

    def with_instance_defaults(self, chunk_param: int) -> "Decision":
        if self.chunk_param is None:
            return replace(self, chunk_param=chunk_param)
        return self


# ---------------------------------------------------------------------------
# the policy protocol
# ---------------------------------------------------------------------------

class SelectionPolicy:
    """Protocol every selection method implements.

    ``decide`` is called before each region instance and must return a
    ``Decision``; ``feedback`` is called after, with the decision that was
    acted on and the measured ``Observation``.  ``decide`` must tolerate
    being called repeatedly without intervening feedback (callers may peek).
    """

    name: str = "base"

    #: instances the method spends learning before committing to a selection
    @property
    def learning_steps(self) -> int:
        return 0

    @property
    def learning(self) -> bool:
        return False

    def decide(self) -> Decision:  # pragma: no cover - abstract
        raise NotImplementedError

    def feedback(self, decision: Decision, obs: Observation) -> None:
        pass

    # -- persistence hooks (paper §5 warm start) ---------------------------
    def state_dict(self) -> Optional[dict]:
        """JSON-serializable state, or None if there is nothing worth
        persisting (stateless / purely reactive policies)."""
        return None

    def load_state_dict(self, state: dict, *,
                        skip_learning: bool = True) -> bool:
        """Restore from ``state_dict`` output; returns True if the policy
        warm-started (e.g. skipped its learning phase)."""
        return False


# ---------------------------------------------------------------------------
# reward-function registry
# ---------------------------------------------------------------------------

#: a reward signal maps a structured observation to a scalar, LOWER IS
#: BETTER (the Eq. 11 tracker rewards new minima).
RewardFn = Callable[[Observation], float]

_REWARDS: Dict[str, RewardFn] = {}


def register_reward(name: str) -> Callable[[RewardFn], RewardFn]:
    """Register ``fn(obs) -> float`` under ``name`` (case-insensitive).

        @register_reward("p99")
        def p99(obs):
            return obs.tail_latency if obs.tail_latency is not None \\
                else obs.loop_time
    """
    def deco(fn: RewardFn) -> RewardFn:
        _REWARDS[name.lower()] = fn
        return fn
    return deco


def get_reward(reward: "str | RewardFn") -> RewardFn:
    """Resolve a reward by registry name (or pass a callable through)."""
    if callable(reward):
        return reward
    try:
        return _REWARDS[reward.lower()]
    except KeyError:
        raise ValueError(
            f"unknown reward {reward!r}; registered: {reward_names()}"
        ) from None


def reward_names() -> List[str]:
    return sorted(_REWARDS)


@register_reward("LT")
def _reward_lt(obs: Observation) -> float:
    """Loop (step / wave / round) execution time — the paper's LT."""
    return obs.loop_time


@register_reward("LIB")
def _reward_lib(obs: Observation) -> float:
    """Percent load imbalance, Eq. 8 — the paper's LIB."""
    return obs.lib


@register_reward("p95")
def _reward_p95(obs: Observation) -> float:
    """Serving-centric: p95 tail latency, falling back to per-PE times and
    then to the loop time when the consumer reports nothing richer."""
    if obs.tail_latency is not None:
        return obs.tail_latency
    if obs.pe_times is not None and len(obs.pe_times):
        return float(np.percentile(np.asarray(obs.pe_times), 95))
    return obs.loop_time


@register_reward("throughput")
def _reward_throughput(obs: Observation) -> float:
    """Negated throughput (lower is better); falls back to loop time."""
    if obs.throughput is not None:
        return -obs.throughput
    return obs.loop_time


@register_reward("LT+LIB")
def _reward_lt_lib(obs: Observation) -> float:
    """Composite: loop time inflated by the imbalance fraction.  A 20 % LIB
    instance scores like a 1.2x slower balanced one, so the agent optimizes
    time while penalizing imbalance it could remove."""
    return obs.loop_time * (1.0 + obs.lib / 100.0)


# ---------------------------------------------------------------------------
# factory (implemented next to the concrete policies)
# ---------------------------------------------------------------------------

def make_policy(name: str, **kw) -> SelectionPolicy:
    """Build a policy by name: Fixed, RandomSel, ExhaustiveSel, ExpertSel,
    QLearn, SARSA, Hybrid, Oracle, plus the simulation-assisted SimPolicy /
    SimHybrid (which require a ``simulator=`` candidate pricer; see
    ``repro.core.simpolicy``).  See ``selectors.make_policy``."""
    from .selectors import make_policy as _impl
    return _impl(name, **kw)
