"""Online drift detection for the reactive sim-assisted policies.

A Page-Hinkley test over a scalar observation stream: the classic two-sided
CUSUM-style detector used by streaming-ML selection literature.  The reactive
policies (``repro.core.simpolicy``) feed it the log surrogate-fidelity ratio
(measured / predicted cost) or the live reward stream; a detection means the
world the simulator was calibrated against has shifted — re-price the
candidate set, re-prune the exploration window, drop stale corrections.

The detector is deliberately tiny and dependency-free: it keeps a running
mean and two cumulative deviation sums, flags when either drifts more than
``threshold`` past its historical extremum, and resets itself on detection
so repeated drifts are each reported once.
"""

from __future__ import annotations

__all__ = ["PageHinkley"]


class PageHinkley:
    """Two-sided Page-Hinkley change detector.

    ``update(x)`` returns True when the stream's mean has shifted (either
    direction) by more than ``delta`` per step accumulated past
    ``threshold``, after at least ``min_obs`` observations.  On detection
    the internal state resets, so the detector re-arms for the next shift.
    """

    def __init__(self, delta: float = 0.05, threshold: float = 0.6,
                 min_obs: int = 8):
        self.delta = float(delta)
        self.threshold = float(threshold)
        self.min_obs = int(min_obs)
        self.n_detections = 0
        self.reset()

    def reset(self) -> None:
        self._n = 0
        self._mean = 0.0
        self._sum_up = 0.0      # cumulative positive deviation (mean rose)
        self._min_up = 0.0
        self._sum_dn = 0.0      # cumulative negative deviation (mean fell)
        self._max_dn = 0.0

    def update(self, x: float) -> bool:
        x = float(x)
        self._n += 1
        self._mean += (x - self._mean) / self._n
        dev = x - self._mean
        self._sum_up += dev - self.delta
        self._min_up = min(self._min_up, self._sum_up)
        self._sum_dn += dev + self.delta
        self._max_dn = max(self._max_dn, self._sum_dn)
        if self._n < self.min_obs:
            return False
        if (self._sum_up - self._min_up > self.threshold
                or self._max_dn - self._sum_dn > self.threshold):
            self.n_detections += 1
            self.reset()
            return True
        return False
