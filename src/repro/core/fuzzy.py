"""Minimal Mamdani fuzzy-inference engine for ExpertSel (paper §3.2, [25]).

[25] uses two fuzzy systems: one mapping *absolute* (T_par, LIB) to an initial
scheduling-algorithm class, and one mapping *changes* (dT_par, dLIB) to a move
along the portfolio's adaptivity ladder.  The exact rule tables live in [25]
(not reprinted in this paper); the rules below encode the same published
expert knowledge: low imbalance → static/low-overhead, moderate → dynamic
non-adaptive, high → adaptive; worsening time after a switch → step back.

Triangular memberships, max-min inference, centroid defuzzification.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple


def tri(x: float, a: float, b: float, c: float) -> float:
    """Triangular membership with peak at b; shoulders clamp at the ends."""
    if x <= a:
        return 1.0 if a == b else 0.0
    if x >= c:
        return 1.0 if b == c else 0.0
    if x < b:
        return (x - a) / (b - a) if b > a else 1.0
    return (c - x) / (c - b) if c > b else 1.0


@dataclass
class FuzzyVar:
    name: str
    terms: Dict[str, Tuple[float, float, float]]

    def fuzzify(self, x: float) -> Dict[str, float]:
        return {t: tri(x, *abc) for t, abc in self.terms.items()}


class FuzzySystem:
    """rules: list of ((term_for_input0, term_for_input1, ...), output_center).
    Inference: firing = min of input memberships; output = centroid of
    firing-weighted output centers."""

    def __init__(self, inputs: Sequence[FuzzyVar],
                 rules: Sequence[Tuple[Tuple[str, ...], float]]):
        self.inputs = list(inputs)
        self.rules = list(rules)

    def infer(self, *xs: float) -> float:
        assert len(xs) == len(self.inputs)
        memberships = [v.fuzzify(x) for v, x in zip(self.inputs, xs)]
        num = den = 0.0
        for terms, center in self.rules:
            w = min(memberships[i][t] for i, t in enumerate(terms))
            num += w * center
            den += w
        return num / den if den > 0 else 0.0


# ---------------------------------------------------------------------------
# The two ExpertSel systems.  Output domain = portfolio index ladder
# [0 STATIC .. 11 mAF] (DLS_0..DLS_n axis of [25]).
# ---------------------------------------------------------------------------

LIB_VAR = FuzzyVar("LIB", {
    "low": (0.0, 0.0, 10.0),
    "moderate": (5.0, 20.0, 40.0),
    "high": (25.0, 100.0, 100.0),
})

TPAR_VAR = FuzzyVar("Tpar_rel", {     # T_par normalized by the first instance
    "low": (0.0, 0.0, 0.9),
    "moderate": (0.8, 1.0, 1.3),
    "high": (1.2, 3.0, 3.0),
})

# initial selection: LIB x Tpar -> algorithm-class center on the ladder
INITIAL_RULES = [
    (("low", "low"), 0.0),        # balanced & fast -> STATIC
    (("low", "moderate"), 0.0),
    (("low", "high"), 3.0),       # balanced but slow -> low-overhead dynamic
    (("moderate", "low"), 2.0),   # GSS
    (("moderate", "moderate"), 5.0),   # TSS/StaticSteal region
    (("moderate", "high"), 6.0),  # mFAC2
    (("high", "low"), 8.0),       # adaptive AWF
    (("high", "moderate"), 9.5),
    (("high", "high"), 11.0),     # severe imbalance -> mAF
]

DT_VAR = FuzzyVar("dT", {            # relative change of T_par (x_t/x_{t-1} - 1)
    "better": (-1.0, -1.0, -0.02),
    "same": (-0.05, 0.0, 0.05),
    "worse": (0.02, 1.0, 1.0),
})

DLIB_VAR = FuzzyVar("dLIB", {        # change of LIB in percentage points
    "down": (-100.0, -100.0, -1.0),
    "same": (-3.0, 0.0, 3.0),
    "up": (1.0, 100.0, 100.0),
})

# differential system: (dT, dLIB) -> ladder step in [-2, +2]
DIFF_RULES = [
    (("better", "down"), 0.0),    # improving: keep
    (("better", "same"), 0.0),
    (("better", "up"), 1.0),      # faster but imbalance creeping: adapt a bit
    (("same", "down"), 0.0),
    (("same", "same"), 0.0),
    (("same", "up"), 1.0),
    (("worse", "down"), -1.0),    # slower though balanced: overhead — step back
    (("worse", "same"), -1.0),
    (("worse", "up"), 2.0),       # slower and more imbalanced: jump to adaptive
]


def make_initial_system() -> FuzzySystem:
    return FuzzySystem([LIB_VAR, TPAR_VAR], INITIAL_RULES)


def make_diff_system() -> FuzzySystem:
    return FuzzySystem([DT_VAR, DLIB_VAR], DIFF_RULES)
