"""Pure-JAX chunk-size schedules for the scheduling portfolio.

``chunk_schedule(alg, N, P, chunk_param, max_chunks)`` returns the sequence of
chunk sizes a central work queue would deliver, computed entirely with
``jax.lax`` control flow so it can run under ``jit`` (e.g. inside the serving
dispatcher, the batched simulation backend, or on-device microbatch
planners).  Covered directly:

    STATIC(0)  SS(1)  GSS(2)  AutoLLVM(3)  TSS(4)  mFAC2(6)

The *adaptive* algorithms (AWF-B/C/D/E, mAF) depend on runtime telemetry and
live in the stateful host classes (``repro.core.portfolio``).  For them this
module provides **telemetry-free surrogate recurrences** — the exact chunk
sequence the host classes emit under constant per-iteration cost (weights
pinned at 1, variance 0):

    AWF-B/D(7,9)  batches of P chunks, each batch Cs = ceil(R/2P)
    AWF-C/E(8,10) Cs = ceil(R/2P) recomputed per request
    mAF(11)       first chunk min(100, N//P), then Cs = R//P

Property tests assert exact agreement with the host classes (constant
telemetry for the adaptive family).  ``staticsteal_schedule`` replays
StaticSteal's quantum serving + half-stealing event loop (noise-free,
uniform cost) and yields explicit (start, size, pe) triples, since stolen
chunks are not contiguous in iteration space.

Integer safety: with x64 disabled everything runs in int32.  All recurrences
are written to stay within int32 for any N <= 2**31 - 1 (STREAM's N = 2e9
included — the old TSS fixed-point state ``f0 * 1024`` silently wrapped
there).  Larger N requires ``jax_enable_x64``; ``chunk_schedule`` raises a
clear error instead of wrapping whenever N is concrete (a traced N inside
an enclosing jit cannot be validated — keep such callers within int32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .portfolio import DIRECT_CHUNK_SET

INT32_MAX = 2**31 - 1

#: algorithms chunk_schedule can emit (5 = StaticSteal has its own function)
SCHEDULABLE = frozenset({0, 1, 2, 3, 4, 6, 7, 8, 9, 10, 11})


def _ceil_div(a, b):
    return -(-a // b)


def _x64_enabled() -> bool:
    return bool(jax.config.read("jax_enable_x64"))


def chunk_schedule(alg: int, N, P, chunk_param, max_chunks: int = 4096):
    """Returns (sizes[max_chunks] int32, count int32).

    sizes[i] is the i-th delivered chunk; zeros beyond ``count``.  The floor
    semantics match ``apply_chunk_floor``: for STATIC/SS the user chunk sets
    the size directly; otherwise ``max(algorithm, max(1, chunk_param))``;
    always clipped by the remaining iterations.
    """
    if alg not in SCHEDULABLE:
        raise ValueError(
            f"chunk_schedule: unsupported algorithm {alg} "
            "(StaticSteal needs staticsteal_schedule)")
    if not _x64_enabled():
        try:
            n_val = int(N)          # ints, np scalars, concrete jnp arrays
        except Exception:           # traced inside jit: cannot validate
            n_val = None
        if n_val is not None and n_val > INT32_MAX:
            raise ValueError(
                f"chunk_schedule: N={N} exceeds int32; enable "
                "jax_enable_x64")
    return _chunk_schedule(alg, N, P, chunk_param, max_chunks)


@functools.partial(jax.jit, static_argnums=(0, 4))
def _chunk_schedule(alg: int, N, P, chunk_param, max_chunks: int):
    dtype = jnp.int64 if _x64_enabled() else jnp.int32
    N = jnp.asarray(N, dtype)
    P32 = jnp.asarray(P, jnp.int32)
    P = P32.astype(dtype)
    chunk_param = jnp.asarray(chunk_param, jnp.int32)
    one = jnp.asarray(1, dtype)
    zero = jnp.asarray(0, dtype)

    # --- per-algorithm precomputed constants (overflow-safe int arithmetic)
    if alg == 3:
        quantum = jnp.maximum(one, N // (P * P * 4))
    if alg == 4:
        # TSS (Eq. 4, f = N/(2P), l = 1): chunk_k = ceil(f - k*delta) with
        # delta = (f-1)/(A-1), i.e. ceil((N*Am1 - k*(N-2P)) / (2P*Am1)).
        # Exact rational form via split multiplies — no intermediate ever
        # leaves int32 for N <= 2**31-1 (the old ``f0 * 1024`` fixed point
        # wrapped on STREAM-scale loops).
        twoP = 2 * P
        tss_small = N < twoP               # f clamps to 1 -> unit chunks
        # A = ceil(2N/(f+1)) = 4P - floor(8P^2 / (N+2P))
        A = 4 * P - (8 * P * P) // (N + twoP)
        Am1 = jnp.maximum(one, A - 1)
        tss_D = twoP * Am1
        tss_a1, tss_b1 = N // tss_D, N % tss_D
        n2 = jnp.maximum(zero, N - twoP)
        tss_a2, tss_b2 = n2 // tss_D, n2 % tss_D
    if alg == 11:
        first_maf = jnp.minimum(jnp.asarray(100, dtype),
                                jnp.maximum(one, N // P))

    # --- initial recurrence state (s0, s1, s2); meaning depends on alg
    if alg == 6:
        # mFAC2: s0 = chunks left in batch, s1 = batch Cs, s2 = batch R
        init_state = (P, _ceil_div(N, 2 * P), N)
    else:
        # AWF-B/D start with s0 = 0 so their first request opens a batch
        init_state = (zero, zero, zero)

    direct = alg in DIRECT_CHUNK_SET

    def body(carry):
        sizes, count, remaining, s0, s1, s2 = carry
        if alg == 0:      # STATIC: ceil(N/P) (chunk_param handled by floor)
            raw = _ceil_div(N, P)
        elif alg == 1:    # SS
            raw = one
        elif alg == 2:    # GSS: ceil(R/P)
            raw = _ceil_div(remaining, P)
        elif alg == 3:    # AutoLLVM: guided/2P with quantum
            raw = jnp.maximum(quantum, _ceil_div(remaining, 2 * P))
        elif alg == 4:    # TSS: linear decrement, exact rational arithmetic
            k = jnp.minimum(count.astype(dtype), Am1)
            hi_part = tss_a1 * Am1 - k * tss_a2
            lo_part = tss_b1 * Am1 - k * tss_b2
            raw = hi_part + _ceil_div(lo_part, tss_D)
            raw = jnp.where(tss_small, one, jnp.maximum(one, raw))
        elif alg == 6:    # mFAC2: batches of P chunks, R_{j+1} = R_j - P*Cs_j
            new_batch = s0 <= 0
            s2 = jnp.where(new_batch, s2 - P * s1, s2)
            s1 = jnp.where(new_batch,
                           jnp.maximum(zero, _ceil_div(s2, 2 * P)), s1)
            s0 = jnp.where(new_batch, P - 1, s0 - 1)
            raw = jnp.maximum(one, s1)
        elif alg in (7, 9):   # AWF-B/D surrogate: batched factoring, w = 1
            new_batch = s0 <= 0
            s1 = jnp.where(new_batch, _ceil_div(remaining, 2 * P), s1)
            s0 = jnp.where(new_batch, P - 1, s0 - 1)
            raw = jnp.maximum(one, s1)
        elif alg in (8, 10):  # AWF-C/E surrogate: chunked factoring, w = 1
            raw = jnp.maximum(one, _ceil_div(remaining, 2 * P))
        elif alg == 11:   # mAF surrogate: mu constant, sigma 0 -> Cs = R/P
            raw = jnp.where(count == 0, first_maf,
                            jnp.maximum(one, remaining // P))
        if direct:
            c = jnp.where(chunk_param > 0, chunk_param.astype(dtype), raw)
        else:
            c = jnp.maximum(raw, jnp.maximum(1, chunk_param).astype(dtype))
        c = jnp.clip(c, 1, remaining)
        sizes = sizes.at[count].set(c.astype(jnp.int32))
        return sizes, count + 1, remaining - c, s0, s1, s2

    def cond(carry):
        _, count, remaining = carry[0], carry[1], carry[2]
        return (remaining > 0) & (count < max_chunks)

    sizes0 = jnp.zeros((max_chunks,), jnp.int32)
    out = jax.lax.while_loop(
        cond, body,
        (sizes0, jnp.asarray(0, jnp.int32), N) + init_state)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# StaticSteal: quantum serving + half-stealing, explicit (start, size, pe)
# ---------------------------------------------------------------------------

def staticsteal_schedule(N: int, P: int, chunk_param: int,
                         max_chunks: int = 4096, unit: float = 1.0,
                         h: float = 0.0, bcost: float = 0.0,
                         base_infl: float = 1.0, amp: float = 0.0,
                         c_loc: float = 64.0):
    """Replay StaticSteal's event loop (noise-free, per-iteration cost
    ``unit``) and return the delivered schedule.

    Returns ``(starts, sizes, pes, own, count)`` — all ``(max_chunks,)``
    buffers plus the live count.  ``own[i]`` marks chunks served from the
    PE's original range (no locality penalty).  Serve order replays the
    reference engine's argmin-over-available-times policy, so for uniform
    noise-free loops the sequence is *exactly* the Python engine's; for
    non-uniform or noisy loops it is the documented surrogate.

    Host-side wrapper: the P+1 range bounds are computed in float64 numpy
    (bit-identical to the engine) and passed into the jitted replay.
    """
    bounds = np.linspace(0, N, P + 1).round().astype(np.int64)
    if not _x64_enabled():
        if N > INT32_MAX:
            raise ValueError(
                f"staticsteal_schedule: N={N} exceeds int32; enable x64")
        bounds = bounds.astype(np.int32)
    return _staticsteal_replay(jnp.asarray(bounds), int(P), int(max_chunks),
                               max(1, int(chunk_param)), float(unit),
                               float(h), float(bcost), float(base_infl),
                               float(amp), float(c_loc))


@functools.partial(jax.jit, static_argnums=(1, 2))
def _staticsteal_replay(bounds, P: int, max_chunks: int, quantum,
                        unit, h, bcost, base_infl, amp, c_loc):
    dtype = bounds.dtype
    q = jnp.asarray(quantum, dtype)
    lo0 = bounds[:-1]
    hi0 = bounds[1:]
    N = bounds[-1]

    def body(carry):
        starts, sizes, pes, own, i, lo, hi, avail, remaining = carry
        pe = jnp.argmin(avail)
        need = lo[pe] >= hi[pe]
        # steal the back half of the richest victim (argmax = first richest,
        # matching the engine's max(); victim != pe whenever remaining > 0)
        victim = jnp.argmax(hi - lo)
        vh = hi[victim]
        half = (vh - lo[victim] + 1) // 2
        hi = hi.at[victim].set(jnp.where(need, vh - half, hi[victim]))
        lo_pe = jnp.where(need, vh - half, lo[pe])
        hi_pe = jnp.where(need, vh, hi[pe])
        lo = lo.at[pe].set(lo_pe)
        hi = hi.at[pe].set(hi_pe)
        c = jnp.minimum(q, hi_pe - lo_pe)
        is_own = (bounds[pe] <= lo_pe) & (lo_pe < bounds[pe + 1])
        locf = jnp.where(is_own, 1.0,
                         base_infl + amp * c_loc / (c.astype(jnp.float32)
                                                    + c_loc))
        dt = h + c.astype(jnp.float32) * unit * locf + bcost
        avail = avail.at[pe].add(dt)
        lo = lo.at[pe].add(c)
        starts = starts.at[i].set(lo_pe.astype(jnp.int32))
        sizes = sizes.at[i].set(c.astype(jnp.int32))
        pes = pes.at[i].set(pe.astype(jnp.int32))
        own = own.at[i].set(is_own)
        return starts, sizes, pes, own, i + 1, lo, hi, avail, remaining - c

    def cond(carry):
        i, remaining = carry[4], carry[8]
        return (remaining > 0) & (i < max_chunks)

    z = jnp.zeros((max_chunks,), jnp.int32)
    out = jax.lax.while_loop(
        cond, body,
        (z, z, z, jnp.zeros((max_chunks,), bool),
         jnp.asarray(0, jnp.int32), lo0, hi0,
         jnp.zeros((P,), jnp.float32), N))
    return out[0], out[1], out[2], out[3], out[4]


# ---------------------------------------------------------------------------
# weighted adaptive surrogates (the two-pass re-estimation's second pass)
# ---------------------------------------------------------------------------

#: adaptive algorithms the weighted surrogate covers (AWF-B/C/D/E, mAF)
ADAPTIVE_SCHEDULABLE = frozenset({7, 8, 9, 10, 11})


def weighted_adaptive_schedule(alg: int, N: int, P: int, chunk_param: int,
                               weights):
    """Chunk schedule of an adaptive algorithm at a *converged weight
    vector* — the second pass of the adaptive-surrogate scheme.

    The telemetry-free surrogates above pin every AWF/mAF weight at 1,
    which is exact only when per-PE rates are homogeneous.  Under PE
    slowdowns / heterogeneous systems the host classes converge to
    mean-1-normalized inverse time-per-iteration weights and deliver
    ``max(1, round(w[pe] * Cs))`` to each requesting PE; this emits that
    fixed-point sequence directly (simulate -> re-estimate weights from the
    perturbed rate table -> re-simulate), host-side in numpy.

    Because weighted chunk sizes are *per-PE*, the assignment is part of
    the schedule: returns ``(sizes int64, pes int32)`` with every chunk
    force-assigned to its requesting PE (fastest PEs request first within a
    batch — they drain their chunks soonest).  At ``weights == 1`` the
    sizes reduce to the unweighted surrogate recurrences.
    """
    if alg not in ADAPTIVE_SCHEDULABLE:
        raise ValueError(f"weighted_adaptive_schedule: {alg} is not an "
                         f"adaptive algorithm ({sorted(ADAPTIVE_SCHEDULABLE)})")
    w = np.asarray(weights, np.float64)
    if w.shape != (P,) or not np.all(w > 0):
        raise ValueError("weights must be a positive (P,) vector")
    order = [int(p) for p in np.argsort(-w, kind="stable")]
    floor = max(1, int(chunk_param))
    sizes: list = []
    pes: list = []
    R = int(N)
    if alg == 11:               # mAF: probe chunk, then Cs = R // P
        probe = min(100, max(1, R // P))
        c = min(R, max(probe, floor))
        sizes.append(c)
        pes.append(order[0])
        R -= c
        while R > 0:
            for p in order:
                if R <= 0:
                    break
                raw = max(1, int(round((R // P) * w[p])))
                c = min(R, max(raw, floor))
                sizes.append(c)
                pes.append(p)
                R -= c
    else:                       # AWF-B/D batched, AWF-C/E per-request
        per_request = alg in (8, 10)
        while R > 0:
            Cs = -(-R // (2 * P))
            for p in order:
                if R <= 0:
                    break
                if per_request:
                    Cs = -(-R // (2 * P))
                raw = max(1, int(round(Cs * w[p])))
                c = min(R, max(raw, floor))
                sizes.append(c)
                pes.append(p)
                R -= c
    return np.asarray(sizes, np.int64), np.asarray(pes, np.int32)
