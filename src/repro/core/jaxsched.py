"""Pure-JAX chunk-size schedules for the non-adaptive portfolio algorithms.

``chunk_schedule(alg, N, P, chunk_param, max_chunks)`` returns the sequence of
chunk sizes a central work queue would deliver, computed entirely with
``jax.lax`` control flow so it can run under ``jit`` (e.g. inside the serving
dispatcher or on-device microbatch planners).  Adaptive algorithms (AWF-*,
mAF) depend on runtime telemetry and live in the stateful host classes
(`repro.core.portfolio`); this module covers:

    STATIC(0)  SS(1)  GSS(2)  AutoLLVM(3)  TSS(4)  mFAC2(6)

Property tests assert exact agreement with the host classes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .portfolio import DIRECT_CHUNK_SET

# static upper bound on schedule length for lax.while_loop buffers


def _ceil_div(a, b):
    return -(-a // b)


@functools.partial(jax.jit, static_argnums=(0, 4))
def chunk_schedule(alg: int, N, P, chunk_param, max_chunks: int = 4096):
    """Returns (sizes[max_chunks] int32, count int32).

    sizes[i] is the i-th delivered chunk; zeros beyond ``count``.  The floor
    semantics match ``apply_chunk_floor``: for STATIC/SS the user chunk sets
    the size directly; otherwise ``max(algorithm, max(1, chunk_param))``;
    always clipped by the remaining iterations.
    """
    N = jnp.asarray(N, jnp.int64) if jax.config.read("jax_enable_x64") else jnp.asarray(N, jnp.int32)
    P = jnp.asarray(P, jnp.int32)
    chunk_param = jnp.asarray(chunk_param, jnp.int32)

    def compute(alg, state, remaining, i):
        """Raw (pre-floor) chunk for the i-th request; `state` carries the
        algorithm-specific recurrence (TSS next size ×1024, mFAC2 counter)."""
        if alg == 0:      # STATIC: ceil(N/P) (chunk_param handled by floor)
            raw = _ceil_div(N, P)
        elif alg == 1:    # SS
            raw = jnp.asarray(1, remaining.dtype)
        elif alg == 2:    # GSS: ceil(R/P)
            raw = _ceil_div(remaining, P)
        elif alg == 3:    # AutoLLVM: guided/2P with quantum
            quantum = jnp.maximum(1, N // (P * P * 4))
            raw = jnp.maximum(quantum, _ceil_div(remaining, 2 * P))
        elif alg == 4:    # TSS: linear decrement, fixed-point state
            raw = jnp.maximum(1, state // 1024)
        elif alg == 6:    # mFAC2: batch counter in state
            j = state // P

            def batch_cs(j):
                def body(_, carry):
                    R, cs = carry
                    cs = _ceil_div(R, 2 * P)
                    return R - P * cs, cs
                _, cs = jax.lax.fori_loop(0, j + 1, body, (N, jnp.asarray(0, N.dtype)))
                return cs
            raw = jnp.maximum(1, batch_cs(j))
        else:
            raise ValueError(f"chunk_schedule: unsupported algorithm {alg}")
        return raw

    def next_state(alg, state):
        if alg == 4:
            f = jnp.maximum(1.0, N.astype(jnp.float32) / (2.0 * P))
            l = 1.0
            A = jnp.ceil(2.0 * N.astype(jnp.float32) / (f + l))
            delta = jnp.where(A > 1, (f - l) / (A - 1), 0.0)
            dec = jnp.asarray(delta * 1024, state.dtype)
            return jnp.maximum(jnp.asarray(1024, state.dtype), state - dec)
        if alg == 6:
            return state + 1
        return state

    if alg == 4:
        f0 = jnp.maximum(1, _ceil_div(N, 2 * P))
        init_state = (f0 * 1024).astype(N.dtype)
    else:
        init_state = jnp.asarray(0, N.dtype)

    direct = alg in DIRECT_CHUNK_SET

    def body(carry):
        sizes, count, remaining, state = carry
        raw = compute(alg, state, remaining, count)
        if direct:
            c = jnp.where(chunk_param > 0, chunk_param.astype(raw.dtype), raw)
        else:
            c = jnp.maximum(raw, jnp.maximum(1, chunk_param).astype(raw.dtype))
        c = jnp.clip(c, 1, remaining)
        sizes = sizes.at[count].set(c.astype(jnp.int32))
        return sizes, count + 1, remaining - c, next_state(alg, state)

    def cond(carry):
        _, count, remaining, _ = carry
        return (remaining > 0) & (count < max_chunks)

    sizes0 = jnp.zeros((max_chunks,), jnp.int32)
    sizes, count, remaining, _ = jax.lax.while_loop(
        cond, body, (sizes0, jnp.asarray(0, jnp.int32), N, init_state))
    return sizes, count
