"""Learned selection: an offline-trained contextual-bandit policy.

Tabular Q-Learn cannot share knowledge across the millions of (region, app,
system) contexts the fleet layer creates — every new cell pays the paper's
28.8 % exploration cost again.  This module closes the ROADMAP "Learned
policies at scale" item: a small MLP maps structured context *features*
(loop profile shape, machine model, heterogeneity/perturbation telemetry,
step phase) to a predicted cost per portfolio algorithm, trained offline by
``repro.runtime.policy_trainer`` on lockstep-replay transition logs
(``repro.sim.translog`` — every transition carries all 12 counterfactual
prices, so this is a true bandit dataset and no off-policy correction is
needed).

Three consumers of the trained net:

``LearnedPolicy``
    A :class:`~repro.core.api.SelectionPolicy` whose ``decide()`` is one
    numpy MLP forward (microseconds — no per-decision what-if call like
    SimPolicy).  Needs a :class:`LoopFeaturizer` bound to the lane's machine
    model; the campaign wiring re-binds the current loop with
    ``set_context`` exactly like a SimPolicy lane's ``LoopWhatIf``.  Without
    weights or context it degrades to the expert fuzzy ladder.

``LearnedHybrid``
    :class:`~repro.core.selectors.HybridPolicy` whose RL exploration window
    is pre-pruned to the net's predicted top-k — the learned twin of
    ``SimAssistedHybrid``, without the per-build pricing call.

``distill_ladder``
    Extracts an interpretable threshold ladder (a depth-bounded decision
    tree over the named features) from the trained net, verified by
    ``benchmarks/bench_learned.py`` to stay within a bounded regret of its
    teacher on held-out cells.

Weights travel as JSON-serializable state dicts (``state_dict`` /
``load_state_dict``), so ``SelectionService(store_dir=...)`` warm starting
works unchanged, and a fleet can ship one trained policy to every region.
``REPRO_LEARNED_STATE`` may name a state JSON on disk to give every
``make_policy("Learned")`` call a default set of weights.
"""

from __future__ import annotations

import json
import math
import os
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .portfolio import N_ALGORITHMS
from .rewards import REWARD_POSITIVE
from .selectors import ExpertPolicy, HybridPolicy
from .api import Decision, Observation, SelectionPolicy, get_reward
from .simpolicy import SimUnavailable

__all__ = [
    "FEATURE_NAMES", "N_FEATURES", "FEATURE_VERSION", "LEARNED_STATE_ENV",
    "LoopFeaturizer", "LearnedPolicy", "LearnedHybrid",
    "mlp_forward", "params_from_state", "params_to_state",
    "make_learned_state", "set_default_state", "resolve_default_state",
    "is_learned_policy", "LEARNED_POLICY_NAMES",
    "DistilledLadder", "distill_ladder",
]

#: env var naming a LearnedPolicy state JSON on disk — the default weights
#: for every ``make_policy("Learned")`` call that passes none explicitly
LEARNED_STATE_ENV = "REPRO_LEARNED_STATE"

#: bump when the feature extraction changes incompatibly; stored states
#: carry it and a mismatch is a warm-start miss, never a silent mis-read
FEATURE_VERSION = 1

#: canonical registry spellings (``make_policy`` accepts these, lowercased)
LEARNED_POLICY_NAMES = ["Learned", "LearnedHybrid"]

_LEARNED_ALIASES = {
    "learned": "Learned", "learnedpolicy": "Learned",
    "learnedsel": "Learned", "mlp": "Learned",
    "learnedhybrid": "LearnedHybrid", "learned-hybrid": "LearnedHybrid",
    "learnedrl": "LearnedHybrid",
}

FEATURE_NAMES: Tuple[str, ...] = (
    # -- loop profile -------------------------------------------------------
    "log_n",          # log10 iteration count
    "log_total",      # log10 total work (s)
    "cov",            # c.o.v. of the per-bucket cost density (imbalance)
    "head_share",     # cost share of the costliest 5 % of buckets
    "memory_bound",
    "locality_sens",
    "log_c_loc",      # log2 reuse window
    # -- machine model ------------------------------------------------------
    "log_p",          # log2 PE count
    "log_h",          # log10 dispatch overhead
    "h_adaptive_mult",
    "h_serial_frac",
    "log_boundary",   # log10 per-chunk boundary cost
    "dyn_locality",
    "loc_amp",
    "noise_sigma",
    "log_jitter",
    "speed_spread",
    # -- heterogeneity + perturbation telemetry -----------------------------
    "pe_cov",         # c.o.v. of the effective per-PE speed multipliers
    "pe_max_ratio",   # log2(max/min) effective multiplier (capped)
    "pe_fail_frac",   # fraction of effectively dead PEs
    "log_sigma_scale",  # log2 of the perturbation's noise-sigma scale
    # -- decision context ---------------------------------------------------
    "chunk_norm",     # chunk_param * P / N (0 = default chunking)
    "phase",          # t / horizon, clipped to [0, 1]
)

N_FEATURES = len(FEATURE_NAMES)

_FEATURIZER_CACHE = 512      # per-profile feature rows kept (LRU)

#: an effective multiplier this large means "dead PE" for telemetry purposes
_FAIL_THRESHOLD = 100.0


def _log10(x: float) -> float:
    return math.log10(max(float(x), 1e-12))


def _density_stats(profile) -> Tuple[float, float]:
    """(cov, head_share) of the profile's per-bucket cost density."""
    grid = getattr(profile, "prefix_grid", None)
    if grid is None:
        return 0.0, 0.05        # uniform: head share is its 5 % baseline
    dens = np.maximum(np.diff(np.asarray(grid, np.float64)), 0.0)
    mean = float(dens.mean())
    if mean <= 0.0:
        return 0.0, 0.05
    cov = float(dens.std() / mean)
    k = max(1, len(dens) // 20)
    head = float(np.sort(dens)[-k:].sum() / max(dens.sum(), 1e-300))
    return cov, head


def _pe_telemetry(system, perturb) -> Tuple[float, float, float]:
    """(pe_cov, pe_max_ratio, pe_fail_frac) of the *effective* per-PE speed
    multipliers: persistent ``pe_speeds`` heterogeneity composed with any
    instance perturbation.  Computed locally (no backend import) so the
    featurizer stays dependency-free."""
    speeds = getattr(system, "pe_speeds", None)
    scale = None if speeds is None else np.asarray(speeds, np.float64)
    pscale = None if perturb is None else getattr(perturb, "pe_scale", None)
    if pscale is not None:
        ps = np.asarray(pscale, np.float64)
        scale = ps if scale is None else scale * ps
    if scale is None:
        return 0.0, 0.0, 0.0
    mean = float(scale.mean())
    cov = float(scale.std() / mean) if mean > 0 else 0.0
    ratio = float(scale.max() / max(scale.min(), 1e-12))
    fail = float((scale >= _FAIL_THRESHOLD).mean())
    return cov, min(math.log2(max(ratio, 1.0)), 16.0), fail


class LoopFeaturizer:
    """Context features for one campaign lane.

    Mirrors the :class:`~repro.sim.whatif.LoopWhatIf` surface the campaign
    already drives — ``set_context(profile, chunk_param, perturb)`` before
    each decision — so learned lanes slot into ``ReplayBatch`` through the
    exact call site sim-assisted lanes use.  ``features(phase)`` returns the
    (N_FEATURES,) float32 row for the bound context; no context bound raises
    :class:`~repro.core.simpolicy.SimUnavailable` (the policy then falls
    back to its expert ladder, like a SimPolicy without a pricer).
    """

    def __init__(self, system, horizon: int = 500):
        self.system = system
        self.horizon = max(1, int(horizon))
        self._profile = None
        self._chunk_param = 0
        self._perturb = None
        # system features never change for a lane: precompute once
        self._sys = np.array([
            math.log2(max(system.P, 1)),
            _log10(system.h),
            float(system.h_adaptive_mult),
            float(system.h_serial_frac),
            _log10(system.boundary_cost),
            float(system.dyn_locality),
            float(system.loc_amp),
            float(system.noise_sigma),
            _log10(system.jitter),
            float(system.speed_spread),
        ], dtype=np.float32)
        self._profile_cache: "Dict[tuple, np.ndarray]" = {}

    # -- the LoopWhatIf-shaped context surface ------------------------------
    def set_context(self, profile, chunk_param: int = 0,
                    perturb=None) -> None:
        """Bind the loop instance the next ``features`` calls are about."""
        self._profile = profile
        self._chunk_param = int(chunk_param)
        self._perturb = None if (perturb is not None
                                 and perturb.neutral) else perturb

    def _profile_row(self, p) -> np.ndarray:
        from ..sim.workloads import profile_digest
        key = profile_digest(p)
        row = self._profile_cache.get(key)
        if row is None:
            cov, head = _density_stats(p)
            row = np.array([
                _log10(p.N), _log10(p.total), cov, head,
                float(p.memory_bound), float(p.locality_sens),
                math.log2(max(p.c_loc, 1)),
            ], dtype=np.float32)
            if len(self._profile_cache) >= _FEATURIZER_CACHE:
                self._profile_cache.clear()     # cheap to refill
            self._profile_cache[key] = row
        return row

    def features(self, phase: float = 0.0) -> np.ndarray:
        """(N_FEATURES,) float32 feature row for the bound context."""
        if self._profile is None:
            raise SimUnavailable("LoopFeaturizer has no loop context bound")
        p = self._profile
        pe_cov, pe_ratio, pe_fail = _pe_telemetry(self.system, self._perturb)
        ss = 1.0 if self._perturb is None else float(
            getattr(self._perturb, "sigma_scale", 1.0))
        ctx = np.array([
            pe_cov, pe_ratio, pe_fail, math.log2(max(ss, 1e-6)),
            self._chunk_param * self.system.P / max(p.N, 1),
            min(max(float(phase), 0.0), 1.0),
        ], dtype=np.float32)
        return np.concatenate([self._profile_row(p), self._sys, ctx])


# ---------------------------------------------------------------------------
# numpy MLP forward (the deployed inference path — no JAX at decide() time)
# ---------------------------------------------------------------------------

def _gelu(x: np.ndarray) -> np.ndarray:
    """tanh-approximated GELU — the same approximation ``jax.nn.gelu``
    defaults to, so the deployed numpy forward matches training."""
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x ** 3)))


def mlp_forward(params: Dict[str, np.ndarray], x: np.ndarray) -> np.ndarray:
    """Predicted per-algorithm normalized log-cost.  ``x`` is (F,) or
    (B, F); returns (A,) / (B, A).  Architecture matches
    ``policy_trainer.forward``: feature layer + one ``gelu_mlp`` block."""
    h0 = _gelu(x @ params["w0"] + params["b0"])
    h1 = _gelu(h0 @ params["w1"] + params["b1"])
    return h1 @ params["w2"] + params["b2"]


def params_to_state(params: Dict[str, np.ndarray]) -> Dict[str, list]:
    return {k: np.asarray(v, np.float32).tolist() for k, v in params.items()}


def params_from_state(state: Dict[str, list]) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v, np.float32) for k, v in state.items()}


def _validate_params(params: Dict[str, np.ndarray], n_actions: int) -> None:
    for k in ("w0", "b0", "w1", "b1", "w2", "b2"):
        if k not in params:
            raise ValueError(f"learned state is missing array {k!r}")
    if params["w0"].shape[0] != N_FEATURES:
        raise ValueError(
            f"learned state expects {params['w0'].shape[0]} features, this "
            f"build extracts {N_FEATURES} (feature version skew)")
    if params["w2"].shape[1] != n_actions:
        raise ValueError(
            f"learned state predicts {params['w2'].shape[1]} actions, "
            f"portfolio has {n_actions}")


def make_learned_state(params: Dict[str, np.ndarray], reward: str = "LT",
                       meta: Optional[dict] = None) -> dict:
    """The JSON-serializable record ``LearnedPolicy.load_state_dict``
    accepts (and ``state_dict`` emits) — also what ``policy_trainer``
    exports and ``REPRO_LEARNED_STATE`` files contain."""
    return {"kind": "Learned", "reward": reward,
            "feature_version": FEATURE_VERSION,
            "feature_names": list(FEATURE_NAMES),
            "params": params_to_state(params),
            "meta": dict(meta or {})}


_DEFAULT_STATE: Optional[dict] = None


def set_default_state(state: Optional[dict]) -> None:
    """Process-wide default weights for policies built without explicit
    ``state=`` (e.g. campaign lanes spawned by name).  ``None`` clears."""
    global _DEFAULT_STATE
    _DEFAULT_STATE = state


def resolve_default_state() -> Optional[dict]:
    """Explicit ``set_default_state`` wins; else a ``REPRO_LEARNED_STATE``
    JSON path is loaded tolerantly (a corrupt/missing file degrades to a
    cold policy, never takes the run down)."""
    if _DEFAULT_STATE is not None:
        return _DEFAULT_STATE
    path = os.environ.get(LEARNED_STATE_ENV)
    if not path:
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError, ValueError) as e:
        warnings.warn(f"ignoring unreadable {LEARNED_STATE_ENV}={path!r}: "
                      f"{e}", stacklevel=2)
        return None


def is_learned_policy(name: Optional[str]) -> bool:
    """True when ``name`` spells one of the learned methods."""
    return isinstance(name, str) and name.lower() in _LEARNED_ALIASES


# ---------------------------------------------------------------------------
# LearnedPolicy — one numpy forward per decision
# ---------------------------------------------------------------------------

class LearnedPolicy(SelectionPolicy):
    """Contextual-bandit selection: argmin of the net's predicted per-
    algorithm cost for the current context.

    Zero live exploration and zero per-decision simulation: where SimPolicy
    prices 12+ candidates through a what-if ``run_batch`` every decision,
    this is one (F,)x(F,H) matmul chain.  The embedded expert ladder digests
    every live observation, so the *fallback* (no weights, or no context
    bound) stays anchored to the live (LT, LIB) trajectory."""

    name = "Learned"

    def __init__(self, featurizer: Optional[LoopFeaturizer] = None,
                 state: Optional[dict] = None, reward="LT",
                 n_actions: int = N_ALGORITHMS, horizon: int = 500):
        self.featurizer = featurizer
        self.reward_name = reward if isinstance(reward, str) else getattr(
            reward, "__name__", "custom")
        self._reward_fn = get_reward(reward)
        self.n_actions = int(n_actions)
        self.horizon = max(1, int(horizon))
        self._fallback = ExpertPolicy(n_actions=n_actions)
        self._params: Optional[Dict[str, np.ndarray]] = None
        self._meta: dict = {}
        self._t = 0
        if state is None:
            state = resolve_default_state()
        if state is not None:
            self.load_state_dict(state)

    @property
    def trained(self) -> bool:
        return self._params is not None

    @property
    def learning_steps(self) -> int:
        return 0 if self.trained else self._fallback.learning_steps

    @property
    def learning(self) -> bool:
        return False if self.trained else self._fallback.learning

    def scores(self, phase: Optional[float] = None) -> Optional[np.ndarray]:
        """(n_actions,) predicted normalized log-costs for the featurizer's
        bound context, or None when the net cannot score (cold / no
        context)."""
        if self._params is None or self.featurizer is None:
            return None
        try:
            x = self.featurizer.features(
                phase=(self._t / self.horizon) if phase is None else phase)
        except SimUnavailable:
            return None
        return np.asarray(mlp_forward(self._params, x), np.float64)

    def decide(self) -> Decision:
        s = self.scores()
        if s is None:
            d = self._fallback.decide()
            return Decision(action=d.action, phase="expert",
                            confidence=d.confidence)
        best = int(np.argmin(s))
        second = float(np.partition(s, 1)[1]) if len(s) > 1 else float(s[best])
        spread = float(s.max() - s.min())
        conf = 0.0 if spread <= 0 else float(
            np.clip((second - float(s[best])) / spread, 0.0, 1.0))
        return Decision(action=best, phase="exploit", confidence=conf)

    def feedback(self, decision: Decision, obs: Observation) -> None:
        self._fallback.feedback(decision, obs)
        self._t += 1

    # -- persistence (SelectionService store_dir warm start) ----------------
    def state_dict(self) -> Optional[dict]:
        if self._params is None:
            return None
        return make_learned_state(self._params, reward=self.reward_name,
                                  meta=self._meta)

    def load_state_dict(self, state: dict, *,
                        skip_learning: bool = True) -> bool:
        ver = int(state.get("feature_version", -1))
        if ver != FEATURE_VERSION:
            raise ValueError(
                f"learned state has feature_version {ver}, this build "
                f"extracts version {FEATURE_VERSION}")
        params = params_from_state(state["params"])
        _validate_params(params, self.n_actions)
        self._params = params
        self._meta = dict(state.get("meta") or {})
        return True


# ---------------------------------------------------------------------------
# LearnedHybrid — the net seeds/bounds the RL window
# ---------------------------------------------------------------------------

class LearnedHybrid(HybridPolicy):
    """Hybrid expert+RL whose exploration window is pruned by the *net's*
    predicted cost — exactly how ``SimAssistedHybrid`` prunes by simulated
    cost, minus the per-build what-if call.  The RL agent then verifies the
    net's neighbourhood on live traffic (``expert_steps + top_k**2``
    instances) and can overrule a mis-ranked winner; without weights or
    context, the expert-ladder window of :class:`HybridPolicy` applies
    unchanged."""

    name = "LearnedHybrid"

    def __init__(self, featurizer: Optional[LoopFeaturizer] = None,
                 state: Optional[dict] = None, top_k: int = 4,
                 expert_steps: int = 2, horizon: int = 500, **kw):
        kw.setdefault("window", top_k)
        super().__init__(expert_steps=expert_steps, **kw)
        self.top_k = max(1, min(int(top_k), self.n_actions))
        # composition, not inheritance: the net half is a LearnedPolicy so
        # state handling (env default, validation, versioning) stays in one
        # place, and state_dict persistence keeps HybridPolicy's agent form
        self.net = LearnedPolicy(featurizer=featurizer, state=state,
                                 n_actions=self.n_actions, horizon=horizon)

    @property
    def featurizer(self) -> Optional[LoopFeaturizer]:
        return self.net.featurizer

    @featurizer.setter
    def featurizer(self, fz: Optional[LoopFeaturizer]) -> None:
        self.net.featurizer = fz

    def _build_agent(self) -> None:
        s = self.net.scores(phase=self._t / self.net.horizon)
        if s is None:
            super()._build_agent()
            return
        order = np.argsort(s, kind="stable")
        best = int(order[0])
        self.actions = sorted(int(a) for a in order[: self.top_k])
        self.window = len(self.actions)
        self.agent = self._agent_cls(n_actions=self.window,
                                     initial_state=self.actions.index(best),
                                     **self._agent_kw)
        # seed: the net's pick starts strictly above the 0-initialized
        # alternatives, so post-exploration greedy ties break toward it
        self.agent.q[:, self.actions.index(best)] = REWARD_POSITIVE


# ---------------------------------------------------------------------------
# distillation — an interpretable threshold ladder from the trained net
# ---------------------------------------------------------------------------

@dataclass
class _TreeNode:
    feature: int = -1            # -1 = leaf
    threshold: float = 0.0
    action: int = 0              # leaf payload
    left: Optional["_TreeNode"] = None
    right: Optional["_TreeNode"] = None


@dataclass
class DistilledLadder:
    """A depth-bounded threshold ladder over the named features — the
    interpretable form of a trained net (paper §6 asks for expert rules;
    this extracts them instead of hand-writing them).

    ``predict`` maps feature rows to portfolio indices; ``describe`` prints
    the rules; ``teacher_agreement`` is the fit-set label agreement with the
    net, and ``regret_bound`` the relative extra cost vs the teacher the
    distillation promises (bench-verified on held-out cells)."""

    root: _TreeNode
    max_depth: int
    teacher_agreement: float
    regret_bound: float = 0.10
    feature_names: Tuple[str, ...] = FEATURE_NAMES

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, np.float64))
        out = np.zeros(len(X), dtype=np.int64)
        for i, x in enumerate(X):
            node = self.root
            while node.feature >= 0:
                node = node.left if x[node.feature] <= node.threshold \
                    else node.right
            out[i] = node.action
        return out

    def describe(self) -> List[str]:
        """Human-readable rules, one line per leaf."""
        from .portfolio import ALGORITHM_NAMES
        lines: List[str] = []

        def walk(node: _TreeNode, conds: List[str]) -> None:
            if node.feature < 0:
                cond = " and ".join(conds) if conds else "always"
                lines.append(f"if {cond}: {ALGORITHM_NAMES[node.action]}")
                return
            nm = self.feature_names[node.feature]
            walk(node.left, conds + [f"{nm} <= {node.threshold:.3g}"])
            walk(node.right, conds + [f"{nm} > {node.threshold:.3g}"])

        walk(self.root, [])
        return lines

    @property
    def n_leaves(self) -> int:
        def count(node: _TreeNode) -> int:
            return 1 if node.feature < 0 else \
                count(node.left) + count(node.right)
        return count(self.root)


def _gini(labels: np.ndarray, n_actions: int) -> float:
    if len(labels) == 0:
        return 0.0
    p = np.bincount(labels, minlength=n_actions) / len(labels)
    return float(1.0 - (p * p).sum())


def _majority(labels: np.ndarray, n_actions: int) -> int:
    return int(np.argmax(np.bincount(labels, minlength=n_actions)))


def _fit_tree(X: np.ndarray, y: np.ndarray, depth: int, max_depth: int,
              min_leaf: int, n_actions: int) -> _TreeNode:
    if depth >= max_depth or len(y) < 2 * min_leaf or len(set(y)) == 1:
        return _TreeNode(action=_majority(y, n_actions))
    parent = _gini(y, n_actions)
    best = None          # (gain, feature, threshold, mask)
    for f in range(X.shape[1]):
        vals = np.unique(X[:, f])
        if len(vals) < 2:
            continue
        # quantile thresholds bound the split search per feature
        qs = np.quantile(vals, np.linspace(0.1, 0.9, min(len(vals) - 1, 16)))
        for thr in np.unique(qs):
            mask = X[:, f] <= thr
            nl = int(mask.sum())
            if nl < min_leaf or len(y) - nl < min_leaf:
                continue
            w = nl / len(y)
            gain = parent - (w * _gini(y[mask], n_actions)
                             + (1 - w) * _gini(y[~mask], n_actions))
            if best is None or gain > best[0]:
                best = (gain, f, float(thr), mask)
    if best is None or best[0] <= 1e-9:
        return _TreeNode(action=_majority(y, n_actions))
    _, f, thr, mask = best
    return _TreeNode(
        feature=f, threshold=thr,
        left=_fit_tree(X[mask], y[mask], depth + 1, max_depth, min_leaf,
                       n_actions),
        right=_fit_tree(X[~mask], y[~mask], depth + 1, max_depth, min_leaf,
                        n_actions))


def distill_ladder(state_or_policy, X: np.ndarray, max_depth: int = 3,
                   min_leaf: int = 8, regret_bound: float = 0.10
                   ) -> DistilledLadder:
    """Fit an interpretable threshold ladder to the net's decisions over the
    feature rows ``X`` (typically the training transitions).

    ``state_or_policy`` is a learned state dict or a trained
    :class:`LearnedPolicy`.  ``regret_bound`` is the promise the ladder
    ships with: on evaluation data its chosen-cost total must stay within
    ``(1 + regret_bound)`` of the teacher's (``bench_learned`` gates this on
    held-out cells)."""
    if isinstance(state_or_policy, LearnedPolicy):
        params = state_or_policy._params
        if params is None:
            raise ValueError("cannot distill an untrained LearnedPolicy")
    else:
        params = params_from_state(state_or_policy["params"])
    X = np.asarray(X, np.float64)
    scores = mlp_forward(params, X.astype(np.float32))
    y = np.asarray(np.argmin(scores, axis=-1), np.int64)
    n_actions = scores.shape[-1]
    root = _fit_tree(X, y, 0, max_depth, min_leaf, n_actions)
    ladder = DistilledLadder(root=root, max_depth=max_depth,
                             teacher_agreement=0.0,
                             regret_bound=float(regret_bound))
    ladder.teacher_agreement = float((ladder.predict(X) == y).mean())
    return ladder
