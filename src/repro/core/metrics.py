"""Load-imbalance and variability metrics (paper Eq. 8 and Table 2).

These operate on per-PE *finishing times* (simulator / serving rounds) or any
per-worker load vector (e.g. per-expert token counts in MoE — the L2/L3
adaptations).  Pure functions over numpy/jnp arrays so they can run inside or
outside ``jax.jit``.
"""

from __future__ import annotations

import numpy as np


def percent_load_imbalance(finish_times) -> float:
    """LIB, Eq. 8: (1 - mean/max) * 100.  Used by RandomSel (P_j = LIB/10)
    and as the RL `LIB` reward input."""
    ft = np.asarray(finish_times, dtype=np.float64)
    mx = float(ft.max())
    if mx <= 0.0:
        return 0.0
    return (1.0 - float(ft.mean()) / mx) * 100.0


def execution_imbalance(finish_times) -> float:
    """Table 2 metric (deRose et al. [16]): (max-mean)/max * P/(P-1) * 100."""
    ft = np.asarray(finish_times, dtype=np.float64)
    P = ft.shape[-1]
    mx = float(ft.max())
    if mx <= 0.0 or P <= 1:
        return 0.0
    return (mx - float(ft.mean())) / mx * (P / (P - 1.0)) * 100.0


def coefficient_of_variation(times) -> float:
    """Fig. 4: std of loop execution times across portfolio / mean."""
    t = np.asarray(times, dtype=np.float64)
    m = float(t.mean())
    if m <= 0.0:
        return 0.0
    return float(t.std()) / m
