"""Q-table persistence and warm starting (paper §3.5 + §5).

The paper ships ``KMP_RL_AGENT_STATS`` (dump Q-value tables after each loop
instance) and suggests the extension: *"This can be extended in the future
and used to initialize the Q-value tables of applications that have already
been executed on a given system.  Thus, eliminating the learning phase of
RL-based methods."*  This module implements exactly that:

* ``AgentStatsLogger`` — per-instance Q-table snapshots (JSON-lines);
* ``save_policy_state`` / ``load_policy_state`` — persist any
  ``SelectionPolicy.state_dict()`` keyed by (region, system fingerprint);
  this is what ``SelectionService(store_dir=...)`` drives automatically;
* ``system_fingerprint`` — a stable digest of the host (the paper keys
  warm starts by application-system *pair*);
* ``save_agent`` / ``load_agent`` / ``warm_start`` — the original
  agent-level helpers, now thin wrappers over
  ``TabularAgent.state_dict()`` / ``load_state_dict()``.
"""

from __future__ import annotations

import json
import os
import platform
import warnings
import zlib
from typing import Dict, Optional

import numpy as np

from .agents import TabularAgent


def _atomic_json_dump(record: Dict, path: str) -> None:
    """Crash-safe JSON write: serialize to a ``.tmp`` sibling, fsync, and
    ``os.replace`` into place — a kill mid-save can truncate only the temp
    file, never a committed snapshot (so a warm-start store survives the
    very crashes it exists to recover from)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _tolerant_json_load(path: str, what: str) -> Optional[Dict]:
    """Load a snapshot, treating a corrupt/unreadable file as a cache miss
    (warn and return None) — a damaged warm-start store must degrade to a
    cold start, never take the run down."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, ValueError, OSError) as e:
        warnings.warn(f"ignoring corrupt {what} snapshot {path!r}: {e}",
                      stacklevel=3)
        return None


def system_fingerprint() -> str:
    """Stable 8-hex digest of the host: the "system" half of the paper's
    application-system pairing.  CRC-32 (not ``hash()``) so the key is
    identical across processes and runs."""
    ident = "|".join((platform.machine(), platform.system(),
                      str(os.cpu_count() or 0)))
    return f"{zlib.crc32(ident.encode('utf-8')):08x}"


class AgentStatsLogger:
    """KMP_RL_AGENT_STATS equivalent: append one Q-table snapshot per loop
    instance to ``<dir>/<region>.jsonl``."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def log(self, region: str, instance: int, agent: TabularAgent) -> None:
        rec = {"instance": instance, "alpha": agent.alpha,
               "state": int(agent.state),
               "learning": bool(agent.learning),
               "q": np.asarray(agent.q).round(6).tolist()}
        with open(os.path.join(self.dir, f"{region}.jsonl"), "a") as f:
            f.write(json.dumps(rec) + "\n")


def _key_path(directory: str, region: str, system: str,
              prefix: str = "qtable") -> str:
    safe = f"{region}__{system}".replace("/", "_")
    return os.path.join(directory, f"{prefix}_{safe}.json")


# ---------------------------------------------------------------------------
# policy-level persistence (SelectionService store_dir)
# ---------------------------------------------------------------------------

def save_policy_state(record: Dict, directory: str, region: str,
                      system: str = "default") -> str:
    """Write a ``{"method": ..., "state": policy.state_dict(), ...}`` record
    keyed by (region, system)."""
    os.makedirs(directory, exist_ok=True)
    path = _key_path(directory, region, system, prefix="policy")
    _atomic_json_dump(record, path)
    return path


def load_policy_state(directory: str, region: str,
                      system: str = "default") -> Optional[Dict]:
    path = _key_path(directory, region, system, prefix="policy")
    return _tolerant_json_load(path, "policy")


# ---------------------------------------------------------------------------
# agent-level helpers (pre-redesign surface; still supported)
# ---------------------------------------------------------------------------

def save_agent(agent: TabularAgent, directory: str, region: str,
               system: str = "default") -> str:
    os.makedirs(directory, exist_ok=True)
    path = _key_path(directory, region, system)
    _atomic_json_dump(agent.state_dict(), path)
    return path


def load_agent(directory: str, region: str, system: str = "default"
               ) -> Optional[Dict]:
    path = _key_path(directory, region, system)
    return _tolerant_json_load(path, "agent")


def warm_start(agent: TabularAgent, rec: Dict,
               skip_learning: bool = True) -> TabularAgent:
    """Initialize ``agent`` from a stored record.

    With ``skip_learning`` the agent resumes at the snapshot's instance
    count: a fully-trained record skips the explore-first phase entirely —
    the paper's 28.8 % exploration cost drops to zero on re-runs of a known
    application-system pair — while a record saved *mid-learning* resumes
    exploration where it stopped (it no longer jumps straight to greedy
    exploitation of a half-filled table).  With ``skip_learning=False`` the
    explore-first phase is replayed from scratch over the restored table."""
    agent.load_state_dict(rec, skip_learning=skip_learning)
    return agent
