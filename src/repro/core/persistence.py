"""Q-table persistence and warm starting (paper §3.5 + §5).

The paper ships ``KMP_RL_AGENT_STATS`` (dump Q-value tables after each loop
instance) and suggests the extension: *"This can be extended in the future
and used to initialize the Q-value tables of applications that have already
been executed on a given system.  Thus, eliminating the learning phase of
RL-based methods."*  This module implements exactly that:

* ``AgentStatsLogger`` — per-instance Q-table snapshots (JSON-lines);
* ``save_agent`` / ``load_agent`` — persist (Q-table, reward extrema, state);
* ``warm_start`` — resume a Q-Learn/SARSA agent from a stored table with the
  explore-first phase SKIPPED (the 144-instance cost drops to 0);
* keyed by (application/region id, system fingerprint), mirroring the
  paper's application-system pairing.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from .agents import QLearnAgent, SarsaAgent, TabularAgent


class AgentStatsLogger:
    """KMP_RL_AGENT_STATS equivalent: append one Q-table snapshot per loop
    instance to ``<dir>/<region>.jsonl``."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def log(self, region: str, instance: int, agent: TabularAgent) -> None:
        rec = {"instance": instance, "alpha": agent.alpha,
               "state": int(agent.state),
               "learning": bool(agent.learning),
               "q": np.asarray(agent.q).round(6).tolist()}
        with open(os.path.join(self.dir, f"{region}.jsonl"), "a") as f:
            f.write(json.dumps(rec) + "\n")


def _key_path(directory: str, region: str, system: str) -> str:
    safe = f"{region}__{system}".replace("/", "_")
    return os.path.join(directory, f"qtable_{safe}.json")


def save_agent(agent: TabularAgent, directory: str, region: str,
               system: str = "default") -> str:
    os.makedirs(directory, exist_ok=True)
    lo, hi = agent.reward.extrema
    rec = {
        "kind": type(agent).__name__,
        "n_actions": agent.n_actions,
        "alpha": agent.alpha, "gamma": agent.gamma,
        "alpha_decay": agent.alpha_decay,
        "state": int(agent.state),
        "instances": agent._t,
        "q": np.asarray(agent.q).tolist(),
        "reward_min": None if not np.isfinite(lo) else lo,
        "reward_max": None if not np.isfinite(hi) else hi,
        "reward_count": agent.reward.count,
    }
    path = _key_path(directory, region, system)
    with open(path, "w") as f:
        json.dump(rec, f)
    return path


def load_agent(directory: str, region: str, system: str = "default"
               ) -> Optional[Dict]:
    path = _key_path(directory, region, system)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def warm_start(agent: TabularAgent, rec: Dict,
               skip_learning: bool = True) -> TabularAgent:
    """Initialize ``agent`` from a stored record.  With ``skip_learning`` the
    explore-first phase is marked done — the paper's 28.8 % exploration cost
    drops to zero on re-runs of a known application-system pair."""
    q = np.asarray(rec["q"], dtype=np.float64)
    assert q.shape == agent.q.shape, (q.shape, agent.q.shape)
    agent.q = q
    agent.state = int(rec["state"])
    agent.alpha = float(rec["alpha"])
    if rec.get("reward_min") is not None:
        agent.reward._min = rec["reward_min"]
        agent.reward._max = rec["reward_max"]
        agent.reward.count = rec.get("reward_count", 1)
    if skip_learning:
        agent._t = max(agent._t, len(agent._explore))
    return agent
