"""The 12-algorithm scheduling portfolio of LB4OMP / Auto4OMP (paper §2, §3.1).

Each algorithm computes *chunk sizes* — how many loop iterations (or, in the
serving adaptation, requests) a processing element (PE) self-assigns per work
request.  The portfolio order matches Table 2's footnote:

    [STATIC, SS, GSS, Auto(LLVM), TSS, StaticSteal,
     mFAC2, AWF-B, AWF-C, AWF-D, AWF-E, mAF]

Two implementations are provided:

* Stateful host-side classes (``ChunkAlgorithm`` subclasses) used by the
  discrete-event simulator (``repro.sim``) and the serving dispatcher
  (``repro.serving``) — these support the *adaptive* algorithms, which need
  per-PE runtime telemetry.
* A pure-JAX ``chunk_schedule`` (in ``repro.core.jaxsched``) for the
  non-adaptive algorithms, usable under ``jax.jit`` and property-tested
  against the host classes.

Chunk-parameter semantics (paper §2, "Significance of the chunk parameter"):
for STATIC and SS the user chunk sets the size *directly*; for every other
algorithm it is a floor: ``delivered = max(algorithm, user)``.  Chunks never
exceed the remaining iterations.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

ALGORITHM_NAMES: List[str] = [
    "STATIC",       # 0  OpenMP static (or static,chunk when a param is given)
    "SS",           # 1  self-scheduling / OpenMP dynamic    [Peiyi&Yen 86]
    "GSS",          # 2  guided self-scheduling              [Polychronopoulos&Kuck 87]
    "AutoLLVM",     # 3  LLVM schedule(auto) heuristic
    "TSS",          # 4  trapezoid self-scheduling           [Tzen&Ni 93]
    "StaticSteal",  # 5  static + work stealing              [Blumofe&Leiserson 99]
    "mFAC2",        # 6  practical factoring, atomic-counter variant [Hummel 92 / LB4OMP]
    "AWF_B",        # 7  adaptive weighted factoring, batched       [Banicescu 03]
    "AWF_C",        # 8  AWF, chunked (recompute per request)
    "AWF_D",        # 9  AWF-B with total-chunk-time weights
    "AWF_E",        # 10 AWF-C with total-chunk-time weights
    "mAF",          # 11 adaptive factoring, practical variant      [Banicescu&Liu 00]
]

N_ALGORITHMS = len(ALGORITHM_NAMES)

# Indices of algorithms whose chunk calculation *adapts* to measured PE speed.
ADAPTIVE_SET = frozenset({7, 8, 9, 10, 11})
# Algorithms where the user chunk parameter sets the size directly.
DIRECT_CHUNK_SET = frozenset({0, 1})


def alg_index(name: str) -> int:
    return ALGORITHM_NAMES.index(name)


# ---------------------------------------------------------------------------
# expert chunk parameter (paper §3.2; Auto4OMP [25] Eq. 1)
# ---------------------------------------------------------------------------

GOLDEN_RATIO = (1.0 + math.sqrt(5.0)) / 2.0  # phi = 1.618...


def exp_chunk(N: int, P: int) -> int:
    """expChunk: golden-ratio point on the curve {N/(2^i P)} between N/(2P) and 1.

    Candidate chunk parameters are N/(2P), N/(4P), ... down to 1 (i in steps of
    2^n).  expChunk sits at 1/phi = 0.618 of the way along that curve, i.e. at
    exponent i = round((1 - 1/phi) * log2(N/P)).  For the paper's running
    example (N=1e6, P=20) this yields 781 — one of the two chunk parameters
    highlighted in Figs. 1-2.
    """
    if N <= 0 or P <= 0:
        raise ValueError("N and P must be positive")
    ratio = max(2.0, N / P)
    k_max = math.log2(ratio)  # exponent at which chunk reaches 1
    i = round((1.0 - 1.0 / GOLDEN_RATIO) * k_max)
    i = max(1, i)
    return max(1, int(N // (2 ** i * P)))


def apply_chunk_floor(alg: int, computed: int, chunk_param: int, remaining: int) -> int:
    """LB4OMP chunk-parameter semantics, clipped to the remaining iterations."""
    if remaining <= 0:
        return 0
    if alg in DIRECT_CHUNK_SET and chunk_param > 0:
        out = chunk_param
    else:
        out = max(computed, max(1, chunk_param))
    return int(max(1, min(out, remaining)))


# ---------------------------------------------------------------------------
# Stateful algorithm classes
# ---------------------------------------------------------------------------


@dataclass
class ChunkAlgorithm:
    """Base class. Lifecycle:

        alg.reset(N, P, chunk_param)
        while work remains:
            c = alg.next_chunk(pe)          # pe = requesting PE id
            ... execute c iterations ...
            alg.report(pe, c, iters_time, chunk_time)
    """

    name: str = "base"
    index: int = -1
    adaptive: bool = False

    def reset(self, N: int, P: int, chunk_param: int = 0) -> None:
        self.N = int(N)
        self.P = int(P)
        self.chunk_param = int(chunk_param)
        self.remaining = int(N)
        self.scheduled = 0
        self._reset_impl()

    def _reset_impl(self) -> None:  # pragma: no cover - overridden
        pass

    def next_chunk(self, pe: int) -> int:
        if self.remaining <= 0:
            return 0
        c = apply_chunk_floor(self.index, self._compute(pe), self.chunk_param,
                              self.remaining)
        self.remaining -= c
        self.scheduled += c
        return c

    def _compute(self, pe: int) -> int:  # pragma: no cover - overridden
        raise NotImplementedError

    def report(self, pe: int, chunk: int, iters_time: float,
               chunk_time: float) -> None:
        """Telemetry hook: ``iters_time`` is the pure iteration execution time,
        ``chunk_time`` additionally includes scheduling overhead (AWF-D/E)."""

    # ---- static-family helpers -------------------------------------------
    def is_static(self) -> bool:
        return False


class Static(ChunkAlgorithm):
    """Eq. 1: P equal chunks, pre-assigned.  With a chunk parameter this is
    ``schedule(static, chunk)``: round-robin fixed-size chunks."""

    def __init__(self) -> None:
        self.name, self.index = "STATIC", 0

    def _compute(self, pe: int) -> int:
        if self.chunk_param > 0:
            return self.chunk_param
        # ceil(N/P) so that P chunks always cover N
        return -(-self.N // self.P)

    def is_static(self) -> bool:
        return True


class SelfScheduling(ChunkAlgorithm):
    """SS, Eq. 2: chunk = 1 (or the user chunk — OpenMP ``dynamic,chunk``)."""

    def __init__(self) -> None:
        self.name, self.index = "SS", 1

    def _compute(self, pe: int) -> int:
        return 1


class GuidedSS(ChunkAlgorithm):
    """GSS, Eq. 3: Cs_i = ceil(R_i / P)."""

    def __init__(self) -> None:
        self.name, self.index = "GSS", 2

    def _compute(self, pe: int) -> int:
        return -(-self.remaining // self.P)


class AutoLLVM(ChunkAlgorithm):
    """LLVM ``schedule(auto)``: guided-analytical heuristic.  Modeled (per
    LLVM's kmp guided_analytical_chunked) as guided with a doubled divisor and
    a parallelism-derived minimum quantum — DESIGN.md §8 notes the source.
    """

    def __init__(self) -> None:
        self.name, self.index = "AutoLLVM", 3

    def _reset_impl(self) -> None:
        # LLVM uses a minimum chunk targeting ~4 chunks per PE tail.
        self._min_quantum = max(1, self.N // (self.P * self.P * 4))

    def _compute(self, pe: int) -> int:
        guided = -(-self.remaining // (2 * self.P))
        return max(self._min_quantum, guided)


class Trapezoid(ChunkAlgorithm):
    """TSS, Eq. 4 with the recommended f = N/(2P), l = 1.

    Chunk k is ceil(f - k*delta) with delta = (f-1)/(A-1), evaluated in
    exact integer arithmetic (chunk_k = ceil((N*(A-1) - k*(N-2P)) /
    (2P*(A-1)))) so the sequence is bit-identical to the pure-JAX
    ``chunk_schedule`` — float64 running subtraction drifts past exact
    integer crossings and used to produce platform-hostage +-1 chunks.
    """

    def __init__(self) -> None:
        self.name, self.index = "TSS", 4

    def _reset_impl(self) -> None:
        self._k = 0
        twoP = 2 * self.P
        if self.N < twoP:          # f clamps to 1 -> delta 0 -> unit chunks
            self._Am1 = 0
            return
        # A = ceil(2N/(f+1)) = ceil(4PN/(N+2P)) = 4P - floor(8P^2/(N+2P))
        A = 4 * self.P - (8 * self.P * self.P) // (self.N + twoP)
        self._Am1 = max(1, A - 1)
        self._D = twoP * self._Am1

    def _compute(self, pe: int) -> int:
        if self._Am1 == 0:
            return 1
        k = min(self._k, self._Am1)
        self._k += 1
        num = self.N * self._Am1 - k * (self.N - 2 * self.P)
        return max(1, -(-num // self._D))


class StaticSteal(ChunkAlgorithm):
    """Static pre-split into P ranges; an idle PE steals half of the richest
    victim's remainder.  Chunks are delivered in sub-chunks of the steal
    quantum so the simulator sees individual work requests."""

    def __init__(self) -> None:
        self.name, self.index = "StaticSteal", 5

    def _reset_impl(self) -> None:
        base = self.N // self.P
        extra = self.N % self.P
        self.local = [base + (1 if i < extra else 0) for i in range(self.P)]
        # LLVM static_steal dispenses the local range one chunk at a time;
        # the default chunk is 1 iteration (the paper's STREAM blowup)
        self.quantum = max(1, self.chunk_param)

    def _compute(self, pe: int) -> int:
        if self.local[pe] <= 0:
            victim = max(range(self.P), key=lambda i: self.local[i])
            if self.local[victim] <= 0:
                return 1  # nothing to steal; floor clips vs remaining
            stolen = -(-self.local[victim] // 2)
            self.local[victim] -= stolen
            self.local[pe] += stolen
        c = min(self.quantum, self.local[pe])
        self.local[pe] -= c
        return c


class MFac2(ChunkAlgorithm):
    """mFAC2 (practical factoring, x=2): batches of P chunks, each batch
    assigns half of the remaining iterations.  Atomic-counter variant — same
    chunk sizes as FAC2, lower overhead (modeled via the system's h)."""

    def __init__(self) -> None:
        self.name, self.index = "mFAC2", 6

    def _reset_impl(self) -> None:
        self._counter = 0  # atomic chunk counter
        self._batch_j = 0
        self._batch_R = self.N
        self._batch_cs = -(-self.N // (2 * self.P))

    def _compute(self, pe: int) -> int:
        j = self._counter // self.P
        # chunk size for batch j: R_j / (2P), R_{j+1} = R_j - P*Cs_j
        while self._batch_j < j:
            self._batch_R -= self.P * self._batch_cs
            self._batch_cs = max(0, -(-self._batch_R // (2 * self.P)))
            self._batch_j += 1
        self._counter += 1
        return max(1, self._batch_cs)


class _AWFBase(ChunkAlgorithm):
    """Adaptive weighted factoring (Banicescu et al. 03) — four variants.

    Weights are the normalized inverse of each PE's measured time-per-
    iteration (variants B/C) or total-chunk time-per-iteration including
    scheduling overhead (variants D/E).  B/D are *batched* (weights frozen
    within a batch); C/E are *chunked* (weights + batch recomputed on every
    work request).
    """

    batched = True
    total_time = False
    adaptive = True

    def _reset_impl(self) -> None:
        import numpy as _np
        self.w = _np.ones(self.P)                # PE weights, mean 1
        self._pe_time = _np.zeros(self.P)        # cumulated timing numerator
        self._pe_iters = _np.zeros(self.P)       # cumulated iterations
        self._batch_left = 0                     # chunks left in current batch
        self._batch_cs = 0
        self._dirty = False

    def report(self, pe, chunk, iters_time, chunk_time):
        t = chunk_time if self.total_time else iters_time
        self._pe_time[pe] += max(t, 1e-12)
        self._pe_iters[pe] += chunk
        if self.batched:
            self._dirty = True       # weights refresh at the batch boundary
        else:
            self._update_weights()   # chunked variants: every request

    def _update_weights(self) -> None:
        import numpy as _np
        # weighted average performance: rate_i = iters_i / time_i
        known = self._pe_iters > 0
        if not known.any():
            return
        rates = _np.where(known, self._pe_iters / _np.maximum(self._pe_time, 1e-30), 0.0)
        mean_rate = rates[known].mean()
        raw = _np.where(known, rates, mean_rate)
        total = raw.sum()
        if total <= 0:
            return
        self.w = self.P * raw / total
        self._dirty = False

    def _compute(self, pe: int) -> int:
        if self.batched:
            if self._batch_left <= 0:
                if self._dirty:
                    self._update_weights()
                self._batch_cs = -(-self.remaining // (2 * self.P))
                self._batch_left = self.P
            self._batch_left -= 1
            base = self._batch_cs
        else:
            base = -(-self.remaining // (2 * self.P))
        return max(1, int(round(self.w[pe] * base)))


class AWF_B(_AWFBase):
    def __init__(self) -> None:
        self.name, self.index = "AWF_B", 7
        self.batched, self.total_time = True, False


class AWF_C(_AWFBase):
    def __init__(self) -> None:
        self.name, self.index = "AWF_C", 8
        self.batched, self.total_time = False, False


class AWF_D(_AWFBase):
    def __init__(self) -> None:
        self.name, self.index = "AWF_D", 9
        self.batched, self.total_time = True, True


class AWF_E(_AWFBase):
    def __init__(self) -> None:
        self.name, self.index = "AWF_E", 10
        self.batched, self.total_time = False, True


class MAdaptiveFactoring(ChunkAlgorithm):
    """mAF (adaptive factoring, Eqs. 6-7): per-PE mu_i, sigma_i estimated
    online; D_n = sum(sigma_i^2/mu_i), T_n = (sum 1/mu_i)^-1,
    Cs_i = (D + 2 T R - sqrt(D^2 + 4 D T R)) / (2 mu_i); first chunk >= 100.
    """

    adaptive = True

    def __init__(self) -> None:
        self.name, self.index = "mAF", 11

    def _reset_impl(self) -> None:
        import numpy as _np
        self._sum_t = _np.zeros(self.P)    # sum of per-iteration times
        self._sum_t2 = _np.zeros(self.P)   # sum of squared per-iteration times
        self._cnt = _np.zeros(self.P)      # chunks reported (mu over chunk means)
        self._have_stats = False

    def report(self, pe, chunk, iters_time, chunk_time):
        if chunk <= 0:
            return
        per_iter = max(iters_time / chunk, 1e-12)
        self._sum_t[pe] += per_iter
        self._sum_t2[pe] += per_iter * per_iter
        self._cnt[pe] += 1
        self._have_stats = True

    def _mu_sigma_all(self):
        import numpy as _np
        known = self._cnt > 0
        tot = self._cnt.sum()
        g_mu = self._sum_t.sum() / tot
        g_var = max(0.0, self._sum_t2.sum() / tot - g_mu * g_mu)
        mu = _np.where(known, self._sum_t / _np.maximum(self._cnt, 1), g_mu)
        ex2 = _np.where(known, self._sum_t2 / _np.maximum(self._cnt, 1),
                        g_var + g_mu * g_mu)
        var = _np.maximum(0.0, ex2 - mu * mu)
        return mu, var

    def _compute(self, pe: int) -> int:
        if not self._have_stats:
            # Eq. 6: Cs^(1) >= 100 for the very first, statistics-free chunks
            return min(100, max(1, self.remaining // self.P))
        mu, var = self._mu_sigma_all()
        # Eq. 7: D = sum(sigma_i^2 / mu_i), T = (sum 1/mu_i)^-1
        D = float((var / mu).sum())
        invmu_sum = float((1.0 / mu).sum())
        if invmu_sum <= 0:
            return max(1, self.remaining // (2 * self.P))
        T = 1.0 / invmu_sum
        R = float(self.remaining)
        mu_pe = float(mu[pe])
        num = D + 2.0 * T * R - math.sqrt(D * D + 4.0 * D * T * R)
        cs = num / (2.0 * mu_pe) if mu_pe > 0 else R / (2.0 * self.P)
        return max(1, int(cs))


_FACTORIES = [Static, SelfScheduling, GuidedSS, AutoLLVM, Trapezoid,
              StaticSteal, MFac2, AWF_B, AWF_C, AWF_D, AWF_E,
              MAdaptiveFactoring]


def make_algorithm(idx_or_name) -> ChunkAlgorithm:
    idx = idx_or_name if isinstance(idx_or_name, int) else alg_index(idx_or_name)
    a = _FACTORIES[idx]()
    assert a.index == idx, (a.index, idx)
    return a


def make_portfolio() -> List[ChunkAlgorithm]:
    return [make_algorithm(i) for i in range(N_ALGORITHMS)]
