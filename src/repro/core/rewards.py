"""Reward function (paper §3.5, Eq. 11).

Two reward *types* feed the same three-level reward:

    LT  — loop (step / round) execution time
    LIB — percent load imbalance, Eq. 8

        R_t(x) = r+   if x <= min_t(x)      (new best)
                 r0   if min < x < max      (neutral)
                 r-   if x >= max_t(x)      (new worst)

min/max are running extrema over all *previously observed* instances of the
loop.  Paper values: r+ = 0.01 (not 0, to stay distinguishable from the
Q-table's 0 init), r0 = -2.0, r- = -4.0.

The LT/LIB *signal extraction* that used to be hard-coded here is now the
pluggable reward registry in :mod:`repro.core.api` (``@register_reward``):
any ``Observation -> float`` (lower is better) can feed this tracker, so
LT/LIB generalize to p95 tail latency, LT+LIB blends, throughput, etc.
``REWARD_TYPES`` is kept for the legacy two-string surface.
"""

from __future__ import annotations

from dataclasses import dataclass, field

REWARD_POSITIVE = 0.01
REWARD_NEUTRAL = -2.0
REWARD_NEGATIVE = -4.0

REWARD_TYPES = ("LT", "LIB")


@dataclass
class RewardTracker:
    """Running min/max extrema + Eq. 11 mapping for one loop id."""

    r_pos: float = REWARD_POSITIVE
    r_neu: float = REWARD_NEUTRAL
    r_neg: float = REWARD_NEGATIVE
    _min: float = field(default=float("inf"))
    _max: float = field(default=float("-inf"))
    count: int = 0

    def reward(self, x: float) -> float:
        """Return Eq. 11 reward for observation ``x`` and fold it into the
        running extrema.  The first observation is a new best → r+."""
        if self.count == 0:
            r = self.r_pos
        elif x <= self._min:
            r = self.r_pos
        elif x >= self._max:
            r = self.r_neg
        else:
            r = self.r_neu
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        self.count += 1
        return r

    @property
    def extrema(self):
        return self._min, self._max
