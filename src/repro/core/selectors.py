"""Scheduling-algorithm selection policies (paper §3.2-3.5, §6).

Every method implements the structured :class:`repro.core.api.SelectionPolicy`
protocol so the simulator, serving dispatcher and step-plan autotuner can
drive any of them through one surface:

    policy = make_policy("QLearn", reward="LT", seed=0)
    for t in range(T):
        d = policy.decide()                  # Decision: action, phase, ...
        obs = execute(d.action)              # -> Observation
        policy.feedback(d, obs)

Expert-based:  RandomSel, ExhaustiveSel, ExpertSel     [25]
RL-based:      QLearn, SARSA                           (this paper)
Combined:      Hybrid — ExpertSel's fuzzy ladder seeds and bounds the RL
               agent's exploration (paper §6's expert+RL combination)
References:    Fixed (single algorithm), Oracle (offline per-instance best)

The pre-redesign scalar surface (``Selector.select()`` /
``observe(action, loop_time, lib)`` and ``make_selector``) survives at the
bottom of this module as thin adapter shims over the policies.  It is
deprecated; new code should use ``make_policy`` / ``SelectionService``.
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, List, Optional

import numpy as np

from .agents import QLearnAgent, SarsaAgent
from .api import Decision, Observation, SelectionPolicy, get_reward
from .fuzzy import make_diff_system, make_initial_system
from .portfolio import N_ALGORITHMS
from .rewards import REWARD_POSITIVE

SELECTOR_NAMES = ["Fixed", "RandomSel", "ExhaustiveSel", "ExpertSel",
                  "QLearn", "SARSA", "Hybrid", "Oracle"]
#: the structured-API spelling of the same registry (plus the
#: simulation-assisted methods, which need a ``simulator=``, and the
#: offline-trained learned methods, which want a ``featurizer=`` +
#: trained ``state=``)
POLICY_NAMES = SELECTOR_NAMES + ["SimPolicy", "SimHybrid", "ReactiveSim",
                                 "ReactiveHybrid", "AwareSim",
                                 "Learned", "LearnedHybrid"]


# ---------------------------------------------------------------------------
# reference policies
# ---------------------------------------------------------------------------

class FixedPolicy(SelectionPolicy):
    """Always the same algorithm — used for per-algorithm campaign runs."""

    name = "Fixed"

    def __init__(self, algorithm: int):
        self.algorithm = int(algorithm)

    def decide(self) -> Decision:
        return Decision(action=self.algorithm, phase="exploit")


class OraclePolicy(SelectionPolicy):
    """Paper §3.3: manually derived per-instance best (offline exhaustive).
    ``best_fn(t)`` maps instance index → portfolio index."""

    name = "Oracle"

    def __init__(self, best_fn: Callable[[int], int]):
        self._best = best_fn
        self._t = 0

    def decide(self) -> Decision:
        return Decision(action=int(self._best(self._t)), phase="exploit")

    def feedback(self, decision: Decision, obs: Observation) -> None:
        self._t += 1


# ---------------------------------------------------------------------------
# expert-based policies [25]
# ---------------------------------------------------------------------------

class RandomPolicy(SelectionPolicy):
    """[25]: jump probability P_j = LIB / 10; if P_j > RND(0,1) pick a random
    algorithm, else keep the current one.  LIB > 10 % → always switch.

    The jump is rolled once per instance (in ``feedback``, and once at
    construction for the first instance), so ``decide`` is a pure peek —
    repeated calls neither advance the RNG nor change the selection."""

    name = "RandomSel"

    def __init__(self, seed: int = 0, initial: int = 0,
                 n_actions: int = N_ALGORITHMS):
        self.rng = np.random.default_rng(seed)
        self.current = int(initial)
        self.n_actions = n_actions
        self._lib = 100.0  # force an exploratory jump on the first instance
        self._jumped = self._jump()

    def _jump(self) -> bool:
        """Mutating roll: maybe re-pick the current algorithm."""
        if self._lib / 10.0 > self.rng.random():
            self.current = int(self.rng.integers(0, self.n_actions))
            return True
        return False

    def decide(self) -> Decision:
        if self._jumped:
            return Decision(action=self.current, phase="explore",
                            confidence=0.0)
        p_jump = self._lib / 10.0
        return Decision(action=self.current, phase="exploit",
                        confidence=float(np.clip(1.0 - p_jump, 0.0, 1.0)))

    def feedback(self, decision: Decision, obs: Observation) -> None:
        self._lib = float(obs.lib)
        self._jumped = self._jump()     # roll for the next instance


class ExhaustivePolicy(SelectionPolicy):
    """[25]: one instance per portfolio algorithm (in order), then argmin of
    the recorded times.  LIB is monitored after selection; a >10 % deviation
    from the recorded average re-triggers the search."""

    name = "ExhaustiveSel"

    def __init__(self, lib_retrigger: float = 0.10, min_samples: int = 3,
                 n_actions: int = N_ALGORITHMS):
        self.n_actions = n_actions
        self._times = np.full(n_actions, np.inf)
        self._phase = 0                 # next algorithm to try
        self._selected: Optional[int] = None
        self._lib_sum = 0.0
        self._lib_cnt = 0
        self._retrigger = lib_retrigger
        self._min_samples = min_samples

    @property
    def learning_steps(self) -> int:
        return self.n_actions

    @property
    def learning(self) -> bool:
        return self._selected is None

    def decide(self) -> Decision:
        if self._selected is None:
            return Decision(action=self._phase, phase="explore",
                            confidence=0.0)
        return Decision(action=self._selected, phase="monitor")

    def feedback(self, decision: Decision, obs: Observation) -> None:
        action, loop_time, lib = decision.action, obs.loop_time, obs.lib
        if self._selected is None:
            self._times[action] = loop_time
            self._phase += 1
            if self._phase >= self.n_actions:
                self._selected = int(np.argmin(self._times))
                self._lib_sum = self._lib_cnt = 0
            return
        # monitoring phase
        self._lib_cnt += 1
        self._lib_sum += lib
        avg = self._lib_sum / self._lib_cnt
        if (self._lib_cnt >= self._min_samples and avg > 1.0
                and abs(lib - avg) > self._retrigger * avg):
            # high-imbalance drift: reassess the portfolio
            self._times[:] = np.inf
            self._phase = 0
            self._selected = None


class ExpertPolicy(SelectionPolicy):
    """[25]: fuzzy-logic selection.  First instance runs STATIC to baseline
    T_par and LIB; the second instance uses the *absolute* fuzzy system; later
    instances use the *differential* system on (dT_par, dLIB) to move along
    the portfolio's adaptivity ladder."""

    name = "ExpertSel"

    def __init__(self, n_actions: int = N_ALGORITHMS):
        self._initial = make_initial_system()
        self._diff = make_diff_system()
        self.n_actions = n_actions
        self.current = 0            # DLS_0 = STATIC
        self._t = 0
        self._first_time: Optional[float] = None
        self._prev_time: Optional[float] = None
        self._prev_lib: Optional[float] = None

    @property
    def learning_steps(self) -> int:
        return 1

    @property
    def learning(self) -> bool:
        return self._t < 1

    def decide(self) -> Decision:
        phase = "expert" if self._t > 0 else "explore"
        return Decision(action=self.current, phase=phase,
                        confidence=0.0 if self._t == 0 else 0.5)

    def feedback(self, decision: Decision, obs: Observation) -> None:
        loop_time, lib = obs.loop_time, obs.lib
        if self._t == 0:
            self._first_time = loop_time
            ladder = self._initial.infer(lib, 1.0)
            self.current = int(np.clip(round(ladder), 0, self.n_actions - 1))
        else:
            dT = loop_time / max(self._prev_time, 1e-12) - 1.0
            dLIB = lib - self._prev_lib
            step = self._diff.infer(dT, dLIB)
            self.current = int(np.clip(round(self.current + step),
                                       0, self.n_actions - 1))
        self._prev_time = loop_time
        self._prev_lib = lib
        self._t += 1


# ---------------------------------------------------------------------------
# RL-based policies (this paper)
# ---------------------------------------------------------------------------

class RLPolicy(SelectionPolicy):
    """Tabular RL over the portfolio with a pluggable reward signal.

    The registered reward function extracts a scalar (lower is better) from
    each ``Observation``; the Eq. 11 three-level tracker inside the agent
    maps it to r+/r0/r-.  ``reward`` may be any registry name ("LT", "LIB",
    "p95", "LT+LIB", ...) or a callable."""

    agent_cls = None  # type: ignore[assignment]

    def __init__(self, reward="LT", alpha: float = 0.5,
                 gamma: float = 0.5, alpha_decay: float = 0.05,
                 decay_mode: str = "subtractive", initial: int = 0,
                 n_actions: int = N_ALGORITHMS):
        self.reward_name = reward if isinstance(reward, str) else getattr(
            reward, "__name__", "custom")
        self._reward_fn = get_reward(reward)
        self.agent = self.agent_cls(n_actions=n_actions, alpha=alpha,
                                    gamma=gamma, alpha_decay=alpha_decay,
                                    decay_mode=decay_mode,
                                    initial_state=initial)

    @property
    def learning_steps(self) -> int:
        return self.agent.learning_steps

    @property
    def learning(self) -> bool:
        return self.agent.learning

    def decide(self) -> Decision:
        a = self.agent.select()
        if self.agent.learning:
            return Decision(action=a, phase="explore", confidence=0.0)
        row = self.agent.q[self.agent.state]
        margin = float(row.max() - np.partition(row, -2)[-2]) \
            if len(row) > 1 else 1.0
        conf = float(np.clip(margin / (abs(float(row.max())) + 1e-9), 0, 1))
        return Decision(action=a, phase="exploit", confidence=conf)

    def feedback(self, decision: Decision, obs: Observation) -> None:
        self.agent.observe(decision.action, self._reward_fn(obs))

    def state_dict(self) -> dict:
        return {"kind": self.name, "reward": self.reward_name,
                "agent": self.agent.state_dict()}

    def load_state_dict(self, state: dict, *,
                        skip_learning: bool = True) -> bool:
        self.agent.load_state_dict(state["agent"],
                                   skip_learning=skip_learning)
        return not self.agent.learning


class QLearnPolicy(RLPolicy):
    name = "QLearn"
    agent_cls = QLearnAgent


class SarsaPolicy(RLPolicy):
    name = "SARSA"
    agent_cls = SarsaAgent


# ---------------------------------------------------------------------------
# hybrid expert + RL (paper §6's combination, previously unbuildable)
# ---------------------------------------------------------------------------

class HybridPolicy(SelectionPolicy):
    """ExpertSel's fuzzy ladder seeds and bounds the RL agent's exploration.

    Phase 1 (``expert_steps`` instances): run the fuzzy ladder exactly like
    ExpertSel, letting published expert knowledge walk toward the right
    portfolio neighbourhood for the observed (T_par, LIB) regime.

    Phase 2: open a window of ``window`` algorithms around the ladder's
    final position and hand it to a tabular RL agent.  The explore-first
    Eulerian circuit then covers only ``window**2`` state-action pairs
    instead of the full ``n_actions**2`` (144), and the Q-table is seeded so
    greedy ties break toward the expert's pick.

    Defaults (6 expert + 5x5 RL = 31 instances) cut the paper's 28.8 %
    exploration cost (144 of 500) to ~6 % while keeping the asymptotic
    selection quality of pure Q-Learn whenever the optimum lies in the
    expert's neighbourhood — the paper's §6 argument for combining the two
    families."""

    name = "Hybrid"

    def __init__(self, reward="LT", agent: str = "qlearn",
                 expert_steps: int = 6, window: int = 5,
                 n_actions: int = N_ALGORITHMS, alpha: float = 0.5,
                 gamma: float = 0.5, alpha_decay: float = 0.05,
                 decay_mode: str = "subtractive"):
        if expert_steps < 1:
            raise ValueError("expert_steps must be >= 1")
        self.reward_name = reward if isinstance(reward, str) else getattr(
            reward, "__name__", "custom")
        self._reward_fn = get_reward(reward)
        self.n_actions = n_actions
        self.window = max(1, min(window, n_actions))
        self.expert_steps = expert_steps
        self._agent_kw = dict(alpha=alpha, gamma=gamma,
                              alpha_decay=alpha_decay, decay_mode=decay_mode)
        self._agent_cls = QLearnAgent if agent.lower() == "qlearn" \
            else SarsaAgent
        self._expert = ExpertPolicy(n_actions=n_actions)
        self.agent = None
        self.actions: List[int] = []    # RL-local index → portfolio index
        self._t = 0

    @property
    def learning_steps(self) -> int:
        return self.expert_steps + self.window * self.window

    @property
    def learning(self) -> bool:
        return self._t < self.learning_steps

    def _build_agent(self) -> None:
        """Bound the action set to a window around the expert's final ladder
        position and seed the Q-table toward its pick."""
        center = self._expert.current
        lo = int(np.clip(center - self.window // 2, 0,
                         self.n_actions - self.window))
        self.actions = list(range(lo, lo + self.window))
        self.agent = self._agent_cls(n_actions=self.window,
                                     initial_state=self.actions.index(
                                         min(self.actions,
                                             key=lambda a: abs(a - center))),
                                     **self._agent_kw)
        # seed: the expert's pick starts strictly above the 0-initialized
        # alternatives, so post-exploration greedy ties break toward it
        self.agent.q[:, self.actions.index(center) if center in self.actions
                     else 0] = REWARD_POSITIVE

    def decide(self) -> Decision:
        if self._t < self.expert_steps:
            d = self._expert.decide()
            return Decision(action=d.action, phase="expert",
                            confidence=d.confidence)
        if self.agent is None:
            self._build_agent()
        a_local = self.agent.select()
        phase = "explore" if self.agent.learning else "exploit"
        return Decision(action=self.actions[a_local], phase=phase,
                        confidence=0.0 if self.agent.learning else 1.0)

    def feedback(self, decision: Decision, obs: Observation) -> None:
        if self._t < self.expert_steps:
            self._expert.feedback(decision, obs)
            self._t += 1
            return
        if self.agent is None:
            self._build_agent()
        if decision.action in self.actions:
            a_local = self.actions.index(decision.action)
            self.agent.observe(a_local, self._reward_fn(obs))
        self._t += 1

    def state_dict(self) -> Optional[dict]:
        if self.agent is None:
            return None     # still in the expert phase: nothing worth keeping
        return {"kind": self.name, "reward": self.reward_name,
                "n_actions": self.n_actions, "t": self._t,
                "actions": list(self.actions),
                "agent": self.agent.state_dict()}

    def load_state_dict(self, state: dict, *,
                        skip_learning: bool = True) -> bool:
        # validate and restore into locals first: a corrupt snapshot must
        # leave the policy untouched (a half-assigned self.agent would
        # silently disable the expert-driven window rebuild)
        if int(state.get("n_actions", -1)) != self.n_actions:
            raise ValueError(
                f"snapshot was taken on a portfolio of "
                f"{state.get('n_actions')} actions, not {self.n_actions}; "
                f"its expert-bounded window would exclude the new actions")
        actions = [int(a) for a in state["actions"]]
        if not actions or any(a < 0 or a >= self.n_actions for a in actions):
            raise ValueError(f"stored action window {actions} is outside "
                             f"this portfolio (n_actions={self.n_actions})")
        agent = self._agent_cls(n_actions=len(actions), **self._agent_kw)
        agent.load_state_dict(state["agent"], skip_learning=skip_learning)
        self.actions = actions
        self.window = len(actions)
        self.agent = agent
        # the snapshot was taken post-expert-phase; keep the instance
        # counter consistent with the restored agent's position
        self._t = self.expert_steps + agent._t
        return not self.learning


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def _pick(kw: Dict, *names: str) -> Dict:
    return {k: v for k, v in kw.items() if k in names}


def _reward_kw(kw: Dict) -> Dict:
    """Honour both the new ``reward=`` spelling and legacy ``reward_type=``."""
    out = {}
    reward = kw.get("reward", kw.get("reward_type"))
    if reward is not None:
        out["reward"] = reward
    return out


def make_policy(name: str, **kw) -> SelectionPolicy:
    """Build any selection policy by name (case-insensitive).

    Unknown keyword arguments are ignored per-policy, so one call site can
    pass a uniform kwargs dict for every method string it accepts."""
    name = name.lower()
    if name in ("fixed",):
        return FixedPolicy(kw["algorithm"])
    if name in ("randomsel", "random"):
        return RandomPolicy(seed=kw.get("seed", 0),
                            initial=kw.get("initial", 0),
                            n_actions=kw.get("n_actions", N_ALGORITHMS))
    if name in ("exhaustivesel", "exhaustive"):
        return ExhaustivePolicy(**_pick(kw, "lib_retrigger", "min_samples",
                                        "n_actions"))
    if name in ("expertsel", "expert"):
        return ExpertPolicy(**_pick(kw, "n_actions"))
    if name in ("qlearn", "q-learn", "q_learn"):
        return QLearnPolicy(**_pick(kw, "alpha", "gamma", "alpha_decay",
                                    "decay_mode", "initial", "n_actions"),
                            **_reward_kw(kw))
    if name in ("sarsa",):
        return SarsaPolicy(**_pick(kw, "alpha", "gamma", "alpha_decay",
                                   "decay_mode", "initial", "n_actions"),
                           **_reward_kw(kw))
    if name in ("hybrid", "hybridsel", "expert+rl", "expertrl"):
        return HybridPolicy(**_pick(kw, "agent", "expert_steps", "window",
                                    "alpha", "gamma", "alpha_decay",
                                    "decay_mode", "n_actions"),
                            **_reward_kw(kw))
    if name in ("oracle",):
        return OraclePolicy(kw["best_fn"])
    # simulation-assisted methods (SimAS-style; repro.core.simpolicy) —
    # imported lazily, simpolicy imports the policies defined above; the
    # alias table lives there so is_sim_policy() and this factory agree
    from .simpolicy import _SIM_ALIASES, SimAssistedHybrid, SimPolicy
    canon = _SIM_ALIASES.get(name)
    if canon is not None:
        if "simulator" not in kw:
            raise ValueError(
                f"policy {name!r} needs a simulator= candidate pricer "
                f"(LoopWhatIf / WaveWhatIf / PlanWhatIf)")
        if canon in ("SimPolicy", "ReactiveSim", "AwareSim"):
            # AwareSim is a plain SimPolicy; its two-pass adaptive-surrogate
            # pricing lives in the lane's LoopWhatIf (campaign wiring keys on
            # the selector name)
            return SimPolicy(kw["simulator"],
                             reactive=(canon == "ReactiveSim"),
                             **_pick(kw, "candidates",
                                     "confidence_threshold", "n_actions",
                                     "fidelity_alpha", "detector"),
                             **_reward_kw(kw))
        return SimAssistedHybrid(kw["simulator"],
                                 reactive=(canon == "ReactiveHybrid"),
                                 **_pick(kw, "top_k", "agent", "expert_steps",
                                         "window", "alpha", "gamma",
                                         "alpha_decay", "decay_mode",
                                         "n_actions", "detector"),
                                 **_reward_kw(kw))
    # offline-trained learned methods (repro.core.learned) — lazily
    # imported for the same reason; weights default to the process-wide
    # state (set_default_state / REPRO_LEARNED_STATE), cold policies fall
    # back to the expert ladder
    from .learned import _LEARNED_ALIASES, LearnedHybrid, LearnedPolicy
    canon = _LEARNED_ALIASES.get(name)
    if canon is not None:
        if canon == "Learned":
            return LearnedPolicy(**_pick(kw, "featurizer", "state",
                                         "n_actions", "horizon"),
                                 **_reward_kw(kw))
        return LearnedHybrid(**_pick(kw, "featurizer", "state", "top_k",
                                     "horizon", "agent", "expert_steps",
                                     "window", "alpha", "gamma",
                                     "alpha_decay", "decay_mode",
                                     "n_actions"),
                             **_reward_kw(kw))
    raise ValueError(f"unknown selection policy {name!r}; "
                     f"choose from {POLICY_NAMES}")


# ---------------------------------------------------------------------------
# DEPRECATED scalar shims — the pre-redesign ``select()/observe()`` surface.
# Kept so external callers and the original paper scripts keep working; new
# code should use ``make_policy`` / ``SelectionService.instance``.
# ---------------------------------------------------------------------------

class Selector:
    """Deprecated adapter: wraps a :class:`SelectionPolicy` behind the old
    ``select() -> int`` / ``observe(action, loop_time, lib)`` protocol."""

    name = "base"
    #: number of instances the method needs before it commits to a selection
    learning_steps = 0

    def __init__(self, policy: Optional[SelectionPolicy] = None):
        self.policy = policy
        if policy is not None:
            self.name = policy.name
            self.learning_steps = policy.learning_steps

    def select(self) -> int:
        if self.policy is None:  # pragma: no cover - abstract base
            raise NotImplementedError
        return self.policy.decide().action

    def observe(self, action: int, loop_time: float, lib: float) -> None:
        if self.policy is not None:
            self.policy.feedback(
                Decision(action=int(action)),
                Observation(loop_time=float(loop_time), lib=float(lib)))


class FixedSel(Selector):
    name = "Fixed"

    def __init__(self, algorithm: int):
        super().__init__(FixedPolicy(algorithm))
        self.algorithm = int(algorithm)


class OracleSel(Selector):
    name = "Oracle"

    def __init__(self, best_fn: Callable[[int], int]):
        super().__init__(OraclePolicy(best_fn))


class RandomSel(Selector):
    """Keeps the pre-redesign semantics exactly: the jump is rolled on every
    ``select()`` call and ``observe`` only updates the LIB signal.  The
    policy constructor already rolled once (for the first instance), so the
    first ``select()`` skips its roll — the RNG stream, and therefore every
    seeded trajectory, is identical to the original implementation."""

    name = "RandomSel"

    def __init__(self, seed: int = 0, initial: int = 0,
                 n_actions: int = N_ALGORITHMS):
        super().__init__(RandomPolicy(seed=seed, initial=initial,
                                      n_actions=n_actions))
        self._rolled = True     # the constructor's roll covers select() #1

    def select(self) -> int:
        if self._rolled:
            self._rolled = False
        else:
            self.policy._jump()
        return self.policy.current

    def observe(self, action: int, loop_time: float, lib: float) -> None:
        self.policy._lib = float(lib)


class ExhaustiveSel(Selector):
    name = "ExhaustiveSel"

    def __init__(self, lib_retrigger: float = 0.10, min_samples: int = 3,
                 n_actions: int = N_ALGORITHMS):
        super().__init__(ExhaustivePolicy(lib_retrigger=lib_retrigger,
                                          min_samples=min_samples,
                                          n_actions=n_actions))


class ExpertSel(Selector):
    name = "ExpertSel"

    def __init__(self):
        super().__init__(ExpertPolicy())


class QLearnSel(Selector):
    name = "QLearn"

    def __init__(self, reward_type: str = "LT", **kw):
        super().__init__(make_policy("qlearn", reward=reward_type, **kw))
        self.reward_type = reward_type
        self.agent = self.policy.agent


class SarsaSel(Selector):
    name = "SARSA"

    def __init__(self, reward_type: str = "LT", **kw):
        super().__init__(make_policy("sarsa", reward=reward_type, **kw))
        self.reward_type = reward_type
        self.agent = self.policy.agent


def make_selector(name: str, **kw) -> Selector:
    """Deprecated: build a scalar-protocol ``Selector``.  Use
    ``make_policy`` (or ``SelectionService``) instead."""
    warnings.warn("make_selector() is deprecated; use make_policy() or "
                  "SelectionService.instance()", DeprecationWarning,
                  stacklevel=2)
    name_l = name.lower()
    if name_l in ("fixed",):
        return FixedSel(kw["algorithm"])
    if name_l in ("oracle",):
        return OracleSel(kw["best_fn"])
    if name_l in ("randomsel", "random"):
        return RandomSel(seed=kw.get("seed", 0),
                         n_actions=kw.get("n_actions", N_ALGORITHMS))
    if name_l in ("qlearn", "q-learn", "q_learn"):
        return QLearnSel(reward_type=kw.get("reward_type",
                                            kw.get("reward", "LT")),
                         **_pick(kw, "alpha", "gamma", "alpha_decay",
                                 "decay_mode", "n_actions"))
    if name_l in ("sarsa",):
        return SarsaSel(reward_type=kw.get("reward_type",
                                           kw.get("reward", "LT")),
                        **_pick(kw, "alpha", "gamma", "alpha_decay",
                                "decay_mode", "n_actions"))
    return Selector(make_policy(name, **kw))
