"""Scheduling-algorithm selection methods (paper §3.2-3.5).

Uniform interface so the simulator, serving dispatcher and step-plan
autotuner can drive any of them:

    sel = make_selector("QLearn", reward_type="LT", seed=0)
    for t in range(T):
        a = sel.select()                 # portfolio index for instance t
        lt, lib = execute(a)             # run the loop / step / round
        sel.observe(a, loop_time=lt, lib=lib)

Expert-based:  RandomSel, ExhaustiveSel, ExpertSel   [25]
RL-based:      QLearn, SARSA                         (this paper)
References:    Fixed (single algorithm), Oracle (offline per-instance best)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from .agents import QLearnAgent, SarsaAgent
from .fuzzy import make_diff_system, make_initial_system
from .portfolio import N_ALGORITHMS

SELECTOR_NAMES = ["Fixed", "RandomSel", "ExhaustiveSel", "ExpertSel",
                  "QLearn", "SARSA", "Oracle"]


class Selector:
    name = "base"
    #: number of instances the method needs before it commits to a selection
    learning_steps = 0

    def select(self) -> int:  # pragma: no cover
        raise NotImplementedError

    def observe(self, action: int, loop_time: float, lib: float) -> None:
        pass


class FixedSel(Selector):
    """Always the same algorithm — used for per-algorithm campaign runs."""

    name = "Fixed"

    def __init__(self, algorithm: int):
        self.algorithm = int(algorithm)

    def select(self) -> int:
        return self.algorithm


class OracleSel(Selector):
    """Paper §3.3: manually derived per-instance best (offline exhaustive).
    ``best_fn(t)`` maps instance index → portfolio index."""

    name = "Oracle"

    def __init__(self, best_fn: Callable[[int], int]):
        self._best = best_fn
        self._t = 0

    def select(self) -> int:
        return int(self._best(self._t))

    def observe(self, action, loop_time, lib):
        self._t += 1


class RandomSel(Selector):
    """[25]: jump probability P_j = LIB / 10; if P_j > RND(0,1) pick a random
    algorithm, else keep the current one.  LIB > 10 % → always switch."""

    name = "RandomSel"

    def __init__(self, seed: int = 0, initial: int = 0,
                 n_actions: int = N_ALGORITHMS):
        self.rng = np.random.default_rng(seed)
        self.current = int(initial)
        self.n_actions = n_actions
        self._lib = 100.0  # force an exploratory jump on the first instance

    def select(self) -> int:
        if self._lib / 10.0 > self.rng.random():
            self.current = int(self.rng.integers(0, self.n_actions))
        return self.current

    def observe(self, action, loop_time, lib):
        self._lib = float(lib)


class ExhaustiveSel(Selector):
    """[25]: one instance per portfolio algorithm (in order), then argmin of
    the recorded times.  LIB is monitored after selection; a >10 % deviation
    from the recorded average re-triggers the search."""

    name = "ExhaustiveSel"
    learning_steps = N_ALGORITHMS

    def __init__(self, lib_retrigger: float = 0.10, min_samples: int = 3,
                 n_actions: int = N_ALGORITHMS):
        self.n_actions = n_actions
        self.learning_steps = n_actions
        self._times = np.full(n_actions, np.inf)
        self._phase = 0                 # next algorithm to try
        self._selected: Optional[int] = None
        self._lib_sum = 0.0
        self._lib_cnt = 0
        self._retrigger = lib_retrigger
        self._min_samples = min_samples

    def select(self) -> int:
        if self._selected is None:
            return self._phase
        return self._selected

    def observe(self, action, loop_time, lib):
        if self._selected is None:
            self._times[action] = loop_time
            self._phase += 1
            if self._phase >= self.n_actions:
                self._selected = int(np.argmin(self._times))
                self._lib_sum = self._lib_cnt = 0
            return
        # monitoring phase
        self._lib_cnt += 1
        self._lib_sum += lib
        avg = self._lib_sum / self._lib_cnt
        if (self._lib_cnt >= self._min_samples and avg > 1.0
                and abs(lib - avg) > self._retrigger * avg):
            # high-imbalance drift: reassess the portfolio
            self._times[:] = np.inf
            self._phase = 0
            self._selected = None


class ExpertSel(Selector):
    """[25]: fuzzy-logic selection.  First instance runs STATIC to baseline
    T_par and LIB; the second instance uses the *absolute* fuzzy system; later
    instances use the *differential* system on (dT_par, dLIB) to move along
    the portfolio's adaptivity ladder."""

    name = "ExpertSel"
    learning_steps = 1

    def __init__(self):
        self._initial = make_initial_system()
        self._diff = make_diff_system()
        self.current = 0            # DLS_0 = STATIC
        self._t = 0
        self._first_time: Optional[float] = None
        self._prev_time: Optional[float] = None
        self._prev_lib: Optional[float] = None

    def select(self) -> int:
        return self.current

    def observe(self, action, loop_time, lib):
        if self._t == 0:
            self._first_time = loop_time
            ladder = self._initial.infer(lib, 1.0)
            self.current = int(np.clip(round(ladder), 0, N_ALGORITHMS - 1))
        else:
            dT = loop_time / max(self._prev_time, 1e-12) - 1.0
            dLIB = lib - self._prev_lib
            step = self._diff.infer(dT, dLIB)
            self.current = int(np.clip(round(self.current + step),
                                       0, N_ALGORITHMS - 1))
        self._prev_time = loop_time
        self._prev_lib = lib
        self._t += 1


class _RLSel(Selector):
    agent_cls = None

    def __init__(self, reward_type: str = "LT", alpha: float = 0.5,
                 gamma: float = 0.5, alpha_decay: float = 0.05,
                 decay_mode: str = "subtractive", initial: int = 0,
                 n_actions: int = N_ALGORITHMS):
        assert reward_type in ("LT", "LIB"), reward_type
        self.reward_type = reward_type
        self.agent = self.agent_cls(n_actions=n_actions, alpha=alpha,
                                    gamma=gamma, alpha_decay=alpha_decay,
                                    decay_mode=decay_mode,
                                    initial_state=initial)
        self.learning_steps = self.agent.learning_steps  # 144

    def select(self) -> int:
        return self.agent.select()

    def observe(self, action, loop_time, lib):
        x = loop_time if self.reward_type == "LT" else lib
        self.agent.observe(action, x)


class QLearnSel(_RLSel):
    name = "QLearn"
    agent_cls = QLearnAgent


class SarsaSel(_RLSel):
    name = "SARSA"
    agent_cls = SarsaAgent


def make_selector(name: str, **kw) -> Selector:
    name = name.lower()
    if name in ("fixed",):
        return FixedSel(kw["algorithm"])
    if name in ("randomsel", "random"):
        return RandomSel(seed=kw.get("seed", 0),
                         n_actions=kw.get("n_actions", N_ALGORITHMS))
    if name in ("exhaustivesel", "exhaustive"):
        return ExhaustiveSel(**{k: v for k, v in kw.items()
                                if k in ("lib_retrigger", "min_samples",
                                         "n_actions")})
    if name in ("expertsel", "expert"):
        return ExpertSel()
    if name in ("qlearn", "q-learn", "q_learn"):
        return QLearnSel(**{k: v for k, v in kw.items()
                            if k in ("reward_type", "alpha", "gamma",
                                     "alpha_decay", "decay_mode",
                                     "n_actions")})
    if name in ("sarsa",):
        return SarsaSel(**{k: v for k, v in kw.items()
                           if k in ("reward_type", "alpha", "gamma",
                                    "alpha_decay", "decay_mode",
                                    "n_actions")})
    if name in ("oracle",):
        return OracleSel(kw["best_fn"])
    raise ValueError(f"unknown selector {name!r}")
