"""Per-region selection service (LB4OMP's loop-id mechanism, paper §3.1/§3.5).

LB4OMP assigns a unique id to every ``schedule(runtime)`` loop so that each
loop learns independently.  ``SelectionService`` mirrors that — callers
register a region id (an OpenMP loop in the simulator, a jitted step in the
autotuner, a dispatch queue in serving) and get an isolated
:class:`~repro.core.api.SelectionPolicy` — and adds the two paper
extensions the old begin/end registry could not reach:

* **structured instances** — the context-manager API hands out a
  :class:`Decision` and accepts a full :class:`Observation`::

      service = SelectionService("Hybrid", reward="LT")
      with service.instance("gravity") as inst:
          a = inst.action                  # or inst.decision for phase etc.
          res = execute(a)
          inst.report(loop_time=res.loop_time, lib=res.lib)

* **per-region policy overrides** — heterogeneous regions can run
  different methods under one service (``overrides={"io_loop": {"method":
  "ExhaustiveSel"}}`` or ``service.set_policy(region, "SARSA", ...)``);

* **automatic Q-table warm start (paper §5)** — with ``store_dir`` set,
  region policies are restored from disk keyed by (region, system
  fingerprint) when first touched, and persisted by ``save()`` (or on exit
  when the service is used as a context manager).  A restored Q-Learn /
  SARSA / Hybrid region skips its explore-first phase entirely — the
  paper's 28.8 % exploration cost drops to zero on re-runs.

The pre-redesign ``begin(region) -> int`` / ``end(region, action, lt, lib)``
calls survive as deprecated shims over the same machinery.
"""

from __future__ import annotations

import zlib
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Dict, Hashable, List, Optional, Tuple

from .api import Decision, Observation, SelectionPolicy, make_policy
from .persistence import (load_policy_state, save_policy_state,
                          system_fingerprint)
from .simpolicy import resolve_sim_policy


def _stable_region_seed(seed: int, region: Hashable) -> int:
    """De-correlate per-region RNG streams *reproducibly*: ``hash()`` of a
    string varies per process under salted hashing, so use a stable CRC-32
    digest of the region id instead."""
    digest = zlib.crc32(repr(region).encode("utf-8"))
    return (int(seed) * 0x9E3779B1 + digest) % (2 ** 31)


#: full Observations kept per region for introspection are bounded to this
#: window (they can carry per-PE time vectors); ``history`` keeps only the
#: compact (action, loop_time, lib) tuple per instance and is deliberately
#: unbounded — campaign-length consumers read it in full.
OBSERVATION_WINDOW = 1024


@dataclass
class RegionRecord:
    policy: SelectionPolicy
    history: List[Tuple[int, float, float]] = field(default_factory=list)
    # (chosen algorithm, loop_time, lib) per instance
    observations: "deque[Observation]" = field(
        default_factory=lambda: deque(maxlen=OBSERVATION_WINDOW))
    instances: int = 0
    warm_started: bool = False


class RegionInstance:
    """One region instance: a decision to act on, and a place to report the
    outcome.  Created by ``SelectionService.instance``; committing the
    feedback happens on ``__exit__`` (or an explicit ``close()``)."""

    def __init__(self, service: "SelectionService", region: Hashable,
                 record: RegionRecord):
        self._service = service
        self._region = region
        self._record = record
        self.decision: Decision = record.policy.decide()
        self._obs: Optional[Observation] = None
        self._done = False

    @property
    def region(self) -> Hashable:
        return self._region

    @property
    def action(self) -> int:
        return self.decision.action

    def report(self, loop_time: Optional[float] = None,
               lib: Optional[float] = None, *,
               throughput: Optional[float] = None,
               tail_latency: Optional[float] = None,
               pe_times=None, observation: Optional[Observation] = None
               ) -> Observation:
        """Attach the measured outcome.  Either pass a ready-made
        ``observation`` or the individual signals; ``pe_times`` alone is
        enough (makespan / Eq. 8 LIB / p95 are derived, but any signal the
        caller supplies explicitly wins over the derived value)."""
        if observation is not None:
            if observation.instance < 0:
                observation = replace(observation,
                                      instance=self._record.instances)
            self._obs = observation
        elif pe_times is not None:
            extra = {"throughput": throughput,
                     "instance": self._record.instances}
            if loop_time is not None:
                extra["loop_time"] = float(loop_time)
            if lib is not None:
                extra["lib"] = float(lib)
            if tail_latency is not None:
                extra["tail_latency"] = tail_latency
            self._obs = Observation.from_pe_times(pe_times, **extra)
        else:
            if loop_time is None:
                raise ValueError("report() needs loop_time, pe_times, or a "
                                 "full observation")
            self._obs = Observation(
                loop_time=float(loop_time),
                lib=0.0 if lib is None else float(lib),
                throughput=throughput, tail_latency=tail_latency,
                pe_times=None if pe_times is None else tuple(pe_times),
                instance=self._record.instances)
        return self._obs

    def close(self) -> None:
        """Commit the feedback (no-op if nothing was reported — the decision
        is then treated as a peek, like the old lone ``begin()``)."""
        if self._done or self._obs is None:
            self._done = True
            return
        self._done = True
        self._service._complete(self._region, self.decision, self._obs)

    def __enter__(self) -> "RegionInstance":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()


class SelectionService:
    """Multiplexes independent selection policies over region ids."""

    def __init__(self, method: Optional[str] = None,
                 reward: Optional[str] = None,
                 store_dir: Optional[str] = None,
                 system: Optional[str] = None,
                 overrides: Optional[Dict[Hashable, Dict]] = None,
                 **policy_kw):
        # no explicit method: honour the REPRO_SIM_POLICY env override (a
        # simulation-assisted default needs a ``simulator=`` in policy_kw)
        self._method = method if method is not None \
            else resolve_sim_policy("QLearn")
        self._kw = dict(policy_kw)
        if reward is not None:
            self._kw["reward"] = reward
        self._regions: Dict[Hashable, RegionRecord] = {}
        self._overrides: Dict[Hashable, Dict] = dict(overrides or {})
        self.store_dir = store_dir
        self.system = system or system_fingerprint()

    # -- region setup -------------------------------------------------------
    def set_policy(self, region: Hashable, method: str, **kw) -> None:
        """Override the policy for one region (before its first instance)."""
        if region in self._regions:
            raise ValueError(f"region {region!r} already has a live policy")
        self._overrides[region] = {"method": method, **kw}

    def _record(self, region: Hashable) -> RegionRecord:
        if region not in self._regions:
            spec = dict(self._overrides.get(region, {}))
            method = spec.pop("method", self._method)
            kw = {**self._kw, **spec}
            if "seed" in kw:
                kw["seed"] = _stable_region_seed(kw["seed"], region)
            rec = RegionRecord(policy=make_policy(method, **kw))
            if self.store_dir is not None:
                try:
                    stored = load_policy_state(self.store_dir, str(region),
                                               self.system)
                except (ValueError, OSError, TypeError):
                    stored = None       # corrupt/unreadable snapshot
                rec.warm_started = self._try_warm_start(rec.policy, stored)
            self._regions[region] = rec
        return self._regions[region]

    @staticmethod
    def _try_warm_start(policy: SelectionPolicy,
                        stored: Optional[Dict]) -> bool:
        """Restore ``policy`` from a stored record only when it is actually
        compatible: same method, same reward objective, same table shape.
        Any mismatch (e.g. the plan portfolio grew since the snapshot) is a
        cache miss — start cold rather than exploit a stale table."""
        if stored is None or stored.get("method") != policy.name:
            return False
        state = stored.get("state") or {}
        want = getattr(policy, "reward_name", None)
        got = state.get("reward")
        if want is not None and got is not None and \
                str(got).lower() != str(want).lower():
            return False
        try:
            return policy.load_state_dict(state)
        except (KeyError, ValueError, TypeError):
            return False

    # -- the instance API ---------------------------------------------------
    def instance(self, region: Hashable) -> RegionInstance:
        """Open one region instance; use as a context manager (feedback is
        committed on exit once ``report`` was called)."""
        return RegionInstance(self, region, self._record(region))

    def _complete(self, region: Hashable, decision: Decision,
                  obs: Observation) -> None:
        rec = self._regions[region]
        rec.policy.feedback(decision, obs)
        rec.history.append((decision.action, obs.loop_time, obs.lib))
        rec.observations.append(obs)
        rec.instances += 1

    # -- introspection ------------------------------------------------------
    def policy(self, region: Hashable) -> SelectionPolicy:
        """The region's policy — instantiated (and warm-started, with a
        store_dir) on first touch, so peeking ``policy(r).decide()`` works
        before any instance runs."""
        return self._record(region).policy

    def warm_started(self, region: Hashable) -> bool:
        return self._record(region).warm_started

    def history(self, region: Hashable):
        """Read-only: empty for regions that never ran an instance (does not
        instantiate the region's policy as a side effect)."""
        rec = self._regions.get(region)
        return rec.history if rec is not None else []

    @property
    def regions(self):
        return list(self._regions)

    # -- persistence (paper §5) ---------------------------------------------
    def save(self) -> List[str]:
        """Persist every stateful region policy, keyed by (region, system
        fingerprint).  Returns the written paths."""
        if self.store_dir is None:
            raise ValueError("SelectionService was created without store_dir")
        paths = []
        for region, rec in self._regions.items():
            state = rec.policy.state_dict()
            if state is None:
                continue
            paths.append(save_policy_state(
                {"method": rec.policy.name, "state": state,
                 "instances": rec.instances},
                self.store_dir, str(region), self.system))
        return paths

    def __enter__(self) -> "SelectionService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and self.store_dir is not None:
            self.save()

    # -- DEPRECATED scalar shims --------------------------------------------
    def begin(self, region: Hashable) -> int:
        """Deprecated: use ``instance``.  Returns the portfolio (or plan)
        index to use for the next region instance."""
        return self._record(region).policy.decide().action

    def end(self, region: Hashable, action: int, loop_time: float,
            lib: float) -> None:
        """Deprecated: use ``instance``/``report``."""
        rec = self._record(region)
        self._complete(region, Decision(action=int(action)),
                       Observation(loop_time=float(loop_time),
                                   lib=float(lib),
                                   instance=rec.instances))
