"""Per-loop selection registry (LB4OMP's loop-id mechanism, paper §3.1/§3.5).

LB4OMP assigns a unique id to every ``schedule(runtime)`` loop so that each
loop learns independently.  ``SelectionService`` mirrors that: callers
register a region id (an OpenMP loop in the simulator, a jitted step in the
autotuner, a dispatch queue in serving) and get an isolated selector.

This is the init-hook analogue of ``kmp_agent_provider.cpp`` being called
from ``kmp_dispatch.cpp`` before every loop execution.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Tuple

from .selectors import Selector, make_selector


@dataclass
class RegionRecord:
    selector: Selector
    history: List[Tuple[int, float, float]] = field(default_factory=list)
    # (chosen algorithm, loop_time, lib) per instance


class SelectionService:
    """Multiplexes independent selectors over region ids."""

    def __init__(self, method: str = "QLearn", **selector_kw):
        self._method = method
        self._kw = dict(selector_kw)
        self._regions: Dict[Hashable, RegionRecord] = {}

    def _record(self, region: Hashable) -> RegionRecord:
        if region not in self._regions:
            kw = dict(self._kw)
            # de-correlate RandomSel streams across regions
            if "seed" in kw:
                kw["seed"] = hash((kw["seed"], region)) % (2 ** 31)
            self._regions[region] = RegionRecord(
                selector=make_selector(self._method, **kw))
        return self._regions[region]

    def begin(self, region: Hashable) -> int:
        """Called before executing a region instance; returns the portfolio
        index (or plan index) to use."""
        return self._record(region).selector.select()

    def end(self, region: Hashable, action: int, loop_time: float,
            lib: float) -> None:
        rec = self._record(region)
        rec.selector.observe(action, loop_time, lib)
        rec.history.append((action, loop_time, lib))

    def history(self, region: Hashable):
        return self._record(region).history

    @property
    def regions(self):
        return list(self._regions)
