"""Simulation-assisted selection (SimAS-style, Mohammed & Ciorba 2021).

The paper's RL and hybrid methods pay for exploration on live traffic: every
instance spent probing a bad scheduling algorithm is a real slowdown.  SimAS
removes that cost by pricing the candidate portfolio *in a simulator* and
executing only the predicted winner.  This module is that idea behind the
:class:`~repro.core.api.SelectionPolicy` protocol:

``SimPolicy``
    On every ``decide()``, price the full candidate set (all 12 portfolio
    algorithms, plus chunk-parameter variants) through one batched what-if
    call on the configured simulator, apply the registered reward to each
    predicted :class:`Observation`, and commit to the argmin.  When the
    simulator's predicted spread is below ``confidence_threshold`` — the
    candidates are indistinguishable, so the prediction carries no signal —
    fall back to the expert fuzzy ladder, which tracks the *live* (LT, LIB)
    trajectory through ``feedback``.

``SimAssistedHybrid``
    :class:`~repro.core.selectors.HybridPolicy` whose RL exploration window
    is pre-pruned by simulated cost: instead of the expert ladder's
    neighbourhood, the agent explores only the simulator's predicted top-k
    algorithms (the Oracle pick of a noise-free simulator is always inside
    the pruned set).  Exploration drops from the full 144-instance grid to
    ``expert_steps + top_k**2`` instances.

A *candidate simulator* is anything with::

    candidates() -> Sequence[Candidate]          # what can be priced now
    price(cands) -> Sequence[Observation] | array of predicted loop times

Concrete simulators live next to their execution layers:
``repro.sim.whatif.LoopWhatIf`` (DES loop instances),
``repro.serving.engine.WaveWhatIf`` (dispatch waves via
``DispatchSimulator.what_if``), and
``repro.distributed.autotune.PlanWhatIf`` (calibrated step-plan cost model).
A simulator that cannot price yet (no context bound) raises
:class:`SimUnavailable`; the policies degrade to their live fallbacks.

``REPRO_SIM_POLICY`` names the sim-assisted method consumers should default
to (e.g. ``SimPolicy`` / ``SimHybrid``): ``SelectionService``,
``DispatchSimulator`` and ``StepAutoTuner`` resolve it when no explicit
method is given, so a whole campaign can be flipped to simulation-assisted
selection from the environment.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from .api import Decision, Observation, SelectionPolicy, get_reward
from .drift import PageHinkley
from .portfolio import N_ALGORITHMS
from .rewards import REWARD_POSITIVE
from .selectors import ExpertPolicy, HybridPolicy

__all__ = [
    "Candidate", "SimUnavailable", "SimPolicy", "SimAssistedHybrid",
    "SIM_POLICY_ENV", "resolve_sim_policy", "is_sim_policy",
    "SIM_POLICY_NAMES",
]

#: env var naming the default simulation-assisted method ("SimPolicy",
#: "SimHybrid"); consumers resolve it through :func:`resolve_sim_policy`.
SIM_POLICY_ENV = "REPRO_SIM_POLICY"

#: canonical registry spellings (``make_policy`` accepts these, lowercased).
#: The ``Reactive*`` variants re-price / re-prune when a drift detector fires
#: on the live stream; ``AwareSim`` is a plain SimPolicy whose campaign lane
#: prices through a two-pass adaptive-surrogate what-if (the lane wiring in
#: ``repro.sim.campaign`` switches on this name).
SIM_POLICY_NAMES = ["SimPolicy", "SimHybrid", "ReactiveSim",
                    "ReactiveHybrid", "AwareSim"]

_SIM_ALIASES = {
    "simpolicy": "SimPolicy", "sim": "SimPolicy", "simsel": "SimPolicy",
    "simas": "SimPolicy",
    "simhybrid": "SimHybrid", "sim-hybrid": "SimHybrid",
    "simassistedhybrid": "SimHybrid",
    "reactivesim": "ReactiveSim", "simreact": "ReactiveSim",
    "reactivesimpolicy": "ReactiveSim",
    "reactivehybrid": "ReactiveHybrid", "simhybridreact": "ReactiveHybrid",
    "reactivesimhybrid": "ReactiveHybrid",
    "awaresim": "AwareSim", "simaware": "AwareSim",
    "adaptivesim": "AwareSim",
}


def is_sim_policy(name: Optional[str]) -> bool:
    """True when ``name`` spells one of the simulation-assisted methods."""
    return isinstance(name, str) and name.lower() in _SIM_ALIASES


def resolve_sim_policy(default: Optional[str] = None) -> Optional[str]:
    """The method consumers should build when none was requested: the
    ``REPRO_SIM_POLICY`` env override if set (canonicalized), else
    ``default``.  A value that spells no sim policy is rejected HERE — the
    env var is read far from the shell that set it, so the eventual
    unknown-policy error would never mention it."""
    import os
    name = os.environ.get(SIM_POLICY_ENV)
    if not name:
        return default
    canon = _SIM_ALIASES.get(name.lower())
    if canon is None:
        raise ValueError(
            f"{SIM_POLICY_ENV}={name!r} names no simulation-assisted "
            f"policy; valid spellings: {sorted(_SIM_ALIASES)}")
    return canon


@dataclass(frozen=True)
class Candidate:
    """One entry of a what-if pricing request: a portfolio algorithm and an
    optional chunk-parameter variant (``None`` = the caller's default, the
    same convention as :class:`~repro.core.api.Decision.chunk_param`)."""

    alg: int
    chunk_param: Optional[int] = None


class SimUnavailable(RuntimeError):
    """Raised by a candidate simulator that cannot price right now (e.g. no
    loop/wave context bound yet).  Policies catch it and fall back to their
    live decision path."""


def _as_observations(priced) -> List[Observation]:
    """Normalize a simulator's output: either ready-made Observations or a
    bare array of predicted loop times."""
    if len(priced) and isinstance(priced[0], Observation):
        return list(priced)
    return [Observation(loop_time=float(t)) for t in np.asarray(priced)]


# ---------------------------------------------------------------------------
# SimPolicy — execute only the simulator's predicted winner
# ---------------------------------------------------------------------------

class SimPolicy(SelectionPolicy):
    """Price every candidate in simulation, run the argmin, learn nothing on
    live traffic.

    ``decide`` issues one batched pricing call over the simulator's candidate
    set and commits to the argmin under the registered reward.  The policy is
    stateless across instances apart from the embedded expert ladder, which
    digests every live observation so that the *fallback* (taken when the
    predicted spread is under ``confidence_threshold``, or when the simulator
    has no context) stays anchored to reality rather than to a cold start.
    """

    name = "SimPolicy"

    def __init__(self, simulator, reward="LT",
                 candidates: Optional[Sequence[Candidate]] = None,
                 confidence_threshold: float = 0.02,
                 n_actions: int = N_ALGORITHMS,
                 reactive: bool = False, fidelity_alpha: float = 0.35,
                 detector: Optional[PageHinkley] = None):
        self.simulator = simulator
        self.reward_name = reward if isinstance(reward, str) else getattr(
            reward, "__name__", "custom")
        self._reward_fn = get_reward(reward)
        self._candidates = list(candidates) if candidates is not None else None
        self.confidence_threshold = float(confidence_threshold)
        self.n_actions = n_actions
        self._fallback = ExpertPolicy(n_actions=n_actions)
        #: (predicted cost of the committed candidate, measured reward) per
        #: sim-driven instance — fidelity introspection for studies
        self.pred_log: List[tuple] = []
        self._last_pred: Optional[float] = None
        # --- reactive re-pricing (perturbation-aware variant) -------------
        self.reactive = bool(reactive)
        if self.reactive:
            self.name = "ReactiveSim"
        self.fidelity_alpha = float(fidelity_alpha)
        self.detector = detector if detector is not None else (
            PageHinkley() if self.reactive else None)
        #: per-(alg, chunk_param) EMA of measured/predicted cost — the live
        #: fidelity correction multiplying each candidate's simulated price
        self._corrections: dict = {}
        self._last_key: Optional[tuple] = None
        #: number of drift detections that flushed the correction table
        self.drift_events = 0

    def _candidate_set(self) -> List[Candidate]:
        if self._candidates is not None:
            return self._candidates
        cands = self.simulator.candidates() if hasattr(
            self.simulator, "candidates") else None
        if not cands:
            return [Candidate(a) for a in range(self.n_actions)]
        return list(cands)

    def decide(self) -> Decision:
        try:
            cands = self._candidate_set()
            priced = _as_observations(self.simulator.price(cands))
        except SimUnavailable:
            self._last_pred = None
            d = self._fallback.decide()
            return Decision(action=d.action, phase="expert", confidence=0.0)
        raw = np.array([self._reward_fn(o) for o in priced],
                       dtype=np.float64)
        costs = raw
        if self.reactive and self._corrections:
            # live surrogate-fidelity corrections: multiply each candidate's
            # simulated price by its measured/predicted EMA ratio
            costs = raw * np.array(
                [self._corrections.get((c.alg, c.chunk_param), 1.0)
                 for c in cands], dtype=np.float64)
        best = int(np.argmin(costs))
        lo, hi = float(costs[best]), float(costs.max())
        spread = (hi - lo) / max(abs(hi), 1e-12)
        if spread < self.confidence_threshold:
            # indistinguishable candidates: the prediction carries no signal
            d = self._fallback.decide()
            self._last_pred = None
            self._last_key = None
            return Decision(action=d.action, phase="expert",
                            confidence=d.confidence)
        # committed: confidence is the relative margin to the runner-up
        second = float(np.partition(costs, 1)[1]) if len(costs) > 1 else hi
        conf = float(np.clip((second - lo) / max(abs(second), 1e-12), 0, 1))
        # fidelity bookkeeping uses the RAW simulated price of the committed
        # candidate (corrections must calibrate against the simulator, not
        # against themselves)
        self._last_pred = float(raw[best])
        self._last_key = (cands[best].alg, cands[best].chunk_param)
        return Decision(action=cands[best].alg,
                        chunk_param=cands[best].chunk_param,
                        phase="exploit", confidence=conf)

    def feedback(self, decision: Decision, obs: Observation) -> None:
        # keep the fallback ladder tracking the live trajectory
        self._fallback.feedback(decision, obs)
        if self._last_pred is None:
            return
        pred, key = self._last_pred, self._last_key
        self._last_pred = None
        self._last_key = None
        measured = self._reward_fn(obs)
        self.pred_log.append((pred, measured))
        if not self.reactive or key is None:
            return
        if pred <= 0.0 or measured <= 0.0:
            return              # ratio undefined (e.g. signed rewards)
        ratio = measured / pred
        prev = self._corrections.get(key, 1.0)
        a = self.fidelity_alpha
        self._corrections[key] = (1.0 - a) * prev + a * ratio
        if self.detector is not None and self.detector.update(
                float(np.log(ratio))):
            # the world shifted: corrections learned before the drift are
            # stale for every candidate except the one just measured
            self._corrections = {key: self._corrections[key]}
            self.drift_events += 1


# ---------------------------------------------------------------------------
# SimAssistedHybrid — RL explores only the simulator's top-k
# ---------------------------------------------------------------------------

class SimAssistedHybrid(HybridPolicy):
    """Hybrid expert+RL whose exploration window is pruned by simulated cost.

    The expert phase runs unchanged (it also keeps the live baseline the
    differential fuzzy system needs); at agent-build time the full algorithm
    grid is priced in simulation and the RL agent's action set becomes the
    predicted top-``top_k`` — always a subset of the portfolio containing
    the simulator's argmin — with the Q-table seeded toward the predicted
    winner.  If the simulator cannot price (no context), the expert-window
    construction of :class:`HybridPolicy` applies unchanged."""

    name = "SimHybrid"

    def __init__(self, simulator, top_k: int = 4, expert_steps: int = 2,
                 reactive: bool = False,
                 detector: Optional[PageHinkley] = None, **kw):
        kw.setdefault("window", top_k)
        super().__init__(expert_steps=expert_steps, **kw)
        self.simulator = simulator
        self.top_k = max(1, min(int(top_k), self.n_actions))
        # --- reactive re-pruning (perturbation-aware variant) -------------
        self.reactive = bool(reactive)
        if self.reactive:
            self.name = "ReactiveHybrid"
        self.detector = detector if detector is not None else (
            PageHinkley() if self.reactive else None)
        self.drift_events = 0

    def _build_agent(self) -> None:
        try:
            cands = [Candidate(a) for a in range(self.n_actions)]
            priced = _as_observations(self.simulator.price(cands))
        except SimUnavailable:
            super()._build_agent()
            return
        costs = np.array([self._reward_fn(o) for o in priced],
                         dtype=np.float64)
        order = np.argsort(costs, kind="stable")
        best = int(order[0])
        self.actions = sorted(int(a) for a in order[: self.top_k])
        self.window = len(self.actions)
        self.agent = self._agent_cls(n_actions=self.window,
                                     initial_state=self.actions.index(best),
                                     **self._agent_kw)
        # seed: the predicted winner starts strictly above the 0-initialized
        # alternatives, so post-exploration greedy ties break toward it
        self.agent.q[:, self.actions.index(best)] = REWARD_POSITIVE

    def feedback(self, decision: Decision, obs: Observation) -> None:
        super().feedback(decision, obs)
        if not self.reactive or self.detector is None:
            return
        if self.agent is None or self.agent.learning:
            return              # still exploring: cost swings are expected
        if self.detector.update(self._reward_fn(obs)):
            # the exploitation-phase cost stream shifted: re-price the full
            # grid against the simulator's *current* context and re-prune the
            # exploration window (fresh agent, fresh Eulerian sweep)
            self._build_agent()
            self.drift_events += 1
