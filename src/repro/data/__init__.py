from .pipeline import DataConfig, TokenPipeline, Request, synthetic_requests
