from .pipeline import (DataConfig, TokenPipeline, Request, field_rng,
                       request_lengths, synthetic_requests)
