"""Deterministic synthetic data pipeline with O(1) resume.

Every batch is a pure function of (seed, step) — restart-safe by
construction: after a checkpoint restore at step k, ``batch_at(k)`` yields
bit-identical data with no stream replay.  A mixture sampler models
multi-corpus training; the request generator drives the serving engine with
heterogeneous-length requests (the L3 imbalance source).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic mixture: (name, weight, zipf exponent) per corpus
    mixture: Tuple[Tuple[str, float, float], ...] = (
        ("web", 0.6, 1.2), ("code", 0.3, 1.05), ("math", 0.1, 1.4))


class TokenPipeline:
    """Step-indexed synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        w = np.array([m[1] for m in cfg.mixture])
        self._weights = w / w.sum()
        self._exps = [m[2] for m in cfg.mixture]

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        corpus = rng.choice(len(self._weights), size=cfg.global_batch,
                            p=self._weights)
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        for i, c in enumerate(corpus):
            # zipf-ish marginal per corpus, shifted into the vocab
            r = rng.random((cfg.seq_len + 1,))
            z = np.floor((cfg.vocab_size - 1) * r ** self._exps[c])
            toks[i] = z.astype(np.int32) % cfg.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class Request:
    rid: int
    prompt_len: int
    gen_len: int
    arrival: float


def synthetic_requests(n: int, seed: int = 0, mean_prompt: int = 512,
                       mean_gen: int = 128, heavy_tail: float = 1.3,
                       arrival_rate: float = 64.0) -> List[Request]:
    """Heterogeneous serving workload: Pareto-tailed prompt/gen lengths (the
    'iteration cost imbalance' of the serving adaptation) with Poisson
    arrivals."""
    rng = np.random.default_rng(seed)
    prompts = np.minimum(
        (rng.pareto(heavy_tail, n) + 1.0) * mean_prompt * 0.4, 16384
    ).astype(int) + 8
    gens = np.minimum((rng.pareto(heavy_tail, n) + 1.0) * mean_gen * 0.4,
                      4096).astype(int) + 4
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n))
    return [Request(i, int(p), int(g), float(a))
            for i, (p, g, a) in enumerate(zip(prompts, gens, arrivals))]
