"""Deterministic synthetic data pipeline with O(1) resume.

Every batch is a pure function of (seed, step) — restart-safe by
construction: after a checkpoint restore at step k, ``batch_at(k)`` yields
bit-identical data with no stream replay.  A mixture sampler models
multi-corpus training; the request generator drives the serving engine with
heterogeneous-length requests (the L3 imbalance source).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic mixture: (name, weight, zipf exponent) per corpus
    mixture: Tuple[Tuple[str, float, float], ...] = (
        ("web", 0.6, 1.2), ("code", 0.3, 1.05), ("math", 0.1, 1.4))


class TokenPipeline:
    """Step-indexed synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        w = np.array([m[1] for m in cfg.mixture])
        self._weights = w / w.sum()
        self._exps = [m[2] for m in cfg.mixture]

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        corpus = rng.choice(len(self._weights), size=cfg.global_batch,
                            p=self._weights)
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        for i, c in enumerate(corpus):
            # zipf-ish marginal per corpus, shifted into the vocab
            r = rng.random((cfg.seq_len + 1,))
            z = np.floor((cfg.vocab_size - 1) * r ** self._exps[c])
            toks[i] = z.astype(np.int32) % cfg.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class Request:
    rid: int
    prompt_len: int
    gen_len: int
    arrival: float


def field_rng(seed: int, fieldname: str) -> np.random.Generator:
    """Named per-field RNG substream: ``(seed, crc32(field))`` entropy, the
    same stable-digest convention as ``SelectionService`` region seeds.

    Request generators draw every field (prompt lengths, gen lengths,
    arrival gaps) from its own substream so that adding, resizing, or
    re-parameterizing one field can never perturb the draws of another —
    ``synthetic_requests(2 * n)[:n]`` extends a workload without rewriting
    its history."""
    digest = zlib.crc32(fieldname.encode("utf-8"))
    return np.random.default_rng((int(seed), digest))


def request_lengths(n: int, seed: int, mean_prompt: int, mean_gen: int,
                    heavy_tail: float) -> Tuple[np.ndarray, np.ndarray]:
    """Pareto-tailed (prompt, gen) token counts — the 'iteration cost
    imbalance' source of the serving adaptation — drawn from the ``prompt``
    and ``gen`` field substreams (independent of any arrival process laid
    on top)."""
    prompts = np.minimum(
        (field_rng(seed, "prompt").pareto(heavy_tail, n) + 1.0)
        * mean_prompt * 0.4, 16384).astype(int) + 8
    gens = np.minimum(
        (field_rng(seed, "gen").pareto(heavy_tail, n) + 1.0)
        * mean_gen * 0.4, 4096).astype(int) + 4
    return prompts, gens


def synthetic_requests(n: int, seed: int = 0, mean_prompt: int = 512,
                       mean_gen: int = 128, heavy_tail: float = 1.3,
                       arrival_rate: float = 64.0,
                       arrivals: Optional[np.ndarray] = None
                       ) -> List[Request]:
    """Heterogeneous serving workload: Pareto-tailed prompt/gen lengths with
    Poisson arrivals (or caller-supplied ``arrivals`` — the fleet trace
    generators inject bursty/diurnal processes here).

    Each field draws from its own named substream (:func:`field_rng`), so
    prompt, gen, and arrival draws are mutually independent: resizing or
    re-parameterizing one field leaves the others bit-identical, and the
    per-seed streams are pinned by a golden regression test
    (``tests/test_fleet.py::test_synthetic_requests_golden``)."""
    prompts, gens = request_lengths(n, seed, mean_prompt, mean_gen,
                                    heavy_tail)
    if arrivals is None:
        arrivals = np.cumsum(
            field_rng(seed, "arrival").exponential(1.0 / arrival_rate, n))
    elif len(arrivals) != n:
        raise ValueError(f"arrivals has {len(arrivals)} entries for {n} "
                         "requests")
    return [Request(i, int(p), int(g), float(a))
            for i, (p, g, a) in enumerate(zip(prompts, gens, arrivals))]
