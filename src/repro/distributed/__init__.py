from .sharding import (param_specs, batch_specs, cache_specs, opt_specs,
                       named, data_axes, fit_spec)
from .autotune import (ExecutionPlan, DEFAULT_PLANS, PlanWhatIf,
                       StepAutoTuner, make_plan_builder)
from .compression import EFCompressor, compression_ratio
from .ctx import activation_sharding, constrain_boundary
