"""Step-plan autotuning — the paper's technique at TPU-step granularity (L2).

The OpenMP runtime chose a *scheduling algorithm* per loop instance; a JAX
runtime's equivalent degree of freedom is the *execution plan* of the
repeatedly-executed jitted step: activation-checkpoint policy, microbatch
count, attention implementation, sharding strategy, gradient compression.

``StepAutoTuner`` holds a portfolio of plans, compiles them lazily, and
drives any selection policy by name (explore-first Q-Learn / SARSA with the
Eq. 11 reward, ExhaustiveSel with its LIB re-trigger, RandomSel, and the
expert-seeded Hybrid) through ``SelectionService.instance`` with:

    LT  reward = measured wall-clock step time
    LIB reward = percent load imbalance over per-expert token loads (MoE) or
                 any per-worker load vector the step reports

This mirrors LB4OMP's loop registry: each region id (e.g. "train_step")
learns independently via ``SelectionService``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core import (SelectionService, is_sim_policy, percent_load_imbalance,
                    resolve_sim_policy)
from ..core.api import Observation
from ..core.simpolicy import Candidate
from ..configs.base import ModelConfig
from ..optim.adamw import AdamWConfig


@dataclass(frozen=True)
class ExecutionPlan:
    name: str
    microbatches: int = 1
    remat: bool = True
    attn_impl: str = "auto"
    fsdp: bool = True
    compress: Optional[str] = None     # None | "int8" | "topk"


DEFAULT_PLANS: Tuple[ExecutionPlan, ...] = (
    ExecutionPlan("mb1_remat", microbatches=1, remat=True),
    ExecutionPlan("mb2_remat", microbatches=2, remat=True),
    ExecutionPlan("mb4_remat", microbatches=4, remat=True),
    ExecutionPlan("mb1_noremat", microbatches=1, remat=False),
    ExecutionPlan("mb2_noremat", microbatches=2, remat=False),
)


class PlanWhatIf:
    """Calibrated analytic cost model over an execution-plan portfolio — the
    autotuner's candidate simulator (SimAS-style).

    The *prior* prices a plan in relative units from its structure: remat
    recomputes the forward pass (~30 % extra FLOPs), every extra microbatch
    pays a launch/pipeline overhead, gradient compression pays an
    encode/decode term.  Every measured step then *calibrates* the model:
    per-plan EMAs override the prior where a plan has been observed, and the
    global seconds-per-unit scale (fit from all observed plans) converts the
    prior of never-executed plans into seconds.  A retuning epoch therefore
    re-prices the whole portfolio from ONE measured plan — candidates are
    evaluated in simulation, not on live steps.

    Predictions carry step time only (no per-worker load vector), so
    sim-assisted tuning should run under the default "LT" reward; a "LIB"
    reward would see zero predicted spread and fall back to the expert
    ladder on every step."""

    REMAT_MULT = 1.30
    MB_OVERHEAD = 0.03
    COMPRESS_MULT = {None: 0.0, "int8": 0.05, "topk": 0.08}
    EMA = 0.3           # per-plan measurement smoothing

    def __init__(self, plans: Sequence[ExecutionPlan]):
        self.plans = list(plans)
        self._measured: Dict[int, float] = {}   # plan index -> EMA seconds
        self._scale: Optional[float] = None     # seconds per prior unit

    def prior(self, plan: ExecutionPlan) -> float:
        """Relative cost of one step under ``plan`` (unitless)."""
        mult = self.REMAT_MULT if plan.remat else 1.0
        mult *= 1.0 + self.MB_OVERHEAD * (plan.microbatches - 1)
        mult *= 1.0 + self.COMPRESS_MULT.get(plan.compress, 0.05)
        return mult

    def observe(self, idx: int, step_time: float) -> None:
        """Fold one measured step into the calibration."""
        prev = self._measured.get(idx)
        self._measured[idx] = step_time if prev is None else \
            (1.0 - self.EMA) * prev + self.EMA * step_time
        scales = [t / self.prior(self.plans[i])
                  for i, t in self._measured.items()]
        self._scale = float(np.median(scales))

    def candidates(self) -> List[Candidate]:
        return [Candidate(i) for i in range(len(self.plans))]

    def price(self, cands: Sequence[Candidate]) -> List[Observation]:
        scale = self._scale if self._scale is not None else 1.0
        out = []
        for c in cands:
            t = self._measured.get(c.alg)
            if t is None:
                t = scale * self.prior(self.plans[c.alg])
            out.append(Observation(loop_time=float(t)))
        return out


class StepAutoTuner:
    """Online selection over compiled step variants.

    build_fn(plan) -> step callable (already jitted or jit-able); the tuner
    compiles on first use and charges compile time to the exploration phase
    only in wall-clock terms (recorded separately).

    With ``method="SimPolicy"`` (or ``REPRO_SIM_POLICY`` set and no explicit
    method) the retuning epochs run in simulation: a :class:`PlanWhatIf`
    prices the whole portfolio before every step, only the predicted winner
    is compiled and executed, and each measured step recalibrates the model
    — the explore-first phase never burns live steps on losing plans."""

    def __init__(self, plans: List[ExecutionPlan], build_fn,
                 method: Optional[str] = None, reward: str = "LT",
                 seed: int = 0, region: str = "train_step",
                 store_dir: Optional[str] = None,
                 sim_model: Optional[PlanWhatIf] = None):
        self.plans = list(plans)
        self.build_fn = build_fn
        self.region = region
        method = method or resolve_sim_policy("ExhaustiveSel")
        self.sim_model = None
        policy_kw = {}
        if is_sim_policy(method):
            self.sim_model = sim_model or PlanWhatIf(self.plans)
            policy_kw["simulator"] = self.sim_model
        elif sim_model is not None:
            raise ValueError(
                f"sim_model= given but method {method!r} never consults a "
                f"simulator; use method='SimPolicy' or 'SimHybrid'")
        # any make_policy name works (incl. "Hybrid"); with store_dir the
        # learned plan table warm-starts across runs (paper §5)
        self.service = SelectionService(method, reward=reward, seed=seed,
                                        n_actions=len(self.plans),
                                        store_dir=store_dir, **policy_kw)
        self._compiled: Dict[int, Callable] = {}
        self.compile_times: Dict[int, float] = {}
        self.history: List[Tuple[str, float, float]] = []

    def _get(self, idx: int) -> Callable:
        if idx not in self._compiled:
            t0 = time.perf_counter()
            self._compiled[idx] = self.build_fn(self.plans[idx])
            self.compile_times[idx] = time.perf_counter() - t0
        return self._compiled[idx]

    def step(self, *args):
        """Run one training step with the currently-selected plan.
        Returns (outputs, plan_name, step_time)."""
        with self.service.instance(self.region) as inst:
            idx = inst.action
            fn = self._get(idx)
            t0 = time.perf_counter()
            out = fn(*args)
            out = jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            lib = self._lib_signal(out)
            inst.report(loop_time=dt, lib=lib)
        if self.sim_model is not None:  # recalibrate the plan cost model
            self.sim_model.observe(idx, dt)
        self.history.append((self.plans[idx].name, dt, lib))
        return out, self.plans[idx].name, dt

    @staticmethod
    def _lib_signal(out) -> float:
        """Paper Eq. 8 over per-worker loads when the step reports them
        (MoE expert loads; per-replica times)."""
        if isinstance(out, tuple) and len(out) == 3 and isinstance(out[2], dict):
            metrics = out[2]
            if "expert_load" in metrics:
                load = np.asarray(metrics["expert_load"], dtype=np.float64)
                load = load.sum(axis=0) if load.ndim > 1 else load
                if load.max() > 0:
                    return percent_load_imbalance(load)
        return 0.0

    @property
    def selected_plan(self) -> str:
        """Peek at the plan the policy would pick now (no feedback owed)."""
        return self.plans[self.service.policy(self.region).decide().action].name

    def save(self) -> List[str]:
        """Persist the learned plan table for warm starts (needs store_dir)."""
        return self.service.save()


def make_plan_builder(cfg: ModelConfig, opt_cfg: AdamWConfig,
                      jit_kwargs: Optional[dict] = None):
    """Standard builder: plan -> jitted train step."""
    import dataclasses as _dc

    from ..launch.steps import make_train_step
    from .compression import EFCompressor

    def build(plan: ExecutionPlan):
        c = _dc.replace(cfg, remat=plan.remat)
        comp = EFCompressor(plan.compress) if plan.compress else None
        step = make_train_step(c, opt_cfg, attn_impl=plan.attn_impl,
                               microbatches=plan.microbatches,
                               compressor=comp)
        return jax.jit(step, **(jit_kwargs or {}))

    return build
