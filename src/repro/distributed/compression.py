"""Gradient compression with error feedback (cross-pod DCI relief).

``EFCompressor`` implements error-feedback compression: the quantization
residual of step t is added back into the gradient at step t+1, preserving
convergence (Seide et al. / Karimireddy et al.).  Two codecs:

* ``int8`` — per-tensor absmax scaling to int8 (4x smaller all-reduce);
* ``topk`` — keep the top-k fraction by magnitude (sparse sync).

In the compiled step the compress->decompress pair shrinks the value range
the cross-pod all-reduce carries; XLA performs the reduction on the
decompressed values here (a custom reducer is a further optimization
documented in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def _compress_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _compress_topk(g, frac: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


class EFCompressor:
    """Stateful wrapper: holds the error-feedback residual pytree."""

    def __init__(self, codec: str = "int8", topk_frac: float = 0.01):
        assert codec in ("int8", "topk")
        self.codec = codec
        self.topk_frac = topk_frac
        self.residual = None

    def init(self, params):
        self.residual = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def __call__(self, grads):
        if self.residual is None:
            self.init(grads)

        def comp(g, r):
            x = g.astype(jnp.float32) + r
            if self.codec == "int8":
                c = _compress_int8(x)
            else:
                c = _compress_topk(x, self.topk_frac)
            return c, x - c

        pairs = jax.tree.map(comp, grads, self.residual)
        out = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
        self.residual = jax.tree.map(lambda t: t[1], pairs,
                                     is_leaf=lambda t: isinstance(t, tuple))
        return out


def compression_ratio(codec: str, topk_frac: float = 0.01) -> float:
    return 0.25 if codec == "int8" else topk_frac * 2  # value+index
