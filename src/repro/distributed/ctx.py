"""Activation-sharding context: Megatron-SP-style boundary constraints.

When active, layer-scan boundary activations (B, S, D) are constrained to
P(dp, tp, None) — sequence sharded over the model axis between blocks — so
the remat-stored residuals divide by the full mesh instead of only the data
axes (qwen2-vl train_4k: 85 GB/device -> 5.3 GB/device).

The models call ``constrain_boundary`` unconditionally; it is a no-op unless
a context is installed (smoke tests on one CPU device stay constraint-free).
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_STATE: dict = {"dp": None, "tp": None, "tp_size": 1, "dp_size": 1,
                "attn_bf16": False, "attn_remat": False, "moe_groups": 1}


@contextmanager
def activation_sharding(dp, tp: Optional[str], dp_size: int, tp_size: int,
                        attn_bf16: bool = False, attn_remat: bool = False,
                        moe_groups: int = 1):
    prev = dict(_STATE)
    _STATE.update(dp=dp, tp=tp, dp_size=dp_size, tp_size=tp_size,
                  attn_bf16=attn_bf16, attn_remat=attn_remat,
                  moe_groups=moe_groups)
    try:
        yield
    finally:
        _STATE.update(prev)


def attn_bf16() -> bool:
    return _STATE["attn_bf16"]


def attn_remat() -> bool:
    return _STATE["attn_remat"]


def moe_groups() -> int:
    return _STATE["moe_groups"]


def constrain_expert_weights(w, kind: str):
    """§Perf B2: force FSDP expert weights to be ALL-GATHERED (D replicated)
    before the expert einsums — otherwise GSPMD psums the (E, C, F)
    activations over the data axes (16 TB/step on grok-1-314b).
    kind: "up" for (..., E, D, F), "down" for (..., E, F, D)."""
    tp = _STATE["tp"]
    if tp is None or _STATE["dp"] is None:
        return w
    pad = [None] * (w.ndim - 2)
    spec = P(*pad, None, tp) if kind == "up" else P(*pad, tp, None)
    return jax.lax.with_sharding_constraint(w, spec)


def constrain_tokens_grouped(xg):
    """MoE grouped dispatch (G, T_local, D): G over the data axes."""
    dp = _STATE["dp"]
    if dp is None or xg.ndim != 3 or xg.shape[0] % _STATE["dp_size"] != 0:
        return xg
    return jax.lax.with_sharding_constraint(xg, P(dp, None, None))


def constrain_boundary(x):
    """x: (B, S, D) hidden states at a block boundary."""
    tp = _STATE["tp"]
    if tp is None or x.ndim != 3:
        return x
    B, S, D = x.shape
    dp = _STATE["dp"]
    spec_b = dp if (dp and B % _STATE["dp_size"] == 0) else None
    spec_s = tp if S % _STATE["tp_size"] == 0 and S >= _STATE["tp_size"] \
        else None
    if spec_b is None and spec_s is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(spec_b, spec_s, None))
