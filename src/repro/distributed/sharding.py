"""Sharding rules: parameter / optimizer / activation / cache partition specs.

Strategy (the production layout; plan variants in ``autotune``):

* TP over the ``model`` axis: attention heads, FFN hidden, vocab.
* FSDP (ZeRO-3-style weight sharding) over the data axes for the *other*
  matrix dimension — this is what lets grok-1-314b's 314e9 params fit
  (params + Adam moments sharded over all 256/512 chips).
* Batch over (``pod``, ``data``); KV caches shard their *sequence* axis over
  ``model`` (works for any n_kv_heads, keeps the 1.1-TB 32k x 128 cache
  distributed; the decode softmax gathers only the tiny score vector).
* SSM decode state shards heads over ``model``.

The spec builder walks the parameter tree by name, so it works for every
family without per-arch tables.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig


def data_axes(mesh: Mesh):
    """The composed batch axes: ('pod','data') on multi-pod meshes."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def lane_spec(mesh: Mesh) -> P:
    """Leading-axis lane sharding for campaign batches: instances / what-if
    candidate rows shard over the composed data axes, everything trailing
    (schedule slots, PEs) stays local to the lane's device."""
    dp = data_axes(mesh)
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))


def lane_count(mesh: Mesh) -> int:
    """Extent of the composed data axes — the number of lane shards."""
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def pad_lanes(n: int, mesh: Mesh) -> int:
    """Round a lane count up to a multiple of the mesh's data extent so the
    leading axis divides evenly under ``shard_map``.  Padding lanes carry
    ``count == 0`` schedules (the event cores never execute them) and are
    sliced off host-side — bit-equality to the unsharded path is preserved
    by construction."""
    d = lane_count(mesh)
    return -(-n // d) * d


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on axes whose size doesn't divide the mesh extent —
    odd vocabularies (whisper's 51865), batch=1 decode, 12-head models.
    Tuple entries are reduced one axis at a time before giving up."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        while entry is not None and dim % _axis_size(mesh, entry) != 0:
            if isinstance(entry, tuple) and len(entry) > 1:
                entry = entry[1:] if len(entry) > 2 else entry[1]
            else:
                entry = None
        out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _spec_for(name: str, ndim: int, dp, tp, fsdp: bool) -> P:
    """Partition spec by parameter name.  Leading layer-stack dims (ndim
    larger than the logical rank) are never sharded."""
    d = dp if fsdp else None

    def lift(*tail):
        """Pad with None for layer-stack leading dims."""
        pad = ndim - len(tail)
        return P(*([None] * pad + list(tail)))

    if name in ("embed",):
        return P(tp, d)
    if name in ("lm_head",):
        return P(d, tp)
    if name in ("wq", "wk", "wv", "xwq", "xwk", "xwv", "w_gate", "w_up",
                "w1", "in_proj"):
        return lift(d, tp)
    if name in ("wo", "xwo", "w_down", "w2", "out_proj"):
        return lift(tp, d)
    if name in ("router",):
        return lift(d, None)
    if name in ("we_gate", "we_up"):
        return lift(None, d, tp)      # (L, E, D, F)
    if name in ("we_down",):
        return lift(None, tp, d)      # (L, E, F, D)
    if name in ("b1",):
        return lift(tp)
    if name in ("conv_w",):
        return lift(None, tp)         # (L, k, channels)
    if name in ("gate_norm",):
        return lift(tp)
    # norms, biases, A_log, D, dt_bias, scalars: replicate
    return P()


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape,
                fsdp: bool = True) -> Dict:
    """PartitionSpec pytree matching ``params_shape`` (an eval_shape tree)."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = "model" if "model" in mesh.axis_names else None

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        name = path[-1]
        spec = _spec_for(name, len(tree.shape), dp, tp, fsdp)
        return fit_spec(spec, tree.shape, mesh)

    return walk(params_shape)


def batch_specs(cfg: ModelConfig, mesh: Mesh) -> Dict:
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    out = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.frontend == "audio":
        out["embeds"] = P(dp, None, None)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Dict:
    """KV caches: sequence over `model`; batch over data axes.
    SSM states: heads over `model`."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = "model" if "model" in mesh.axis_names else None
    out: Dict = {}
    for k, leaf in cache_shape.items():
        if k == "len":
            out[k] = P()
        elif k in ("k", "v", "xk", "xv"):
            # (L, B, S, K, hd): shard S over model, B over data
            out[k] = fit_spec(P(None, dp, tp, None, None), leaf.shape, mesh)
        elif k == "conv":
            # (L, B, k-1, ch): channels over model
            out[k] = fit_spec(P(None, dp, None, tp), leaf.shape, mesh)
        elif k == "state":
            # (L, B, nh, hp, st): heads over model
            out[k] = fit_spec(P(None, dp, tp, None, None), leaf.shape, mesh)
        else:
            out[k] = P()
    return out


def opt_specs(param_spec_tree) -> Dict:
    """Adam moments inherit the parameter sharding (ZeRO: fully sharded)."""
    from ..optim.adamw import AdamWState
    return AdamWState(step=P(),
                      m=jax.tree.map(lambda s: s, param_spec_tree),
                      v=jax.tree.map(lambda s: s, param_spec_tree))


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
