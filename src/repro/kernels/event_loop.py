"""Fused OpenMP self-scheduling event loop — Pallas TPU kernel.

The batched simulation backend's hot path is a sequential recurrence: for
each dispatched chunk, assign it to the earliest-available PE (or to its
pre-assigned owner for StaticSteal) and advance that PE's finish time.
``lax.while_loop`` pays XLA per-iteration dispatch for every one of up to
~1e5 chunks; this kernel runs the whole recurrence on-chip instead.

Layout mirrors ``ssd_scan``: grid = (B, K // seg) with the chunk-segment
axis innermost (sequential), so the per-PE finish times live in VMEM
scratch and persist across segments — one kernel launch replaces K loop
dispatches.  Segments past an instance's chunk count cost one guarded
``fori_loop`` with zero trips.

Two entry points share the assignment recurrence:

* ``event_finish`` — minimal sequential core ``(eff_costs, forced, count)
  -> finish``: effective per-chunk costs are precomputed outside (the
  serving what-if path, whose costs come from an exact float64 host
  prefix-gather).
* ``event_finish_fused`` — full fusion for the campaign path: the
  prefix-grid cost gather (linear interpolation over the profile's
  cumulative-cost row) and the locality/noise application also run
  on-chip per segment, so the (B, K) effective-cost array is never
  materialized to HBM.

Accuracy contract (``tests/test_event_kernel.py``): both entry points are
**bit-identical in interpret mode** to the vmapped ``lax.while_loop``
reference core in ``repro.sim.backends.jax_batched`` — per chunk the op
sequence ``fin[pe] += h_eff + eff[i] * speed[pe] + bcost`` (argmin ties to
the lowest PE index) is replicated exactly, and all random draws
(jitter/speed/noise) stay in the shared data-parallel precompute so every
core sees the same noise realization.  Like every kernel module here, the
entry points take an explicit ``interpret`` flag; the platform policy
(interpret on CPU, Mosaic-compiled on TPU) lives in ``kernels/ops.py``,
which the simulation backend routes through.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: default chunk-segment length; divides every K bucket the backend pads to
DEFAULT_SEG = 512


def _assign_segment(eff, forced, speed, h_eff, bc, n, fin):
    """Run ``n`` assignment steps of one segment (argmin / forced owner)."""

    def body(i, fin):
        pe = jnp.where(forced[i] >= 0, forced[i], jnp.argmin(fin))
        return fin.at[pe].add(h_eff + eff[i] * speed[pe] + bc)

    return lax.fori_loop(0, n, body, fin)


def _loop_kernel(eff_ref, speed_ref, jit_ref, forced_ref, cnt_ref, sc_ref,
                 out_ref, fin_scr, *, seg: int, n_seg: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        fin_scr[...] = jit_ref[...]

    n = jnp.clip(cnt_ref[0, 0] - si * seg, 0, seg)

    @pl.when(n > 0)     # segments past the chunk count touch nothing
    def _run():
        fin_scr[0] = _assign_segment(eff_ref[0], forced_ref[0], speed_ref[0],
                                     sc_ref[0, 0], sc_ref[0, 1], n,
                                     fin_scr[0])

    @pl.when(si == n_seg - 1)
    def _emit():
        out_ref[...] = fin_scr[...]


def _fused_kernel(gid_ref, row_ref, starts_ref, sizes_ref, loc_ref,
                  noise_ref, speed_ref, jit_ref, forced_ref, cnt_ref, sc_ref,
                  out_ref, fin_scr, *, seg: int, n_seg: int, G: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        fin_scr[...] = jit_ref[...]

    n = jnp.clip(cnt_ref[0, 0] - si * seg, 0, seg)

    @pl.when(n > 0)     # segments past the chunk count touch nothing
    def _run():
        # on-chip prefix-grid gather: costs of this segment's chunks via
        # linear interpolation over the profile's cumulative-cost row —
        # selected straight out of the deduplicated stack by the
        # scalar-prefetched grid_id (no host-side (B, G+1) row gather)
        row = row_ref[0]
        gscale = sc_ref[0, 2]

        def pref(x):
            pos = x.astype(jnp.float32) * gscale
            i = jnp.clip(pos.astype(jnp.int32), 0, G - 1)
            lo = row[i]
            return lo + (pos - i) * (row[i + 1] - lo)

        starts = starts_ref[0]
        costs = pref(starts + sizes_ref[0]) - pref(starts)
        eff = costs * loc_ref[0] * noise_ref[0]
        fin_scr[0] = _assign_segment(eff, forced_ref[0], speed_ref[0],
                                     sc_ref[0, 0], sc_ref[0, 1], n,
                                     fin_scr[0])

    @pl.when(si == n_seg - 1)
    def _emit():
        out_ref[...] = fin_scr[...]


def _seg_for(K: int, seg: int) -> int:
    seg = min(seg, K)
    if K % seg:
        raise ValueError(f"segment {seg} must divide padded length {K}")
    return seg


def _lane_specs(seg, P):
    """BlockSpecs shared by both kernels: per-lane (1, seg) chunk segments,
    (1, P) PE rows, and SMEM scalar rows."""
    chunk = pl.BlockSpec((1, seg), lambda bi, si: (bi, si))
    lane = pl.BlockSpec((1, P), lambda bi, si: (bi, 0))
    return chunk, lane


@functools.partial(jax.jit, static_argnames=("seg", "interpret"))
def event_finish(eff, speed, jitter, h_eff, bcost, forced, count, *,
                 seg: int = DEFAULT_SEG, interpret: bool = False):
    """Sequential assignment core over precomputed effective chunk costs.

    eff (B, K) f32, speed/jitter (B, P) f32, h_eff/bcost (B,) f32,
    forced (B, K) i32 (-1 = argmin assignment), count (B,) i32.
    Returns finish (B, P) f32.
    """
    B, K = eff.shape
    P = speed.shape[1]
    seg = _seg_for(K, seg)
    n_seg = K // seg
    chunk, lane = _lane_specs(seg, P)
    kernel = functools.partial(_loop_kernel, seg=seg, n_seg=n_seg)
    return pl.pallas_call(
        kernel,
        grid=(B, n_seg),
        in_specs=[
            chunk,                                              # eff
            lane,                                               # speed
            lane,                                               # jitter
            chunk,                                              # forced
            pl.BlockSpec((1, 1), lambda bi, si: (bi, 0),
                         memory_space=pltpu.SMEM),              # count
            pl.BlockSpec((1, 2), lambda bi, si: (bi, 0),
                         memory_space=pltpu.SMEM),              # h_eff, bcost
        ],
        out_specs=lane,
        out_shape=jax.ShapeDtypeStruct((B, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, P), jnp.float32)],
        interpret=interpret,
    )(eff, speed, jitter, forced, count.reshape(B, 1),
      jnp.stack([h_eff, bcost], axis=1))


@functools.partial(jax.jit, static_argnames=("seg", "interpret"))
def event_finish_fused(grids, grid_id, gscale, starts, sizes, loc, noise,
                       speed, jitter, h_eff, bcost, forced, count, *,
                       seg: int = DEFAULT_SEG, interpret: bool = False):
    """Fully fused campaign core: prefix-grid gather + locality/noise
    application + assignment recurrence in one on-chip pass.

    grids (S, G+1) f32 deduplicated cumulative-cost stack, grid_id (B,) i32
    per-lane row index (scalar-prefetched: each lane's row streams straight
    from the shared stack, never materializing a (B, G+1) gather), gscale
    (B,) f32 (= G / N per lane), starts/sizes (B, K) i32, loc/noise (B, K)
    f32; the rest as in :func:`event_finish`.  Returns finish (B, P) f32.
    """
    B, K = starts.shape
    P = speed.shape[1]
    G = grids.shape[1] - 1
    seg = _seg_for(K, seg)
    n_seg = K // seg
    chunk = pl.BlockSpec((1, seg), lambda bi, si, gid_ref: (bi, si))
    lane = pl.BlockSpec((1, P), lambda bi, si, gid_ref: (bi, 0))
    kernel = functools.partial(_fused_kernel, seg=seg, n_seg=n_seg, G=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                                  # grid_id
        grid=(B, n_seg),
        in_specs=[
            pl.BlockSpec((1, G + 1),
                         lambda bi, si, gid_ref: (gid_ref[bi], 0)),  # row
            chunk,                                              # starts
            chunk,                                              # sizes
            chunk,                                              # loc
            chunk,                                              # noise
            lane,                                               # speed
            lane,                                               # jitter
            chunk,                                              # forced
            pl.BlockSpec((1, 1), lambda bi, si, gid_ref: (bi, 0),
                         memory_space=pltpu.SMEM),              # count
            pl.BlockSpec((1, 3), lambda bi, si, gid_ref: (bi, 0),
                         memory_space=pltpu.SMEM),       # h_eff, bcost, gscale
        ],
        out_specs=lane,
        scratch_shapes=[pltpu.VMEM((1, P), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P), jnp.float32),
        interpret=interpret,
    )(grid_id, grids, starts, sizes, loc, noise, speed, jitter, forced,
      count.reshape(B, 1), jnp.stack([h_eff, bcost, gscale], axis=1))
