"""Flash attention — Pallas TPU kernel with explicit VMEM BlockSpec tiling.

Design (TPU-native, not a CUDA port):

* grid = (batch*q_heads, n_q_blocks, n_kv_blocks); the innermost grid axis is
  sequential on TPU, so the online-softmax running state (m, l, acc) lives in
  VMEM scratch that persists across kv blocks.
* q tile (BLOCK_Q, hd) stays resident; k/v tiles (BLOCK_KV, hd) stream
  through VMEM; all matmul shapes are multiples of 128 on the contracting
  dims for MXU alignment (hd = 64/112/128 padded to 128 by the wrapper).
* GQA is handled by the k/v index_map (q head h reads kv head h // G).

Validated on CPU in interpret mode against ``ref.flash_attention_ref``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_kv: int,
                  seq_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_kv

    if causal:
        # skip blocks entirely above the diagonal
        run = k_start <= q_start + block_q - 1
    else:
        run = kj >= 0

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[...].astype(jnp.float32)                  # (bkv, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
        kpos = k_start + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < seq_kv
        if causal:
            qpos = q_start + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            valid = valid & (kpos <= qpos)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)                  # (bkv, hd)
        acc_scr[...] = (acc_scr[...] * alpha
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, T, K, hd) with H % K == 0.
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    n_q = -(-S // block_q)
    n_kv = -(-T // block_kv)
    Sp, Tp = n_q * block_q, n_kv * block_kv

    # (B*H, S, hd) layout; pad S/T to block multiples
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * K, T, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * K, T, hd)
    if Sp != S:
        qh = jnp.pad(qh, ((0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        kh = jnp.pad(kh, ((0, 0), (0, Tp - T), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, Tp - T), (0, 0)))

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_kv=block_kv, seq_kv=T)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_kv, hd),
                         lambda b, i, j, G=G, K=K: ((b // (G * K)) * K + (b // G) % K, j, 0)),
            pl.BlockSpec((None, block_kv, hd),
                         lambda b, i, j, G=G, K=K: ((b // (G * K)) * K + (b // G) % K, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)

    out = out[:, :S].reshape(B, H, S, hd)
    return jnp.moveaxis(out, 1, 2)
