"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python with real block indexing, which is what the per-kernel
allclose tests validate.  On TPU backends the same calls compile via Mosaic.
"""

from __future__ import annotations

import jax

from .event_loop import event_finish as _event_finish
from .event_loop import event_finish_fused as _event_finish_fused
from .flash_attention import flash_attention as _flash
from .rmsnorm import rmsnorm as _rmsnorm
from .ssd_scan import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def preferred_event_core() -> str:
    """Platform default for the batched engine's sequential event core.

    On accelerators the Pallas kernels compile (Mosaic on TPU) and the fused
    event loop lifts the per-iteration dispatch XLA leaves on the table; on
    CPU they only *interpret* (a correctness vehicle, 0.2–1.1x of the
    while-loop core per ``results/bench_event_kernel.json``), so the vmapped
    ``lax.while_loop`` reference stays the default there.  Kept here so the
    interpret-vs-compile platform policy lives in one module.
    """
    return "while_loop" if _interpret() else "pallas"


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_kv: int = 512):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
                  interpret=_interpret())


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, head_block: int = 8):
    return _ssd(x, dt, A, B, C, chunk=chunk, head_block=head_block,
                interpret=_interpret())


def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256):
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows,
                    interpret=_interpret())


def event_finish(eff, speed, jitter, h_eff, bcost, forced, count, *,
                 seg: int = 512):
    return _event_finish(eff, speed, jitter, h_eff, bcost, forced, count,
                         seg=seg, interpret=_interpret())


def event_finish_fused(grids, grid_id, gscale, starts, sizes, loc, noise,
                       speed, jitter, h_eff, bcost, forced, count, *,
                       seg: int = 512):
    return _event_finish_fused(grids, grid_id, gscale, starts, sizes, loc,
                               noise, speed, jitter, h_eff, bcost, forced,
                               count, seg=seg, interpret=_interpret())
