"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,K,hd). Materializes full scores (oracle)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = (q.astype(jnp.float32) * scale).reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, *, chunk: int = 0):
    """Sequential state-space recurrence (exact oracle, O(S) scan).

    x: (b,S,nh,hp); dt: (b,S,nh); A: (nh,); B,C: (b,S,st).
    Returns (y, final_state)."""
    b, S, nh, hp = x.shape

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp        # (b,nh,hp), (b,nh), (b,st), (b,st)
        dA = jnp.exp(dt_t * A[None, :])
        inc = jnp.einsum("bhp,bs,bh->bhps", x_t, B_t, dt_t)
        h = h * dA[..., None, None] + inc
        y_t = jnp.einsum("bhps,bs->bhp", h, C_t)
        return h, y_t

    h0 = jnp.zeros((b, nh, hp, A.shape[0] and B.shape[-1]), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_last


def rmsnorm_ref(x, w, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)
