"""Fused RMSNorm — Pallas TPU kernel (bandwidth-bound fusion: one pass over
HBM instead of XLA's reduce + broadcast-mul pair).

grid = (n_row_blocks,); each step normalizes a (BLOCK_ROWS, D) VMEM tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = False):
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    n_blocks = -(-rows // br)
    pad = n_blocks * br - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * br, D), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
