"""Mamba2 SSD scan — Pallas TPU kernel.

One kernel computes the full SSD (intra-chunk dense matmuls + inter-chunk
recurrence): grid = (batch, n_head_blocks, n_chunks); the chunk axis is the
innermost (sequential) grid dimension, so the running state (hb, hp, st)
persists in VMEM scratch across chunks — the TPU-idiomatic replacement for
Mamba2's two-pass GPU formulation.

Tile sizes: chunk Q x head-dim hp (256 x 64 default) and state st = 64/128
keep every matmul MXU-shaped.  Validated in interpret mode against
``ref.ssd_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                h_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[...].astype(jnp.float32)        # (hb, Q, hp)
    dt = dt_ref[...].astype(jnp.float32)      # (hb, Q)
    A = a_ref[...].astype(jnp.float32)        # (hb,)
    B = b_ref[...].astype(jnp.float32)        # (Q, st)
    C = c_ref[...].astype(jnp.float32)        # (Q, st)

    dA = dt * A[:, None]                      # (hb, Q)
    dA_cum = jnp.cumsum(dA, axis=1)           # within-chunk
    dA_tot = dA_cum[:, -1]                    # (hb,)

    # decay matrix L[i,j] = exp(sum_{k in (j, i]} dA_k), lower-triangular
    seg = dA_cum[:, :, None] - dA_cum[:, None, :]
    iq = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = iq >= jq
    L = jnp.where(tril[None], jnp.exp(seg), 0.0)           # (hb, Q, Q)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (Q, Q)
    M = scores[None] * L                                   # (hb, Q, Q)
    xdt = x * dt[..., None]                                # (hb, Q, hp)
    y_diag = jax.lax.dot_general(M, xdt,
                                 (((2,), (1,)), ((0,), (0,))))  # (hb, Q, hp)

    # offset from carried state: y_off = (C h^T) * decay_from_start
    h = h_scr[...]                                         # (hb, hp, st)
    ch = jax.lax.dot_general(C, h, (((1,), (2,)), ((), ())))  # (Q, hb, hp)
    ch = jnp.moveaxis(ch, 1, 0)                            # (hb, Q, hp)
    y_off = ch * jnp.exp(dA_cum)[..., None]
    y_ref[...] = (y_diag + y_off).astype(y_ref.dtype)

    # chunk state: S_c = sum_j (decay_to_end_j * dt_j) * x_j B_j^T
    w = jnp.exp(dA_tot[:, None] - dA_cum) * dt             # (hb, Q)
    xw = x * w[..., None]                                  # (hb, Q, hp)
    S_c = jax.lax.dot_general(xw, B, (((1,), (0,)), ((), ())))
    # (hb, hp, st)
    h_scr[...] = h * jnp.exp(dA_tot)[:, None, None] + S_c

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[...] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "head_block",
                                             "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, head_block: int = 8,
             interpret: bool = False):
    """x: (b, S, nh, hp); dt: (b, S, nh); A: (nh,); B, C: (b, S, st).
    Returns (y (b, S, nh, hp), final state (b, nh, hp, st))."""
    b, S, nh, hp = x.shape
    st = B.shape[-1]
    n_chunks = S // chunk
    assert n_chunks * chunk == S, (S, chunk)
    hb = min(head_block, nh)
    assert nh % hb == 0, (nh, hb)
    n_hb = nh // hb

    # head-major layouts
    xh = jnp.moveaxis(x, 2, 1)          # (b, nh, S, hp)
    dth = jnp.moveaxis(dt, 2, 1)        # (b, nh, S)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, n_hb, n_chunks),
        in_specs=[
            pl.BlockSpec((None, hb, chunk, hp), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, hb, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((hb,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((None, chunk, st), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, chunk, st), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, hb, chunk, hp), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, hb, hp, st), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, S, hp), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hp, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, hp, st), jnp.float32)],
        interpret=interpret,
    )(xh, dth, A, B, C)

    return jnp.moveaxis(y, 1, 2), state
