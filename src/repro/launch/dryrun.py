import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, extract memory / FLOPs / collective-bytes for the
roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be run as its own process (the XLA_FLAGS line above executes before any
other jax import — 512 placeholder host devices).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun.json                 # everything (slow)
"""

import argparse
import json
import sys
import time
from typing import Dict, List, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_NAMES, SHAPES, applicable, get_config
from ..distributed.ctx import activation_sharding
from ..distributed.sharding import (batch_specs, cache_specs, data_axes,
                                    fit_spec, named, opt_specs, param_specs)
from ..optim.adamw import AdamWConfig
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .steps import (input_specs, make_prefill_step, make_serve_step,
                    make_train_step, opt_shape, params_shape)

# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

# per-cell tuned plans from the §Perf hillclimb (EXPERIMENTS.md): grouped MoE
# dispatch pays off for olmoe's 64-expert layers (peak -71%, bytes -13%) but
# regressed grok's 8-expert ones — tuned per arch, like the paper's per-loop
# selection.
TUNED_PLANS = {
    ("olmoe-1b-7b", "train_4k"): {"moe_groups": 16},
    ("olmoe-1b-7b", "prefill_32k"): {"moe_groups": 16},
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp: bool = True, microbatches: int = 1,
             attn_impl: str = "auto", attn_bf16: bool = False,
             attn_remat: bool = True, moe_groups: int = 1) -> Dict:
    for k, v in TUNED_PLANS.get((arch, shape_name), {}).items():
        if k == "moe_groups" and moe_groups == 1:
            moe_groups = v
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    res: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        res["skipped"] = why
        return res

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    pshape = params_shape(cfg)
    pspec = param_specs(cfg, mesh, pshape, fsdp=fsdp)
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp_size = mesh.shape.get("model", 1)

    with mesh, activation_sharding(dp, "model", dp_size, tp_size,
                                   attn_bf16=attn_bf16,
                                   attn_remat=attn_remat,
                                   moe_groups=moe_groups):
        if shape.kind == "train":
            opt_cfg = AdamWConfig(moment_dtype=cfg.moment_dtype)
            oshape = opt_shape(cfg, opt_cfg)
            ospec = opt_specs(pspec)
            bspec = batch_specs(cfg, mesh)
            step = make_train_step(cfg, opt_cfg, attn_impl=attn_impl,
                                   microbatches=microbatches)
            jitted = jax.jit(
                step,
                in_shardings=(named(mesh, pspec), named(mesh, ospec),
                              named(mesh, bspec)),
                out_shardings=(named(mesh, pspec), named(mesh, ospec), None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(pshape, oshape, input_specs(cfg, shape))
        elif shape.kind == "prefill":
            bspec = {k: v for k, v in batch_specs(cfg, mesh).items()
                     if k != "labels"}
            step = make_prefill_step(cfg, attn_impl=attn_impl)
            ispec = input_specs(cfg, shape)
            cshape = jax.eval_shape(step, pshape, ispec)[1]
            cspec = cache_specs(cfg, mesh, cshape)
            lg_spec = fit_spec(P(dp, "model"),
                               (shape.global_batch, cfg.vocab_size), mesh)
            out_sh = (NamedSharding(mesh, lg_spec), named(mesh, cspec))
            jitted = jax.jit(step,
                             in_shardings=(named(mesh, pspec),
                                           named(mesh, bspec)),
                             out_shardings=out_sh)
            lowered = jitted.lower(pshape, ispec)
        else:  # decode
            ispec = input_specs(cfg, shape)
            cspec = cache_specs(cfg, mesh, ispec["cache"])
            tok_spec = fit_spec(P(dp), (shape.global_batch,), mesh)
            logits_spec = fit_spec(
                P(tok_spec[0] if len(tok_spec) else None, "model"),
                (shape.global_batch, cfg.vocab_size), mesh)
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(named(mesh, pspec), named(mesh, cspec),
                              NamedSharding(mesh, tok_spec)),
                out_shardings=(NamedSharding(mesh, logits_spec),
                               named(mesh, cspec)),
                donate_argnums=(1,))
            lowered = jitted.lower(pshape, ispec["cache"], ispec["token"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo, n_dev)
    coll = costs.coll

    res.update({
        "devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": costs.flops,
        "bytes_per_device": costs.bytes,
        "xla_flops_once": float(cost.get("flops", -1.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "bytes_by_category": costs.bytes_by,
        "collective_wire_bytes_per_device": coll,
        "collective_total": sum(coll.values()),
        "n_params": cfg.n_params(),
        "active_params": cfg.active_params(),
    })
    return res


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn", default="auto")
    ap.add_argument("--attn-bf16", action="store_true")
    ap.add_argument("--attn-remat", dest="attn_remat", action="store_true",
                    default=True)
    ap.add_argument("--no-attn-remat", dest="attn_remat",
                    action="store_false")
    ap.add_argument("--moe-groups", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells: List[Tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                r = run_cell(arch, shape, mp, fsdp=not args.no_fsdp,
                             microbatches=args.microbatches,
                             attn_impl=args.attn, attn_bf16=args.attn_bf16,
                             attn_remat=args.attn_remat,
                             moe_groups=args.moe_groups)
            except Exception as e:  # a failing cell is a bug — surface it
                r = {"arch": arch, "shape": shape,
                     "mesh": "2x16x16" if mp else "16x16",
                     "error": f"{type(e).__name__}: {e}"}
            results.append(r)
            print(json.dumps(r), flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    if any("error" in r for r in results):
        sys.exit(1)


if __name__ == "__main__":
    main()
