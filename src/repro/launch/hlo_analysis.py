"""While-aware HLO cost analysis.

``compiled.cost_analysis()`` (XLA's HloCostAnalysis) visits every instruction
ONCE — a ``lax.scan`` over 64 layers contributes a single layer of FLOPs.
For scanned-layer models that undercounts by ~n_layers, making any roofline
derived from it garbage.  This module re-derives FLOPs / bytes / collective
wire-bytes from the post-SPMD HLO text, multiplying while-loop bodies by
their trip counts (XLA annotates ``backend_config={"known_trip_count"...}``)
and recursing through calls, conditionals and fusions.

Accounting (per-device; post-SPMD shapes are already per-device):

* FLOPs: ``dot`` = 2 * numel(result) * prod(lhs contracting dims);
  elementwise/reduce = numel(result) (secondary but counted); fusion bodies
  contribute their internal dot/elementwise FLOPs.
* Bytes: result + operand bytes per instruction (HloCostAnalysis's own
  approximation); fusions count only their boundary operands/result;
  dynamic-slice / dynamic-update-slice count the slice, not the buffer.
* Collectives: per-device ring wire-bytes (see ``collective_wire``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}

_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OP_RE = re.compile(r"([a-z][\w\-]*)\(")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(type_str: str) -> int:
    total = 0
    for _, shape in _parse_shapes(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


_BYTE_CATS = ("dot", "elementwise", "dus", "data_movement", "collective",
              "other")


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLL_KINDS})
    bytes_by: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in _BYTE_CATS})

    def add_bytes(self, cat: str, n: float):
        self.bytes += n
        self.bytes_by[cat] += n

    def __iadd__(self, other: "Costs"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in COLL_KINDS:
            self.coll[k] += other.coll[k]
        for k in _BYTE_CATS:
            self.bytes_by[k] += other.bytes_by[k]
        return self

    def scaled(self, f: float) -> "Costs":
        return Costs(self.flops * f, self.bytes * f,
                     {k: v * f for k, v in self.coll.items()},
                     {k: v * f for k, v in self.bytes_by.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclass
class Instr:
    name: str
    rtype: str
    op: str
    args: str
    line: str


class HloAnalyzer:
    def __init__(self, hlo: str, total_devices: int):
        self.total_devices = total_devices
        self.comps: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        cur = None
        for line in hlo.splitlines():
            mh = _HDR_RE.match(line)
            if mh:
                cur = mh.group(2)
                self.comps[cur] = []
                if mh.group(1):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            mn = _NAME_RE.match(line)
            if not mn:
                continue
            rest = line[mn.end():]
            mo = _OP_RE.search(rest)
            if not mo:
                continue
            self.comps[cur].append(Instr(
                name=mn.group(1),
                rtype=rest[:mo.start()],
                op=mo.group(1),
                args=rest[mo.end():],
                line=line,
            ))
        self._shape_cache: Dict[str, Dict[str, str]] = {}
        self._cost_cache: Dict[str, Costs] = {}

    # -- helpers -------------------------------------------------------------
    def _shapes(self, comp: str) -> Dict[str, str]:
        if comp not in self._shape_cache:
            self._shape_cache[comp] = {i.name: i.rtype
                                       for i in self.comps.get(comp, [])}
        return self._shape_cache[comp]

    def _operands(self, args: str) -> List[str]:
        head = args.split(")", 1)[0]
        return re.findall(r"%([\w\.\-]+)", head)

    def _operand_bytes(self, comp: str, args: str) -> int:
        shapes = self._shapes(comp)
        return sum(_bytes_of(shapes[o]) for o in self._operands(args)
                   if o in shapes)

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        flops = 2.0 * _numel(ins.rtype)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        ops = self._operands(ins.args)
        k = 1
        if m and ops:
            lt = self._shapes(comp).get(ops[0])
            if lt:
                parsed = _parse_shapes(lt)
                if parsed:
                    _, lshape = parsed[0]
                    for idx in (m.group(1).split(",") if m.group(1) else []):
                        i = int(idx)
                        if i < len(lshape):
                            k *= lshape[i]
        return flops * k

    def _group_size(self, line: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
        if m:
            return len(m.group(1).split(","))
        return self.total_devices

    # -- main ----------------------------------------------------------------
    def cost(self, comp: Optional[str] = None,
             stack: Tuple[str, ...] = ()) -> Costs:
        comp = comp or self.entry
        if comp is None:
            return Costs()
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        if comp in stack:
            return Costs()
        total = Costs()
        for ins in self.comps.get(comp, []):
            op = ins.op
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if mb:
                    mt = _TRIP_RE.search(ins.line)
                    trips = int(mt.group(1)) if mt else self._cond_trips(ins)
                    total += self.cost(mb.group(1),
                                       stack + (comp,)).scaled(trips)
                continue
            if op in ("call", "conditional", "custom-call", "async-start"):
                for mt in re.finditer(
                        r"(?:to_apply=|calls=|branch_computations=\{)"
                        r"%?([\w\.\-]+)", ins.line):
                    total += self.cost(mt.group(1), stack + (comp,))
                continue
            if op == "fusion":
                total.add_bytes("data_movement",
                                _bytes_of(ins.rtype)
                                + self._operand_bytes(comp, ins.args))
                mf = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if mf:
                    total.flops += self.cost(mf.group(1),
                                             stack + (comp,)).flops
                continue
            handled = False
            for kind in COLL_KINDS:
                if op.startswith(kind) and not op.endswith("-done"):
                    size = _bytes_of(ins.rtype)
                    G = max(2, self._group_size(ins.line))
                    total.coll[kind] += collective_wire(kind, size, G)
                    total.add_bytes("collective", size)
                    handled = True
                    break
            if handled or op in _SKIP_OPS:
                continue
            if op == "dynamic-slice":
                total.add_bytes("dus", 2 * _bytes_of(ins.rtype))
                continue
            if op == "dynamic-update-slice":
                ops = self._operands(ins.args)
                upd = self._shapes(comp).get(ops[1]) if len(ops) > 1 else None
                total.add_bytes("dus", 2 * _bytes_of(upd) if upd
                                else _bytes_of(ins.rtype) // 4)
                continue
            if op in ("copy", "copy-start", "transpose", "reshape",
                      "concatenate", "broadcast", "slice", "pad", "reverse",
                      "gather", "scatter", "select-and-scatter", "sort"):
                total.add_bytes("data_movement", 2 * _bytes_of(ins.rtype))
                continue
            if op in ("dot", "convolution"):
                total.add_bytes("dot", _bytes_of(ins.rtype)
                                + self._operand_bytes(comp, ins.args))
                total.flops += self._dot_flops(comp, ins)
                continue
            if op in ("reduce", "reduce-window"):
                total.add_bytes("other", _bytes_of(ins.rtype)
                                + self._operand_bytes(comp, ins.args))
                total.flops += _numel(ins.rtype)
                continue
            # elementwise-ish: write-once/read-once (fusion-equivalent)
            total.add_bytes("elementwise", 2 * _bytes_of(ins.rtype))
            total.flops += _numel(ins.rtype)
        self._cost_cache[comp] = total
        return total

    def _cond_trips(self, ins: Instr) -> int:
        mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
        best = 1
        if mc:
            for i in self.comps.get(mc.group(1), []):
                for m in re.finditer(r"constant\((\d+)\)", i.line):
                    best = max(best, int(m.group(1)))
        return best


def collective_wire(kind: str, result_bytes: float, G: int) -> float:
    """Per-device wire bytes for a ring implementation."""
    if kind == "all-gather":
        return (G - 1) / G * result_bytes
    if kind == "all-reduce":
        return 2 * (G - 1) / G * result_bytes
    if kind == "reduce-scatter":
        return (G - 1) * result_bytes
    if kind == "all-to-all":
        return (G - 1) / G * result_bytes
    return float(result_bytes)


def analyze_hlo(hlo: str, total_devices: int) -> Costs:
    return HloAnalyzer(hlo, total_devices).cost()
