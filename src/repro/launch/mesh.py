"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the smoke tests, which must see one
CPU device while the dry-run subprocess sees 512 placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever fits the local devices — used by tests and examples."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
