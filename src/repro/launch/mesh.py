"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the smoke tests, which must see one
CPU device while the dry-run subprocess sees 512 placeholder devices.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1,
                   data_parallel: Optional[int] = None):
    """Whatever fits the local devices — used by tests, examples and the
    campaign lane sharding.

    ``data_parallel`` clamps the data axis so callers can request fewer
    lanes than the host exposes (a campaign slice smaller than the device
    count, or a controlled scaling sweep over 1/2/4/8 devices); the mesh
    then covers the first ``data_parallel * model_parallel`` devices.
    """
    devices = jax.devices()
    n = len(devices)
    if model_parallel < 1:
        raise ValueError(f"model_parallel must be >= 1, got {model_parallel}")
    if n % model_parallel != 0:
        raise ValueError(
            f"device count {n} is not divisible by "
            f"model_parallel={model_parallel}; pick a divisor of {n}")
    dp = n // model_parallel
    if data_parallel is not None:
        if data_parallel < 1:
            raise ValueError(
                f"data_parallel must be >= 1, got {data_parallel}")
        dp = min(dp, data_parallel)
    use = devices[: dp * model_parallel]
    return jax.sharding.Mesh(
        np.asarray(use, dtype=object).reshape(dp, model_parallel),
        ("data", "model"))


def campaign_mesh(data_parallel: Optional[int] = None):
    """1-D-data host mesh for campaign lane sharding: every batched lane
    dimension (``run_batch`` / ``run_lockstep`` instances, what-if candidate
    rows) shards over ``data``; ``model`` stays 1 — the event cores are
    per-lane sequential and never split a lane across devices."""
    return make_host_mesh(model_parallel=1, data_parallel=data_parallel)
