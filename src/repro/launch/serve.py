"""Production serving launcher: continuous batching + chunk-self-scheduled
dispatch with online algorithm selection (the paper's technique, L3).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --requests 2048 --replicas 16 --selector QLearn --reward LT
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_config, smoke_reduce
from ..core import ALGORITHM_NAMES
from ..data import synthetic_requests
from ..models import decode_step, init_decode_cache, init_params
from ..serving import ContinuousBatcher, DispatchSimulator, ReplicaCostModel


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--selector", default="QLearn")
    ap.add_argument("--reward", default="LT")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = smoke_reduce(get_config(args.arch)) if args.smoke \
        else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_decode_cache(cfg, args.slots, 256)
    serve = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    # live path: calibrate the replica cost model from real decode steps
    warm = synthetic_requests(24, seed=0, mean_prompt=8, mean_gen=16)
    batcher = ContinuousBatcher(serve, None, args.slots)
    batcher.submit(warm)
    stats = batcher.run(params, cache, jnp.zeros((args.slots,), jnp.int32),
                        max_steps=200)
    per_tok = stats["wall"] / max(stats["tokens"], 1)
    print(f"live: {stats['tokens_per_s']:.0f} tok/s on {args.slots} slots "
          f"({cfg.family}); per-token {per_tok * 1e6:.0f} us")

    # scale path: selection over the 12-algorithm dispatch portfolio
    reqs = synthetic_requests(args.requests, seed=7, heavy_tail=1.15)
    sim = DispatchSimulator(args.replicas, selector=args.selector,
                            reward=args.reward,
                            cost_model=ReplicaCostModel(per_token=per_tok / 50))
    sim.run(reqs)
    s = sim.summary()
    shares = {}
    for st in sim.stats:
        shares[st.algorithm] = shares.get(st.algorithm, 0) + 1
    top = max(shares, key=shares.get)
    print(f"dispatch[{args.selector}/{args.reward}]: "
          f"makespan={s['total_makespan']:.3f}s mean LIB={s['mean_lib']:.1f}% "
          f"waves={s['waves']} mostly->{ALGORITHM_NAMES[top]}")


if __name__ == "__main__":
    main()
