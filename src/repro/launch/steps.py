"""Step builders shared by the trainer, the serving engine and the dry-run:
``make_train_step`` (fwd + bwd + AdamW, optional microbatched gradient
accumulation and gradient compression) and ``make_serve_step`` /
``make_prefill_step``.  ``input_specs`` produces ShapeDtypeStruct stand-ins
for every (arch x shape) cell — weak-type-correct, shardable, no device
allocation.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models.decode import decode_cache_specs, decode_step
from ..models.model import init_params, loss_fn
from ..models.decode import prefill
from ..optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    attn_impl: str = "auto", microbatches: int = 1,
                    compressor=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    microbatches > 1 accumulates gradients over sequential microbatch slices
    (lets XLA overlap the reduce-scatter of one slice with the compute of the
    next); ``compressor`` optionally compresses gradients before the update
    (see distributed.compression)."""

    def lf(p, b):
        return loss_fn(cfg, p, b, attn_impl=attn_impl)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params,
                                                                      batch)
        else:
            def split(x):
                B = x.shape[0]
                assert B % microbatches == 0, (B, microbatches)
                return x.reshape((microbatches, B // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_step(carry, b):
                g_acc, l_acc = carry
                (l, _aux), g = jax.value_and_grad(lf, has_aux=True)(params, b)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, jnp.float32(0)),
                                            mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            aux = {}
        if compressor is not None:
            grads = compressor(grads)
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params,
                                                    opt_cfg)
        metrics = {"loss": loss, **metrics}
        if "expert_load" in aux:
            metrics["expert_load"] = aux["expert_load"]
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token):
        return decode_step(cfg, params, cache, token)
    return serve_step


def make_prefill_step(cfg: ModelConfig, attn_impl: str = "auto"):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch["tokens"],
                       embeds=batch.get("embeds"), attn_impl=attn_impl)
    return prefill_step


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins
# ---------------------------------------------------------------------------

def params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def opt_shape(cfg: ModelConfig, opt_cfg: AdamWConfig):
    ps = params_shape(cfg)
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), ps)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Model inputs for one dry-run cell (no device allocation)."""
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": sds((B, S), jnp.int32),
               "labels": sds((B, S), jnp.int32)}
        if cfg.family == "encdec":
            out["embeds"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                jnp.dtype(cfg.param_dtype))
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.family == "encdec":
            out["embeds"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                jnp.dtype(cfg.param_dtype))
        return out
    if shape.kind == "decode":
        return {"token": sds((B,), jnp.int32),
                "cache": decode_cache_specs(cfg, B, S)}
    raise ValueError(shape.kind)
