"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 100 [--smoke] [--method QLearn] [--ckpt DIR]

``--smoke`` (default on CPU-sized hosts) trains the reduced same-family
config; without it, the full assigned config is used (pod-scale hardware).
The step-plan autotuner (the paper's selection technique, L2) picks the
execution plan online; checkpoints are atomic + async; injected failures
exercise the restart path.
"""

from __future__ import annotations

import argparse

from ..configs import ARCH_NAMES, get_config, smoke_reduce
from ..data import DataConfig
from ..distributed import DEFAULT_PLANS, StepAutoTuner, make_plan_builder
from ..optim.adamw import AdamWConfig
from ..runtime import Trainer, TrainerConfig


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--method", default="ExhaustiveSel")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--failure-rate", type=float, default=0.0)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_reduce(cfg)
    print(f"arch={args.arch} family={cfg.family} "
          f"params={cfg.n_params() / 1e6:.1f}M smoke={args.smoke}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps,
                          moment_dtype=cfg.moment_dtype)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch)
    tuner = StepAutoTuner(list(DEFAULT_PLANS),
                          make_plan_builder(cfg, opt_cfg),
                          method=args.method)
    trainer = Trainer(cfg, opt_cfg, data_cfg,
                      TrainerConfig(ckpt_dir=args.ckpt,
                                    ckpt_every=max(10, args.steps // 5),
                                    failure_rate=args.failure_rate),
                      autotuner=tuner)
    trainer.install_preemption_handler()
    out = trainer.train(args.steps)
    losses = out["losses"]
    print(f"done: steps={out['final_step']} restarts={out['restarts']} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"plan={tuner.selected_plan}")


if __name__ == "__main__":
    main()
