"""repro.models — the architecture zoo (dense / MoE / SSM / hybrid / enc-dec)."""

from .model import (init_params, forward, loss_fn, logits_fn,
                    chunked_ce_loss)
from .decode import (decode_step, prefill, init_decode_cache,
                     decode_cache_specs)

__all__ = ["init_params", "forward", "loss_fn", "logits_fn",
           "chunked_ce_loss", "decode_step", "prefill", "init_decode_cache",
           "decode_cache_specs"]
