"""Serving paths: cache init, prefill, and single-token decode for every
architecture family.

Cache layouts (leading L = layer-stacked so decode scans over layers):

    dense/moe : {"k": (L,B,Smax,K,hd), "v": ..., "len": i32[]}
    ssm       : {"conv": (L,B,k-1,ch), "state": (L,B,nh,hp,st), "len": i32[]}
    hybrid    : ssm caches + shared-attn KV per segment (n_seg leading)
    encdec    : decoder self-attn KV + precomputed cross KV over encoder_seq

``decode_step(cfg, params, cache, token)`` is the unit the serving engine
and the ``decode_*`` dry-run shapes lower.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .layers import layer_norm, rms_norm
from .model import (_dense_block, _dtype, _moe_block_apply, _sinusoid, forward,
                    logits_fn)
from .ssm import ssm_layer_apply


def _kv_shape(cfg: ModelConfig, B: int, S: int):
    return (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None) -> Dict:
    """Concrete zero-filled cache (smoke tests / serving)."""
    specs = decode_cache_specs(cfg, batch, max_len, dtype)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def decode_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=None) -> Dict:
    """ShapeDtypeStruct cache pytree (dry-run input_specs)."""
    dt = dtype or _dtype(cfg)
    sds = jax.ShapeDtypeStruct
    L, B = cfg.n_layers, batch
    out: Dict = {"len": sds((), jnp.int32)}
    if cfg.family in ("dense", "moe"):
        out["k"] = sds(_kv_shape(cfg, B, max_len), dt)
        out["v"] = sds(_kv_shape(cfg, B, max_len), dt)
    elif cfg.family == "ssm":
        ch = cfg.d_inner + 2 * cfg.ssm_state
        out["conv"] = sds((L, B, cfg.ssm_conv - 1, ch), dt)
        out["state"] = sds((L, B, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32)
    elif cfg.family == "hybrid":
        n_seg = cfg.n_layers // cfg.attn_every
        ch = cfg.d_inner + 2 * cfg.ssm_state
        out["conv"] = sds((L, B, cfg.ssm_conv - 1, ch), dt)
        out["state"] = sds((L, B, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32)
        out["k"] = sds((n_seg, B, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
        out["v"] = sds((n_seg, B, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
    elif cfg.family == "encdec":
        out["k"] = sds(_kv_shape(cfg, B, max_len), dt)
        out["v"] = sds(_kv_shape(cfg, B, max_len), dt)
        out["xk"] = sds((cfg.n_layers, B, cfg.encoder_seq, cfg.n_kv_heads,
                         cfg.head_dim), dt)
        out["xv"] = sds((cfg.n_layers, B, cfg.encoder_seq, cfg.n_kv_heads,
                         cfg.head_dim), dt)
    else:
        raise ValueError(cfg.family)
    return out


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Dict, tokens, *, embeds=None,
            attn_impl: str = "auto"):
    """Full-sequence pass that materializes the KV/state caches and the
    last-position logits.  Returns (logits (B, V), cache)."""
    hidden, kvs, aux = forward(cfg, params, tokens, embeds=embeds,
                               attn_impl=attn_impl, collect_cache=True)
    B, S = tokens.shape[0], tokens.shape[1]
    cache: Dict = {"len": jnp.asarray(S, jnp.int32)}
    if cfg.family in ("dense", "moe") and kvs is not None:
        cache["k"], cache["v"] = kvs
    elif cfg.family == "encdec" and kvs is not None:
        (cache["k"], cache["v"]), cache["xk"], cache["xv"] = \
            (kvs[0], kvs[1], kvs[2])
    elif cfg.family == "ssm" and kvs is not None:
        cache["conv"], cache["state"] = kvs["conv"], kvs["state"]
    elif cfg.family == "hybrid" and kvs is not None:
        states, kv = kvs
        # inner scan emits (n_seg, attn_every, B, ...) -> flatten to (L, ...)
        flat = lambda a: a.reshape((-1,) + a.shape[2:])
        cache["conv"] = flat(states["conv"])
        cache["state"] = flat(states["state"])
        cache["k"], cache["v"] = kv
    logits = logits_fn(cfg, params, hidden[:, -1:, :])[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, token,
                ) -> Tuple[jnp.ndarray, Dict]:
    """One new token for every sequence in the batch.

    token: (B,) int32.  Returns (logits (B, V), updated cache)."""
    B = token.shape[0]
    x = params["embed"][token][:, None, :]              # (B,1,D)
    pos = jnp.broadcast_to(cache["len"][None, None], (B, 1))
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe"):
        def body(x, inp):
            if cfg.family == "dense":
                p, kc, vc = inp
                x, (k2, v2) = _dense_block(p, cfg, x, pos, "full",
                                           cache=(kc, vc),
                                           cache_len=cache["len"])
                return x, (k2, v2)
            p, kc, vc = inp
            x, (k2, v2), _aux = _moe_block_apply(p, cfg, x, pos, "full",
                                                 cache=(kc, vc),
                                                 cache_len=cache["len"])
            return x, (k2, v2)
        x, (k_new, v_new) = lax.scan(body, x,
                                     (params["layers"], cache["k"],
                                      cache["v"]))
        new_cache["k"], new_cache["v"] = k_new, v_new

    elif cfg.family == "ssm":
        def body(x, inp):
            p, conv, state = inp
            x, c2 = ssm_layer_apply(p, x, cfg,
                                    decode_cache={"conv": conv,
                                                  "state": state})
            return x, (c2["conv"], c2["state"])
        x, (conv_new, state_new) = lax.scan(
            body, x, (params["layers"], cache["conv"], cache["state"]))
        new_cache["conv"], new_cache["state"] = conv_new, state_new

    elif cfg.family == "hybrid":
        n_seg = cfg.n_layers // cfg.attn_every
        seg = lambda a: a.reshape((n_seg, cfg.attn_every) + a.shape[1:])
        seg_params = jax.tree.map(seg, params["layers"])
        seg_conv = seg(cache["conv"])
        seg_state = seg(cache["state"])
        shared = params["shared_attn"]

        def segment(x, inp):
            sp, conv_s, state_s, kc, vc = inp

            def inner(x, inp2):
                p, conv, state = inp2
                x, c2 = ssm_layer_apply(p, x, cfg,
                                        decode_cache={"conv": conv,
                                                      "state": state})
                return x, (c2["conv"], c2["state"])
            x, (conv2, state2) = lax.scan(inner, x, (sp, conv_s, state_s))
            x, (k2, v2) = _dense_block(shared, cfg, x, pos, "full",
                                       cache=(kc, vc),
                                       cache_len=cache["len"])
            return x, (conv2, state2, k2, v2)

        x, (conv_new, state_new, k_new, v_new) = lax.scan(
            segment, x, (seg_params, seg_conv, seg_state, cache["k"],
                         cache["v"]))
        unseg = lambda a: a.reshape((cfg.n_layers,) + a.shape[2:])
        new_cache["conv"], new_cache["state"] = unseg(conv_new), unseg(state_new)
        new_cache["k"], new_cache["v"] = k_new, v_new

    elif cfg.family == "encdec":
        D = cfg.d_model
        x = x + lax.dynamic_slice_in_dim(
            _sinusoid(cache["k"].shape[2] + 1, D), cache["len"], 1,
            axis=0)[None].astype(x.dtype)

        def body(x, inp):
            p, kc, vc, xk, xv = inp
            a = layer_norm(x, p["ln1"], p["ln1_b"], cfg.norm_eps)
            from .model import _attn_apply
            o, (k2, v2) = _attn_apply(p, cfg, a, None, cache=(kc, vc),
                                      cache_len=cache["len"])
            x = x + o
            c = layer_norm(x, p["lnx"], p["lnx_b"], cfg.norm_eps)
            o2, _ = _attn_apply(p, cfg, c, None, causal=False, kv=(xk, xv),
                                prefix="x")
            x = x + o2
            m = layer_norm(x, p["ln2"], p["ln2_b"], cfg.norm_eps)
            from .layers import gelu_mlp
            x = x + gelu_mlp(m, p["w1"], p["b1"], p["w2"], p["b2"])
            return x, (k2, v2)
        x, (k_new, v_new) = lax.scan(body, x,
                                     (params["dec_layers"], cache["k"],
                                      cache["v"], cache["xk"], cache["xv"]))
        new_cache["k"], new_cache["v"] = k_new, v_new
        x = layer_norm(x, params["final_norm"], params["final_norm_b"],
                       cfg.norm_eps)
        logits = logits_fn(cfg, params, x)[:, 0]
        new_cache["len"] = cache["len"] + 1
        return logits, new_cache
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(cfg, params, x)[:, 0]
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache
