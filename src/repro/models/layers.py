"""Neural-net building blocks shared by all architecture families.

Pure-functional JAX: params are pytrees of arrays, layer stacks are scanned
(``jax.lax.scan``) so the lowered HLO stays compact for the 512-device
dry-run.  Attention is GQA with optional qk-norm, RoPE or M-RoPE, and a
memory-bounded *chunked* (online-softmax) path used for long sequences —
the XLA-portable twin of the Pallas flash-attention kernel in
``repro.kernels``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                     / (head_dim // 2))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float,
                sections=(0.25, 0.375, 0.375)):
    """Qwen2-VL M-RoPE: rotary frequencies split into (temporal, height,
    width) sections, each driven by its own position stream.

    x: (..., S, H, hd); positions3: (3, ..., S).  For text-only input all
    three streams are equal and M-RoPE reduces to RoPE.
    """
    hd = x.shape[-1]
    half = hd // 2
    n_t = int(half * sections[0])
    n_h = int(half * sections[1])
    n_w = half - n_t - n_h
    freqs = rope_freqs(hd, theta)                      # (half,)
    sec_pos = jnp.concatenate([
        jnp.repeat(positions3[0][..., :, None], n_t, axis=-1),
        jnp.repeat(positions3[1][..., :, None], n_h, axis=-1),
        jnp.repeat(positions3[2][..., :, None], n_w, axis=-1),
    ], axis=-1).astype(jnp.float32)                    # (..., S, half)
    ang = sec_pos * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _gqa_scores_einsum(q, k):
    """q: (B,S,K,G,hd), k: (B,T,K,hd) -> (B,K,G,S,T)."""
    return jnp.einsum("bskgh,btkh->bkgst", q, k)


def full_attention(q, k, v, *, causal: bool, q_offset=0):
    """Reference attention. q:(B,S,H,hd) k,v:(B,T,K,hd); H = K*G."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, K, G, hd)
    scores = _gqa_scores_einsum(qg.astype(jnp.float32) * scale,
                                k.astype(jnp.float32))
    if causal:
        qpos = jnp.arange(S) + q_offset
        kpos = jnp.arange(T)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      kv_chunk: int = 2048, score_dtype=None,
                      remat_chunks: bool = False):
    """Memory-bounded online-softmax attention (flash-style, pure lax.scan).

    Never materializes the (S, T) score matrix: scans KV chunks carrying a
    running (max, denominator, numerator).  This is the XLA-portable path
    used on long sequences and in the dry-run; the Pallas kernel implements
    the same tiling for TPU VMEM.

    score_dtype: dtype for the score/p tensors (§Perf: bf16 halves the
    dominant attention traffic; reductions stay f32).
    remat_chunks: checkpoint the scan body so backward recomputes per-chunk
    scores instead of stashing an (n_chunks, B,K,G,S,Tc) residual buffer.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    sdt = score_dtype or jnp.float32
    NEG = jnp.asarray(-3e38 if sdt == jnp.float32 else -3e4, sdt)
    n_chunks = -(-T // kv_chunk)
    pad = n_chunks * kv_chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)

    scale = 1.0 / math.sqrt(hd)
    qg = (q.astype(sdt) * scale).reshape(B, S, K, G, hd)
    qpos = jnp.arange(S) + q_offset

    def step(carry, inp):
        m, den, num = carry                     # (B,K,G,S), ..., (B,K,G,S,hd)
        ci, k_i, v_i = inp
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = _gqa_scores_einsum(qg, k_i.astype(sdt))          # (B,K,G,S,Tc)
        valid = kpos[None, :] < T + 0 * qpos[:, None]
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(valid[None, None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        m_safe = jnp.where(m_new > -1e30, m_new, 0.0)
        alpha = jnp.where(m > -1e30, jnp.exp(m - m_safe), 0.0)
        p = jnp.exp(s.astype(jnp.float32) - m_safe[..., None]).astype(sdt)
        p = jnp.where(valid[None, None, None], p,
                      jnp.asarray(0.0, sdt))
        den_new = den * alpha + p.sum(axis=-1).astype(jnp.float32)
        num_new = (num * alpha[..., None]
                   + jnp.einsum("bkgst,btkh->bkgsh", p, v_i.astype(sdt),
                                preferred_element_type=jnp.float32))
        return (m_new, den_new, num_new), None

    if remat_chunks:
        step = jax.checkpoint(step)

    m0 = jnp.full((B, K, G, S), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, K, G, S), jnp.float32)
    n0 = jnp.zeros((B, K, G, S, hd), jnp.float32)
    (m, den, num), _ = lax.scan(step, (m0, d0, n0),
                                (jnp.arange(n_chunks), kc, vc))
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode: q (B,1,H,hd) against (B,Smax,K,hd) caches with
    ``cache_len`` valid entries (scalar or (B,))."""
    B, _, H, hd = q.shape
    Smax, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = (q.astype(jnp.float32) * scale).reshape(B, 1, K, G, hd)
    s = _gqa_scores_einsum(qg, k_cache.astype(jnp.float32))  # (B,K,G,1,Smax)
    pos = jnp.arange(Smax)
    valid = pos[None, :] < jnp.reshape(jnp.asarray(cache_len), (-1, 1))
    s = jnp.where(valid[:, None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w1, b1, w2, b2):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w1) + b1)
    return jnp.einsum("...f,fd->...d", h, w2) + b2


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based capacity dispatch)
# ---------------------------------------------------------------------------

def moe_block(x, router_w, w_gate, w_up, w_down, *, k: int,
              capacity_factor: float = 1.25, groups: int = 1):
    """Top-k MoE with sort-based dispatch into a static-capacity buffer.

    x: (T, D); router_w: (D, E); expert weights: (E, D, F) / (E, F, D).
    Returns (out (T, D), aux) where aux carries router stats — including
    per-expert token loads, the paper's LIB signal at the MoE level.

    groups > 1 (§Perf): dispatch LOCALLY per token group — the argsort /
    scatter / gather batch over a leading group dim that GSPMD shards over
    the data axes, so no device ever materializes the global token array
    (grouped == per-shard capacity, standard in large-scale MoE).
    """
    if groups > 1:
        from ..distributed.ctx import constrain_tokens_grouped
        T, D = x.shape
        assert T % groups == 0, (T, groups)
        xg = constrain_tokens_grouped(x.reshape(groups, T // groups, D))
        out, aux = jax.vmap(
            lambda xx: moe_block(xx, router_w, w_gate, w_up, w_down, k=k,
                                 capacity_factor=capacity_factor))(xg)
        out = out.reshape(T, D)
        aux = {"expert_load": aux["expert_load"].sum(0),
               "dropped_frac": aux["dropped_frac"].mean(),
               "router_z": aux["router_z"].mean(),
               "load_balance": aux["load_balance"].mean()}
        return out, aux
    T, D = x.shape
    E = router_w.shape[-1]
    C = max(1, int(capacity_factor * k * T / E))

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, k)                 # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                        # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topw.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    # rank within expert = index - first index of this expert in sorted order
    first = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - first[e_sorted]
    keep = rank < C
    slot = jnp.where(keep, e_sorted * C + rank, E * C)   # overflow -> dropped

    buf = jnp.zeros((E * C + 1, D), x.dtype)
    buf = buf.at[slot].set(x[flat_t[order]], mode="drop")
    buf = buf[:E * C].reshape(E, C, D)

    h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down)

    y_flat = y.reshape(E * C, D)
    gathered = jnp.where(keep[:, None],
                         y_flat[jnp.clip(slot, 0, E * C - 1)], 0.0)
    out = jnp.zeros((T, D), x.dtype)
    out = out.at[flat_t[order]].add(gathered * flat_w[order][:, None])

    # router aux: per-expert load (tokens routed) and dropped fraction
    load = jnp.bincount(flat_e, length=E)
    aux = {
        "expert_load": load,
        "dropped_frac": 1.0 - keep.mean(),
        "router_z": jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2),
        "load_balance": E * jnp.mean(probs.mean(0) *
                                     (load / jnp.maximum(load.sum(), 1))),
    }
    return out, aux
