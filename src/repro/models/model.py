"""Architecture assembly: init / forward / prefill / decode for all families.

Families: dense (llama/qwen/granite/nemo/VLM-backbone), moe (olmoe, grok),
ssm (mamba2), hybrid (zamba2: SSM stack + shared attention block), encdec
(whisper backbone; audio frontend stubbed to precomputed frame embeddings).

All layer stacks are scanned; blocks are optionally rematerialized
(cfg.remat) so the dry-run activations stay at layer-boundary footprint.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .layers import (apply_mrope, apply_rope, chunked_attention,
                     decode_attention, full_attention, gelu_mlp, layer_norm,
                     moe_block, rms_norm, swiglu)
from .ssm import init_ssm_layer, ssm_layer_apply
from ..distributed.ctx import (attn_bf16, attn_remat, constrain_boundary,
                               moe_groups)

ATTN_CHUNK_THRESHOLD = 2048   # use online-softmax attention above this S
CE_CHUNK = 512                # sequence chunk for the blockwise CE loss


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ===========================================================================
# init
# ===========================================================================

def _init_attn(key, cfg: ModelConfig, dtype):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    p = {
        "wq": (jax.random.normal(ks[0], (D, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (D, K * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (D, K * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * hd, D)) * s
               / math.sqrt(2 * max(cfg.n_layers, 1))).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _init_dense_layer(key, cfg: ModelConfig, dtype):
    D, F = cfg.d_model, cfg.d_ff
    k_attn, k1, k2, k3 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    p = {
        "ln1": jnp.ones((D,), dtype),
        "ln2": jnp.ones((D,), dtype),
        **_init_attn(k_attn, cfg, dtype),
        "w_gate": (jax.random.normal(k1, (D, F)) * s).astype(dtype),
        "w_up": (jax.random.normal(k2, (D, F)) * s).astype(dtype),
        "w_down": (jax.random.normal(k3, (F, D)) * s
                   / math.sqrt(2 * max(cfg.n_layers, 1))).astype(dtype),
    }
    return p


def _init_moe_layer(key, cfg: ModelConfig, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k_attn, kr, k1, k2, k3 = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(D)
    return {
        "ln1": jnp.ones((D,), dtype),
        "ln2": jnp.ones((D,), dtype),
        **_init_attn(k_attn, cfg, dtype),
        "router": (jax.random.normal(kr, (D, E)) * s).astype(dtype),
        "we_gate": (jax.random.normal(k1, (E, D, F)) * s).astype(dtype),
        "we_up": (jax.random.normal(k2, (E, D, F)) * s).astype(dtype),
        "we_down": (jax.random.normal(k3, (E, F, D)) * s
                    / math.sqrt(2 * cfg.n_layers)).astype(dtype),
    }


def _init_encdec_layer(key, cfg: ModelConfig, dtype, cross: bool):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    p = {
        "ln1": jnp.ones((D,), dtype), "ln1_b": jnp.zeros((D,), dtype),
        "ln2": jnp.ones((D,), dtype), "ln2_b": jnp.zeros((D,), dtype),
        **_init_attn(ks[0], cfg, dtype),
        "w1": (jax.random.normal(ks[1], (D, F)) * s).astype(dtype),
        "b1": jnp.zeros((F,), dtype),
        "w2": (jax.random.normal(ks[2], (F, D)) * s).astype(dtype),
        "b2": jnp.zeros((D,), dtype),
    }
    if cross:
        kc = jax.random.split(ks[3], 1)[0]
        p.update({("x" + k): v for k, v in _init_attn(kc, cfg, dtype).items()})
        p["lnx"] = jnp.ones((D,), dtype)
        p["lnx_b"] = jnp.zeros((D,), dtype)
    return p


def _stack(layer_init, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, *args))(keys)


def padded_vocab(cfg: ModelConfig) -> int:
    """Embedding tables padded to a multiple of 256 so the vocab axis always
    shards over the model axis (whisper's 51865, mamba2's 50280...).  Padded
    ids are valid but unused (§Perf C2; standard practice)."""
    return -(-cfg.vocab_size // 256) * 256


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = _dtype(cfg)
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    D, V = cfg.d_model, padded_vocab(cfg)
    params: Dict = {
        "embed": (jax.random.normal(k_emb, (V, D)) / math.sqrt(D)).astype(dtype),
        "final_norm": jnp.ones((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (D, V))
                             / math.sqrt(D)).astype(dtype)

    if cfg.family == "dense":
        params["layers"] = _stack(_init_dense_layer, k_layers, cfg.n_layers,
                                  cfg, dtype)
    elif cfg.family == "moe":
        params["layers"] = _stack(_init_moe_layer, k_layers, cfg.n_layers,
                                  cfg, dtype)
    elif cfg.family == "ssm":
        params["layers"] = _stack(init_ssm_layer, k_layers, cfg.n_layers,
                                  cfg, dtype)
    elif cfg.family == "hybrid":
        params["layers"] = _stack(init_ssm_layer, k_layers, cfg.n_layers,
                                  cfg, dtype)
        shared = _init_dense_layer(k_extra, cfg, dtype)
        params["shared_attn"] = shared
    elif cfg.family == "encdec":
        ke, kd = jax.random.split(k_layers)
        params["enc_layers"] = _stack(partial(_init_encdec_layer, cross=False),
                                      ke, cfg.encoder_layers, cfg, dtype)
        params["dec_layers"] = _stack(partial(_init_encdec_layer, cross=True),
                                      kd, cfg.n_layers, cfg, dtype)
        params["enc_final_norm"] = jnp.ones((D,), dtype)
        params["enc_final_norm_b"] = jnp.zeros((D,), dtype)
        params["final_norm_b"] = jnp.zeros((D,), dtype)
    else:
        raise ValueError(cfg.family)
    return params


# ===========================================================================
# attention block application
# ===========================================================================

def _positions3(positions):
    return jnp.stack([positions, positions, positions])


def _attn_apply(p, cfg: ModelConfig, x, positions, *, causal=True,
                attn_impl="auto", q_offset=0, kv=None, cache=None,
                cache_len=None, prefix=""):
    """Shared attention application.  Returns (out, (k, v) or None).

    kv: precomputed (k, v) for cross attention.
    cache: (k_cache, v_cache) for decode (x is a single step).
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = lambda n: p[prefix + n]
    q = jnp.einsum("bsd,de->bse", x, g("wq")).reshape(B, S, H, hd)
    if kv is None:
        k = jnp.einsum("bsd,de->bse", x, g("wk")).reshape(B, S, K, hd)
        v = jnp.einsum("bsd,de->bse", x, g("wv")).reshape(B, S, K, hd)
    else:
        k, v = kv
    if cfg.qk_norm and (prefix + "q_norm") in p:
        q = rms_norm(q, g("q_norm"), cfg.norm_eps)
        k = rms_norm(k, g("k_norm"), cfg.norm_eps) if kv is None else k
    if positions is not None and kv is None:
        if cfg.mrope:
            q = apply_mrope(q, _positions3(positions), cfg.rope_theta)
            k = apply_mrope(k, _positions3(positions), cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        k_cache, v_cache = cache
        idx = jnp.reshape(cache_len, ())
        k_cache = lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), idx, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), idx, axis=1)
        o = decode_attention(q, k_cache, v_cache, idx + 1)
        kv_out = (k_cache, v_cache)
    else:
        T = k.shape[1]
        use_chunked = (attn_impl == "chunked" or
                       (attn_impl == "auto" and T > ATTN_CHUNK_THRESHOLD))
        if use_chunked:
            o = chunked_attention(
                q, k, v, causal=causal, q_offset=q_offset,
                score_dtype=jnp.bfloat16 if attn_bf16() else None,
                remat_chunks=attn_remat())
        else:
            o = full_attention(q, k, v, causal=causal, q_offset=q_offset)
        kv_out = (k, v)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * hd), g("wo"))
    return out, kv_out


def _dense_block(p, cfg, x, positions, attn_impl, collect_kv=False,
                 cache=None, cache_len=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, kv = _attn_apply(p, cfg, h, positions, attn_impl=attn_impl,
                        cache=cache, cache_len=cache_len)
    x = x + o
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(h2, p["w_gate"], p["w_up"], p["w_down"])
    return (x, kv) if (collect_kv or cache is not None) else (x, None)


def _moe_block_apply(p, cfg, x, positions, attn_impl, cache=None,
                     cache_len=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, kv = _attn_apply(p, cfg, h, positions, attn_impl=attn_impl,
                        cache=cache, cache_len=cache_len)
    x = x + o
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    B, S, D = h2.shape
    # NOTE §Perf B2 (refuted): constraining expert weights to gather
    # (replicate D over dp) backfired 14x — GSPMD replicated the expert
    # grad einsums across the data axes.  Kept out; see EXPERIMENTS.md.
    y, aux = moe_block(h2.reshape(B * S, D), p["router"], p["we_gate"],
                       p["we_up"], p["we_down"], k=cfg.experts_per_token,
                       capacity_factor=cfg.capacity_factor,
                       groups=(moe_groups() if cache is None else 1))
    return x + y.reshape(B, S, D), kv, aux


# ===========================================================================
# forward (train / prefill trunk)
# ===========================================================================

def forward(cfg: ModelConfig, params: Dict, tokens, *, embeds=None,
            attn_impl: str = "auto", collect_cache: bool = False):
    """Token trunk -> final hidden states (B, S, D).

    collect_cache: also return per-layer (k, v) stacks (prefill path).
    Returns (hidden, cache_or_None, aux dict).
    """
    if cfg.family == "encdec":
        return _encdec_forward(cfg, params, tokens, embeds=embeds,
                               collect_cache=collect_cache)

    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux: Dict = {}

    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    if cfg.family == "dense":
        @remat
        def body(x, p):
            x, kv = _dense_block(p, cfg, x, positions, attn_impl,
                                 collect_kv=collect_cache)
            return constrain_boundary(x), kv if collect_cache else None
        x, kvs = lax.scan(body, constrain_boundary(x), params["layers"])
        cache = kvs

    elif cfg.family == "moe":
        @remat
        def body(x, p):
            x, kv, aux_l = _moe_block_apply(p, cfg, x, positions, attn_impl)
            out = (kv if collect_cache else None, aux_l["expert_load"])
            return constrain_boundary(x), out
        x, (kvs, loads) = lax.scan(body, constrain_boundary(x),
                                   params["layers"])
        aux["expert_load"] = loads            # (L, E) — MoE LIB signal
        cache = kvs

    elif cfg.family == "ssm":
        @remat
        def body(x, p):
            x, st = ssm_layer_apply(p, x, cfg, collect_state=collect_cache)
            return constrain_boundary(x), st
        x, cache = lax.scan(body, constrain_boundary(x), params["layers"])

    elif cfg.family == "hybrid":
        x, cache = _hybrid_forward(cfg, params, x, positions, attn_impl,
                                   collect_cache)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, cache, aux


def _hybrid_forward(cfg, params, x, positions, attn_impl, collect_cache):
    """Zamba2: scan segments of `attn_every` SSM layers, apply the *shared*
    attention block after each segment."""
    n_seg = cfg.n_layers // cfg.attn_every
    assert n_seg * cfg.attn_every == cfg.n_layers, "attn_every must divide n_layers"
    seg_params = jax.tree.map(
        lambda a: a.reshape((n_seg, cfg.attn_every) + a.shape[1:]),
        params["layers"])
    shared = params["shared_attn"]
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    @remat
    def segment(x, seg_p):
        def inner(x, p):
            x, st = ssm_layer_apply(p, x, cfg, collect_state=collect_cache)
            return x, st
        x, states = lax.scan(inner, x, seg_p)
        x, kv = _dense_block(shared, cfg, x, positions, attn_impl,
                             collect_kv=collect_cache)
        out = (states, kv) if collect_cache else None
        return constrain_boundary(x), out

    x, outs = lax.scan(segment, x, seg_params)
    return x, outs


def _sinusoid(S, D):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(D // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _encdec_forward(cfg, params, tokens, *, embeds, collect_cache):
    """Whisper backbone.  embeds: (B, encoder_seq, D) stub frame embeddings."""
    assert embeds is not None, "encdec needs frontend embeddings"
    B, Senc, D = embeds.shape
    h = embeds.astype(_dtype(cfg)) + _sinusoid(Senc, D).astype(_dtype(cfg))

    def enc_body(x, p):
        a = layer_norm(x, p["ln1"], p["ln1_b"], cfg.norm_eps)
        o, _ = _attn_apply(p, cfg, a, None, causal=False)
        x = x + o
        m = layer_norm(x, p["ln2"], p["ln2_b"], cfg.norm_eps)
        x = x + gelu_mlp(m, p["w1"], p["b1"], p["w2"], p["b2"])
        return constrain_boundary(x), None
    h, _ = lax.scan(enc_body, h, params["enc_layers"])
    enc_out = layer_norm(h, params["enc_final_norm"],
                         params["enc_final_norm_b"], cfg.norm_eps)

    Bd, S = tokens.shape
    x = params["embed"][tokens] + _sinusoid(S, D).astype(_dtype(cfg))

    def dec_body(x, p):
        a = layer_norm(x, p["ln1"], p["ln1_b"], cfg.norm_eps)
        o, kv = _attn_apply(p, cfg, a, None, causal=True)
        x = x + o
        c = layer_norm(x, p["lnx"], p["lnx_b"], cfg.norm_eps)
        xk = jnp.einsum("bsd,de->bse", enc_out,
                        p["xwk"]).reshape(B, Senc, cfg.n_kv_heads, cfg.head_dim)
        xv = jnp.einsum("bsd,de->bse", enc_out,
                        p["xwv"]).reshape(B, Senc, cfg.n_kv_heads, cfg.head_dim)
        o2, _ = _attn_apply(p, cfg, c, None, causal=False, kv=(xk, xv),
                            prefix="x")
        x = x + o2
        m = layer_norm(x, p["ln2"], p["ln2_b"], cfg.norm_eps)
        x = x + gelu_mlp(m, p["w1"], p["b1"], p["w2"], p["b2"])
        return constrain_boundary(x), ((kv, xk, xv) if collect_cache
                                       else None)

    x, kvs = lax.scan(dec_body, x, params["dec_layers"])
    x = layer_norm(x, params["final_norm"], params["final_norm_b"],
                   cfg.norm_eps)
    return x, kvs, {"enc_out": enc_out}


# ===========================================================================
# logits & loss
# ===========================================================================

def _head(cfg, params):
    return (params["embed"].T if cfg.tie_embeddings else params["lm_head"])


def logits_fn(cfg, params, hidden):
    return jnp.einsum("bsd,dv->bsv", hidden, _head(cfg, params))


def chunked_ce_loss(cfg, params, hidden, labels, z_loss: float = 1e-4):
    """Blockwise cross-entropy: never materializes (B, S, V) logits.
    hidden (B,S,D), labels (B,S) int32.  Returns scalar mean loss."""
    B, S, D = hidden.shape
    head = _head(cfg, params)
    n_chunks = -(-S // CE_CHUNK)
    pad = n_chunks * CE_CHUNK - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, n_chunks, CE_CHUNK, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, CE_CHUNK).transpose(1, 0, 2)

    @jax.checkpoint
    def step(acc, inp):
        # checkpointed: backward recomputes the (B, CE_CHUNK, V) logits
        # instead of stashing them per chunk (§Perf A4)
        h, l = inp
        lg = jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.clip(l, 0)[..., None], axis=-1)[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        nll = ((lse - gold) + z_loss * lse ** 2) * valid
        return (acc[0] + nll.sum(), acc[1] + valid.sum()), None

    (tot, cnt), _ = lax.scan(step, (jnp.float32(0), jnp.float32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params, batch, attn_impl: str = "auto"):
    """batch: {"tokens": (B,S), "labels": (B,S), ["embeds"]}."""
    hidden, _, aux = forward(cfg, params, batch["tokens"],
                             embeds=batch.get("embeds"), attn_impl=attn_impl)
    loss = chunked_ce_loss(cfg, params, hidden, batch["labels"])
    return loss, aux
