"""Mamba2 — SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked matmul formulation (the TPU-friendly one: intra-chunk work is dense
MXU matmuls, inter-chunk state passing is a short ``lax.scan``):

    within chunk c:  Y_diag = (C B^T ∘ L) (dt·x)        L = exp(segsum(dt·A))
    chunk states:    S_c    = (dt·B · decay_to_end)^T (x)
    across chunks:   h_{c+1} = exp(sum dt·A)_c · h_c + S_c
    offset:          Y_off  = C h_prev · decay_from_start

The same tiling is implemented as a Pallas TPU kernel in
``repro.kernels.ssd_scan``; this module is the lowering-portable reference
used by the models and the dry-run.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax


def segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k in (j, i]} x[..., k]
    (lower-triangular), -inf above the diagonal."""
    Tq = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Tq, Tq), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD scan.

    x:  (b, S, nh, hp)   per-head inputs
    dt: (b, S, nh)       positive step sizes (softplus'd)
    A:  (nh,)            negative decay rates
    B:  (b, S, st)       input projection (ngroups=1, shared across heads)
    C:  (b, S, st)       output projection
    Returns y: (b, S, nh, hp) and final state (b, nh, hp, st).
    """
    b, S, nh, hp = x.shape
    st = B.shape[-1]
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    xc = x.reshape(b, nc, chunk, nh, hp)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, st)
    Cc = C.reshape(b, nc, chunk, st)

    dA = dtc * A[None, None, None, :]                    # (b,nc,Q,nh)
    dA_cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum
    dA_total = dA_cum[:, :, -1]                          # (b,nc,nh)

    xdt = xc * dtc[..., None]                            # (b,nc,Q,nh,hp)

    # ---- intra-chunk (diagonal) term --------------------------------------
    # L[i,j] = exp(segsum dA) lower-tri; scores = C_i · B_j
    L = jnp.exp(segsum(jnp.moveaxis(dA, 3, 2)))          # (b,nc,nh,Q,Q)
    scores = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)       # (b,nc,Q,Q)
    M = scores[:, :, None] * L                           # (b,nc,nh,Q,Q)
    Y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xdt)

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(dA_total[:, :, None, :] - dA_cum)   # (b,nc,Q,nh)
    S_c = jnp.einsum("bcjs,bcjh,bcjhp->bchps",
                     Bc, decay_to_end * dtc, xc)         # (b,nc,nh,hp,st)

    # ---- inter-chunk recurrence -------------------------------------------
    def step(h, inp):
        S_i, g = inp                                     # g: (b,nh)
        h_next = h * jnp.exp(g)[..., None, None] + S_i
        return h_next, h                                  # emit state *before* chunk

    h0 = jnp.zeros((b, nh, hp, st), jnp.float32)
    h_last, h_prevs = lax.scan(step,
                               h0,
                               (jnp.moveaxis(S_c, 1, 0).astype(jnp.float32),
                                jnp.moveaxis(dA_total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (b,nc,nh,hp,st)

    # ---- inter-chunk (offset) term ----------------------------------------
    decay_from_start = jnp.exp(dA_cum)                   # (b,nc,Q,nh)
    Y_off = jnp.einsum("bcis,bchps,bcih->bcihp",
                       Cc, h_prevs.astype(Cc.dtype), decay_from_start)

    y = (Y_diag + Y_off).reshape(b, S, nh, hp)
    return y.astype(x.dtype), h_last.astype(jnp.float32)


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token recurrence.  state: (b,nh,hp,st); x_t: (b,nh,hp);
    dt_t: (b,nh); B_t/C_t: (b,st)."""
    dA = jnp.exp(dt_t * A[None, :])                      # (b,nh)
    inc = jnp.einsum("bhp,bs,bh->bhps", x_t, B_t, dt_t)
    state = state * dA[..., None, None] + inc
    y = jnp.einsum("bhps,bs->bhp", state, C_t)
    return state, y.astype(x_t.dtype)


def causal_conv1d(x, w, conv_state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: (b,S,ch), w: (k,ch).
    Training path: full-sequence conv.  Decode path: pass conv_state
    (b, k-1, ch) and S == 1; returns (y, new_state)."""
    k = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
        return jax.nn.silu(y), xp[:, -(k - 1):] if k > 1 else None
    window = jnp.concatenate([conv_state, x], axis=1)    # (b,k,ch)
    y = jnp.einsum("bkc,kc->bc", window, w)[:, None]
    return jax.nn.silu(y), window[:, 1:]


def ssm_layer_apply(p: Dict, x, cfg, decode_cache: Optional[Dict] = None,
                    collect_state: bool = False):
    """One Mamba2 block. x: (b,S,D).

    p: {ln, in_proj, conv_w, A_log, D, gate_norm, out_proj, dt_bias}
    decode_cache: {"conv": (b,k-1,ch), "state": (b,nh,hp,st)} for S==1.
    collect_state: full-sequence (prefill) path also returns the final
    {"conv", "state"} cache.
    Returns (y, new_cache_or_None).
    """
    b, S, Dm = x.shape
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    hp = cfg.ssm_headdim

    h = rms_norm_local(x, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [di, di + di + 2 * st], axis=-1)
    # xbc -> conv -> x, B, C
    if decode_cache is None:
        xbc, conv_tail = causal_conv1d(xbc, p["conv_w"])
        new_conv = conv_tail
    else:
        xbc, new_conv = causal_conv1d(xbc, p["conv_w"], decode_cache["conv"])
    xs, B, C = jnp.split(xbc, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])      # (b,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (nh,)
    xh = xs.reshape(b, S, nh, hp)

    if decode_cache is None:
        y, last_state = ssd_chunked(xh, dt, A,
                                    B.astype(jnp.float32),
                                    C.astype(jnp.float32), cfg.ssm_chunk)
        new_cache = None
        if collect_state:
            new_cache = {"conv": new_conv, "state": last_state}
    else:
        state, y1 = ssd_decode_step(decode_cache["state"],
                                    xh[:, 0].astype(jnp.float32),
                                    dt[:, 0], A,
                                    B[:, 0].astype(jnp.float32),
                                    C[:, 0].astype(jnp.float32))
        y = y1[:, None]
        new_cache = {"conv": new_conv, "state": state}

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, S, di)
    # gated RMSNorm (Mamba2): norm(y * silu(z))
    y = rms_norm_local(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return x + out.astype(x.dtype), new_cache


def rms_norm_local(x, w, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def init_ssm_layer(key, cfg, dtype) -> Dict:
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    d_proj = 2 * di + 2 * st + nh
    scale = 1.0 / math.sqrt(D)
    return {
        "ln": jnp.ones((D,), dtype),
        "in_proj": (jax.random.normal(k1, (D, d_proj)) * scale).astype(dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, di + 2 * st))
                   * 0.5).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(k3, (di, D)) * scale).astype(dtype),
    }
