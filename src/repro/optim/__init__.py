from .adamw import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                    global_norm, schedule_lr)
