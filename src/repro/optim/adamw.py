"""AdamW + schedules, global-norm clipping, optional gradient compression.

Functional pytree optimizer (no external deps).  Moments can be kept in a
reduced dtype (``moment_dtype``) for the memory-constrained dry-run configs
(grok-1-314b, qwen2-vl-72b).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Dict
    v: Dict


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine | linear | constant
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def schedule_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params,
                 cfg: AdamWConfig) -> Tuple[Dict, AdamWState, Dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    step = state.step + 1
    lr = schedule_lr(cfg, state.step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
