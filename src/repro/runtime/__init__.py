from .trainer import Trainer, TrainerConfig, SimulatedFailure
from .policy_trainer import (PolicyTrainer, PolicyTrainerConfig,
                             TransitionDataset, train_policy_state)

__all__ = ["Trainer", "TrainerConfig", "SimulatedFailure", "PolicyTrainer",
           "PolicyTrainerConfig", "TransitionDataset", "train_policy_state"]
