"""Offline trainer for the learned selection policy.

This is the first real consumer of the training stack the repo has carried
dormant since the runtime PRs: the net is the ``models/layers.py`` MLP
block (``gelu_mlp``) behind one feature layer, the optimizer is
``optim/adamw.py`` (schedules, global-norm clipping), and the run
discipline is ``runtime/trainer.py``'s checkpoint/restart contract —
atomic sharded saves through :class:`~repro.checkpoint.manager.
CheckpointManager`, async checkpointing off the critical path, SIGTERM →
final synchronous save, ``failure_rate`` fault injection with
restore-and-replay, and **bit-identical resume** (test-enforced): batches
are a pure function of ``(seed, step)``, so an interrupted run restored
from its latest checkpoint replays to exactly the uninterrupted result.

Training data is the counterfactual transition log (``repro.sim.translog``):
every row carries the priced cost of *all 12* portfolio algorithms for its
context, so the net is fit by plain supervised regression of row-centered
log costs — a contextual bandit with full feedback, no off-policy
correction.  :class:`TransitionDataset` holds out whole ``(app, system)``
cells (never single rows) so evaluation measures transfer to configurations
the net has *never seen*, and feature normalization is folded into the
first layer at export time, so the deployed numpy forward
(:func:`repro.core.learned.mlp_forward`) consumes raw feature rows.
"""

from __future__ import annotations

import math
import signal
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.learned import N_FEATURES, make_learned_state
from ..models.layers import gelu_mlp
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .trainer import SimulatedFailure

__all__ = ["TransitionDataset", "PolicyTrainerConfig", "PolicyTrainer",
           "forward", "train_policy_state"]


def forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """The training-side net: feature layer + one ``gelu_mlp`` block.  The
    deployed numpy twin is ``repro.core.learned.mlp_forward`` (same tanh
    GELU approximation, so argmins agree)."""
    h0 = jax.nn.gelu(x @ params["w0"] + params["b0"])
    return gelu_mlp(h0, params["w1"], params["b1"], params["w2"],
                    params["b2"])


class TransitionDataset:
    """Translog arrays + cell-keyed split + deterministic batching.

    ``holdout_cells`` names ``"app|system"`` keys whose rows are excluded
    from training entirely — the held-out set the bench gates regret on.
    Targets are row-centered log costs (the per-row mean is scale and has
    no bearing on the argmin; centering removes it so the net spends
    capacity on *ranking* algorithms, not predicting absolute runtimes).

    ``batch_at(step)`` is a pure function of ``(seed, step)`` — the
    :class:`~repro.data.pipeline.TokenPipeline` resume contract — which is
    what makes checkpoint-restored training bit-identical.
    """

    def __init__(self, arrays: Dict[str, np.ndarray],
                 holdout_cells: Sequence[str] = (), seed: int = 0):
        X = np.asarray(arrays["features"], np.float64)
        costs = np.asarray(arrays["costs"], np.float64)
        if len(X) == 0:
            raise ValueError("empty transition log")
        if X.shape[1] != N_FEATURES:
            raise ValueError(f"translog has {X.shape[1]} features, this "
                             f"build extracts {N_FEATURES}")
        cell = np.asarray(arrays["cell"], np.int64)
        self.cell_keys = [str(k) for k in arrays["cell_keys"]]
        logc = np.log(np.maximum(costs, 1e-12))
        self.X = X
        self.costs = costs
        self.Y = logc - logc.mean(axis=1, keepdims=True)
        self.cell = cell
        self.seed = int(seed)
        self.holdout_cells = sorted(set(holdout_cells))
        unknown = [c for c in self.holdout_cells if c not in self.cell_keys]
        if unknown:
            raise ValueError(f"holdout cells {unknown} not in the log "
                             f"(have {self.cell_keys})")
        hold_ids = {self.cell_keys.index(c) for c in self.holdout_cells}
        mask = np.array([c in hold_ids for c in cell])
        self.train_idx = np.flatnonzero(~mask)
        self.holdout_idx = np.flatnonzero(mask)
        if len(self.train_idx) == 0:
            raise ValueError("holdout split leaves no training rows")
        # normalization over the TRAIN split only (no holdout leakage)
        Xt = X[self.train_idx]
        self.mu = Xt.mean(axis=0)
        self.sigma = np.maximum(Xt.std(axis=0), 1e-6)

    @property
    def n_train(self) -> int:
        return len(self.train_idx)

    @property
    def n_actions(self) -> int:
        return self.costs.shape[1]

    def normalize(self, X: np.ndarray) -> np.ndarray:
        return (np.asarray(X, np.float64) - self.mu) / self.sigma

    def batch_at(self, step: int, batch_size: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic O(1) batch for ``step`` — pure in (seed, step), so
        replaying steps after a restore reproduces the exact gradient
        sequence of the uninterrupted run."""
        rng = np.random.default_rng((self.seed, int(step)))
        idx = self.train_idx[rng.integers(0, self.n_train, batch_size)]
        return (self.normalize(self.X[idx]).astype(np.float32),
                self.Y[idx].astype(np.float32))

    def split(self, which: str = "holdout"
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(normalized X, centered-log-cost Y, raw costs) of a split."""
        idx = self.train_idx if which == "train" else self.holdout_idx
        return (self.normalize(self.X[idx]).astype(np.float32),
                self.Y[idx].astype(np.float32), self.costs[idx])


@dataclass
class PolicyTrainerConfig:
    ckpt_dir: str
    hidden: int = 32                 # width of both hidden layers
    n_steps: int = 400
    batch_size: int = 128
    seed: int = 0
    ckpt_every: int = 25
    async_ckpt: bool = True
    #: stddev of Gaussian jitter added to (z-scored) features per batch —
    #: the net must transfer to (app, system) pairings it never saw, and
    #: an unregularized MLP extrapolates arbitrarily into novel feature
    #: combinations; input noise forces a smooth ranking surface
    aug_sigma: float = 0.25
    failure_rate: float = 0.0        # P(node failure) per step (injected)
    failure_seed: int = 1234
    max_restarts: int = 10


class PolicyTrainer:
    """Supervised contextual-bandit training with the Trainer's
    fault-tolerance discipline (checkpoint/restart, SIGTERM final save,
    injected failures, bit-identical resume)."""

    def __init__(self, dataset: TransitionDataset, cfg: PolicyTrainerConfig,
                 opt_cfg: Optional[AdamWConfig] = None):
        self.ds = dataset
        self.cfg = cfg
        self.opt_cfg = opt_cfg or AdamWConfig(
            lr=3e-3, weight_decay=1e-4, clip_norm=1.0,
            warmup_steps=max(10, cfg.n_steps // 20),
            total_steps=cfg.n_steps)
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.metrics_log: List[Dict] = []
        self._preempted = False
        self._restarts = 0
        self._fail_rng = np.random.default_rng(cfg.failure_seed)
        self._step_fn = jax.jit(self._step)

    # -- lifecycle ----------------------------------------------------------
    def _init_state(self):
        h, a = self.cfg.hidden, self.ds.n_actions
        keys = jax.random.split(jax.random.PRNGKey(self.cfg.seed), 3)

        def dense(key, fan_in, fan_out):
            scale = math.sqrt(2.0 / fan_in)
            return jax.random.normal(key, (fan_in, fan_out),
                                     jnp.float32) * scale

        params = {
            "w0": dense(keys[0], N_FEATURES, h),
            "b0": jnp.zeros((h,), jnp.float32),
            "w1": dense(keys[1], h, h),
            "b1": jnp.zeros((h,), jnp.float32),
            "w2": dense(keys[2], h, a),
            "b2": jnp.zeros((a,), jnp.float32),
        }
        return params, adamw_init(params, self.opt_cfg)

    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        params, opt = self._init_state()
        if latest is None:
            return 0, params, opt
        state = self.ckpt.restore(latest, {"params": params, "opt": opt})
        return latest, state["params"], state["opt"]

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    # -- training -----------------------------------------------------------
    def _step(self, params, opt, x, y):
        def loss_fn(p):
            pred = forward(p, x)
            return jnp.mean((pred - y) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, metrics = adamw_update(grads, opt, params, self.opt_cfg)
        return params, opt, {"loss": loss, **metrics}

    def train(self, n_steps: Optional[int] = None) -> Dict:
        n_steps = self.cfg.n_steps if n_steps is None else int(n_steps)
        step, params, opt = self._restore_or_init()
        while step < n_steps:
            try:
                x, y = self.ds.batch_at(step, self.cfg.batch_size)
                if self.cfg.aug_sigma > 0.0:
                    # augmentation is pure in (seed, step) like the batch
                    # itself, so resume stays bit-identical
                    arng = np.random.default_rng(
                        (self.cfg.seed, int(step), 1))
                    x = x + arng.normal(
                        scale=self.cfg.aug_sigma,
                        size=x.shape).astype(np.float32)
                if (self.cfg.failure_rate > 0.0 and
                        self._fail_rng.random() < self.cfg.failure_rate):
                    raise SimulatedFailure(f"injected node failure @ {step}")
                params, opt, metrics = self._step_fn(params, opt, x, y)
                jax.block_until_ready(metrics["loss"])
                self.metrics_log.append({"step": step,
                                         "loss": float(metrics["loss"])})
                step += 1
                if step % self.cfg.ckpt_every == 0:
                    state = {"params": params, "opt": opt}
                    if self.cfg.async_ckpt:
                        self.ckpt.async_save(step, state)
                    else:
                        self.ckpt.save(step, state)
                if self._preempted:
                    break
            except SimulatedFailure:
                self._restarts += 1
                if self._restarts > self.cfg.max_restarts:
                    raise
                # relaunch path: restore latest checkpoint, replay data
                self.ckpt.wait()
                step, params, opt = self._restore_or_init()
        self.ckpt.wait()
        self.ckpt.save(step, {"params": params, "opt": opt})
        return {"final_step": step, "params": params, "opt": opt,
                "restarts": self._restarts,
                "preempted": self._preempted,
                "losses": [m["loss"] for m in self.metrics_log]}

    # -- evaluation + export ------------------------------------------------
    def regret(self, params, which: str = "holdout") -> float:
        """Mean relative regret of the net's argmin vs the per-row best
        counterfactual cost, over a dataset split."""
        x, _, costs = self.ds.split(which)
        if len(x) == 0:
            return float("nan")
        pred = np.asarray(forward(params, jnp.asarray(x)))
        chosen = costs[np.arange(len(costs)), pred.argmin(axis=1)]
        best = costs.min(axis=1)
        return float(np.mean((chosen - best) / np.maximum(best, 1e-12)))

    def export_state(self, params, meta: Optional[dict] = None) -> dict:
        """The deployable ``LearnedPolicy`` state.  The net was trained on
        z-scored features; the deployed forward takes raw rows, so the
        normalization is folded into the first layer:
        ``z @ w0 + b0 == x @ (w0/sigma) + (b0 - (mu/sigma) @ w0)``."""
        p = {k: np.asarray(v, np.float64) for k, v in params.items()}
        sigma, mu = self.ds.sigma, self.ds.mu
        folded = dict(p)
        folded["w0"] = p["w0"] / sigma[:, None]
        folded["b0"] = p["b0"] - (mu / sigma) @ p["w0"]
        info = {"n_steps": self.cfg.n_steps, "hidden": self.cfg.hidden,
                "seed": self.cfg.seed, "n_train": self.ds.n_train,
                "holdout_cells": self.ds.holdout_cells}
        info.update(meta or {})
        return make_learned_state(
            {k: np.asarray(v, np.float32) for k, v in folded.items()},
            reward="LT", meta=info)


def train_policy_state(arrays: Dict[str, np.ndarray], ckpt_dir: str,
                       holdout_cells: Sequence[str] = (),
                       cfg: Optional[PolicyTrainerConfig] = None,
                       opt_cfg: Optional[AdamWConfig] = None
                       ) -> Tuple[dict, Dict]:
    """One-call train-and-export: returns (LearnedPolicy state, the
    trainer's result dict augmented with train/holdout regret)."""
    ds = TransitionDataset(arrays, holdout_cells=holdout_cells)
    cfg = cfg or PolicyTrainerConfig(ckpt_dir=ckpt_dir)
    if cfg.ckpt_dir != ckpt_dir:
        cfg = PolicyTrainerConfig(**{**cfg.__dict__, "ckpt_dir": ckpt_dir})
    tr = PolicyTrainer(ds, cfg, opt_cfg=opt_cfg)
    tr.install_preemption_handler()
    result = tr.train()
    result["train_regret"] = tr.regret(result["params"], "train")
    if len(ds.holdout_idx):
        result["holdout_regret"] = tr.regret(result["params"], "holdout")
    return tr.export_state(result["params"]), result
