"""Fault-tolerant training runtime.

Production posture on a 1000+-node fleet, scaled to this container:

* checkpoint/restart — atomic sharded checkpoints (repro.checkpoint), async
  save off the critical path, deterministic O(1) data resume (repro.data);
* failure handling — ``failure_rate`` injects SimulatedFailure at step
  boundaries; the driver restores the latest checkpoint and replays.  The
  restart-equivalence test asserts bit-identical final params vs an
  uninterrupted run;
* preemption — SIGTERM triggers a final synchronous save before exit;
* straggler response — when step time drifts >10 % above its running mean
  (the paper's ExhaustiveSel LIB-re-trigger rule), the autotuner's selector
  re-opens exploration so a new plan can be chosen;
* elastic restart — restore() re-places shards onto whatever mesh the
  relaunched job has (repro.checkpoint elastic path).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, TokenPipeline
from ..distributed.autotune import StepAutoTuner
from ..models.model import init_params
from ..optim.adamw import AdamWConfig, adamw_init


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 25
    async_ckpt: bool = True
    failure_rate: float = 0.0        # P(node failure) per step (injected)
    failure_seed: int = 1234
    max_restarts: int = 10
    straggler_threshold: float = 1.10


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 step_fn: Optional[Callable] = None,
                 autotuner: Optional[StepAutoTuner] = None,
                 seed: int = 0):
        assert (step_fn is None) != (autotuner is None), \
            "exactly one of step_fn / autotuner"
        self.cfg, self.opt_cfg, self.data_cfg, self.tcfg = (
            cfg, opt_cfg, data_cfg, tcfg)
        self.step_fn = jax.jit(step_fn) if step_fn is not None else None
        self.autotuner = autotuner
        self.pipeline = TokenPipeline(data_cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.seed = seed
        self.metrics_log: List[Dict] = []
        self._preempted = False
        self._restarts = 0
        self._fail_rng = np.random.default_rng(tcfg.failure_seed)

    # -- lifecycle -------------------------------------------------------------
    def _init_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(self.seed))
        opt = adamw_init(params, self.opt_cfg)
        return params, opt

    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        params, opt = self._init_state()
        if latest is None:
            return 0, params, opt
        state = self.ckpt.restore(latest, {"params": params, "opt": opt})
        return latest, state["params"], state["opt"]

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    # -- training ---------------------------------------------------------------
    def train(self, n_steps: int) -> Dict:
        start, params, opt = self._restore_or_init()
        step = start
        step_times: List[float] = []
        while step < n_steps:
            try:
                batch = {k: jax.numpy.asarray(v)
                         for k, v in self.pipeline.batch_at(step).items()}
                if (self.tcfg.failure_rate > 0.0 and
                        self._fail_rng.random() < self.tcfg.failure_rate):
                    raise SimulatedFailure(f"injected node failure @ {step}")
                t0 = time.perf_counter()
                if self.autotuner is not None:
                    (params, opt, metrics), plan, dt = self.autotuner.step(
                        params, opt, batch)
                else:
                    params, opt, metrics = self.step_fn(params, opt, batch)
                    jax.block_until_ready(metrics["loss"])
                    dt = time.perf_counter() - t0
                    plan = "fixed"
                step_times.append(dt)
                self._straggler_check(step_times)
                self.metrics_log.append({
                    "step": step, "loss": float(metrics["loss"]),
                    "plan": plan, "time": dt})
                step += 1
                if step % self.tcfg.ckpt_every == 0:
                    state = {"params": params, "opt": opt}
                    if self.tcfg.async_ckpt:
                        self.ckpt.async_save(step, state)
                    else:
                        self.ckpt.save(step, state)
                if self._preempted:
                    break
            except SimulatedFailure:
                self._restarts += 1
                if self._restarts > self.tcfg.max_restarts:
                    raise
                # relaunch path: restore latest checkpoint, replay data
                self.ckpt.wait()
                step, params, opt = self._restore_or_init()
        self.ckpt.wait()
        self.ckpt.save(step, {"params": params, "opt": opt})
        return {"final_step": step, "params": params, "opt": opt,
                "restarts": self._restarts,
                "preempted": self._preempted,
                "losses": [m["loss"] for m in self.metrics_log]}

    def _straggler_check(self, times: List[float]) -> None:
        """Paper's LIB-drift rule applied to step-time drift: re-open the
        plan search when the current step runs >10 % above the mean."""
        if self.autotuner is None or len(times) < 5:
            return
        mean = float(np.mean(times[:-1]))
        if times[-1] > self.tcfg.straggler_threshold * mean:
            sel = self.autotuner.service._record(
                self.autotuner.region).selector
            if hasattr(sel, "_selected"):
                sel._times[:] = np.inf
                sel._phase = 0
                sel._selected = None
