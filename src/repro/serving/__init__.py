from .engine import (DispatchSimulator, ContinuousBatcher, ReplicaCostModel,
                     WaveStats, WaveWhatIf)
from .fleet import (AdmissionControl, ArrivalTrace, FleetReport,
                    FleetSimulator, FleetView, LeastOutstandingRouter,
                    RoundRobinRouter, RouterPolicy, WhatIfRouter,
                    make_router, make_trace)
