from .engine import (DispatchSimulator, ContinuousBatcher, ReplicaCostModel,
                     WaveStats, WaveWhatIf)
