from .engine import (DispatchSimulator, ContinuousBatcher, ReplicaCostModel,
                     WaveStats, WaveWhatIf)
from .fleet import (AdmissionControl, ArrivalTrace, FleetReport,
                    FleetSimulator, FleetView, LeastOutstandingRouter,
                    RecoveryLedger, RecoveryPolicy, RoundRobinRouter,
                    RouterPolicy, RunJournal, WhatIfRouter, make_router,
                    make_trace)
