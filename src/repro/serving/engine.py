"""Serving engine — the paper's technique at dispatch granularity (L3).

Structure of the adaptation (DESIGN.md §2):

    OpenMP threads        -> data-parallel replica groups
    loop iterations       -> queued requests (heterogeneous token counts)
    chunk of iterations   -> batch of requests a replica self-assigns
    scheduling algorithm  -> the SAME 12-algorithm portfolio (repro.core)
    loop instance         -> one dispatch wave over the pending queue
    LIB (Eq. 8)           -> imbalance of replica busy-times per wave
    selection methods     -> RandomSel/ExhaustiveSel/ExpertSel/QLearn/SARSA
                             /Hybrid (expert-seeded RL), via SelectionService

``DispatchSimulator`` runs waves through the DES engine (replica service
time = token-count cost model measured from a real decode step or supplied
analytically).  ``ContinuousBatcher`` is the live path: real jitted decode
on slots, used by examples/serve driver.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import (N_ALGORITHMS, SelectionService, exp_chunk, is_sim_policy,
                    percent_load_imbalance, resolve_sim_policy)
from ..core.api import Observation
from ..core.portfolio import make_algorithm
from ..core.simpolicy import Candidate, SimUnavailable
from ..data.pipeline import Request
from ..sim.backends import get_backend


@dataclass
class WaveStats:
    wave: int
    algorithm: int
    n_requests: int
    makespan: float
    lib: float
    chunks: int


@dataclass
class ReplicaCostModel:
    """Service time of a batch of requests on one replica group.

    t = fixed + per_token * sum(tokens) + per_request * n
    (calibrate per_token from a measured decode step)."""
    fixed: float = 2e-3
    per_token: float = 10e-6
    per_request: float = 0.5e-3

    def cost(self, tokens: np.ndarray) -> float:
        return (self.fixed + self.per_token * float(tokens.sum())
                + self.per_request * len(tokens))


class WaveWhatIf:
    """Candidate simulator over ``DispatchSimulator.what_if`` — the serving
    side of simulation-assisted selection.  ``run_wave`` binds the pending
    request queue before consulting the policy; ``price`` fans the candidate
    set (algorithm x chunk variant) into batched what-if calls against the
    *current* replica busy-state.

    Predictions carry the wave makespan ONLY (``what_if_wave`` returns no
    per-replica finishes), so every reward ranks candidates by predicted LT:
    "LT+LIB"/"p95"/"throughput" reduce to their loop-time fallbacks, and a
    pure "LIB" reward sees zero spread everywhere — SimPolicy then takes its
    expert fallback on every wave.  Use reward="LT" with sim-assisted
    dispatch."""

    def __init__(self, sim: "DispatchSimulator"):
        self._sim = sim
        self._requests: Optional[List[Request]] = None

    def set_requests(self, requests: List[Request]) -> None:
        self._requests = requests

    def candidates(self) -> List[Candidate]:
        if self._requests is None:
            raise SimUnavailable("WaveWhatIf has no pending wave bound")
        out = [Candidate(a) for a in range(N_ALGORITHMS)]
        ec = exp_chunk(len(self._requests), self._sim.R)
        if ec != self._sim.chunk_param:
            out += [Candidate(a, ec) for a in range(N_ALGORITHMS)]
        return out

    def price(self, cands: Sequence[Candidate]) -> List[Observation]:
        if self._requests is None:
            raise SimUnavailable("WaveWhatIf has no pending wave bound")
        # one batched what_if per distinct chunk parameter
        groups: Dict[Optional[int], List[int]] = {}
        for i, c in enumerate(cands):
            groups.setdefault(c.chunk_param, []).append(i)
        out: List[Optional[Observation]] = [None] * len(cands)
        for cp, idxs in groups.items():
            mk = self._sim.what_if(self._requests,
                                   algs=[cands[i].alg for i in idxs],
                                   chunk_param=cp)
            for i, m in zip(idxs, mk):
                out[i] = Observation(loop_time=float(m))
        return out


class DispatchSimulator:
    """Chunk-self-scheduled request dispatch over R replica groups."""

    def __init__(self, n_replicas: int, selector: Optional[str] = None,
                 reward: str = "LT", chunk_param: int = 0, seed: int = 0,
                 cost_model: Optional[ReplicaCostModel] = None,
                 dispatch_overhead: float = 0.2e-3,
                 selector_kw: Optional[dict] = None,
                 backend: Optional[str] = None,
                 region: str = "dispatch"):
        self.R = n_replicas
        self.chunk_param = chunk_param
        #: SelectionService region id — the fleet layer names one region per
        #: replica group so warm-start snapshots (store_dir) never collide
        self.region = region
        self.h = dispatch_overhead
        self.cost = cost_model or ReplicaCostModel()
        #: simulation backend for ``what_if`` queries ("jax" evaluates the
        #: whole candidate set in one batched call)
        self.backend = backend
        # no explicit selector: REPRO_SIM_POLICY can flip the dispatcher to
        # simulation-assisted selection from the environment
        selector = selector or resolve_sim_policy("QLearn")
        kw = dict(selector_kw or {})
        kw.setdefault("seed", seed)
        # SimPolicy / SimHybrid consult this simulator's own what_if before
        # every wave (SimAS-style): zero exploration on live dispatches.
        # A caller-supplied wave pricer (anything with ``set_requests``) is
        # bound the same way, so it sees every pending queue too.
        self._whatif = None
        if is_sim_policy(selector):
            sim = kw.get("simulator")
            if sim is None:
                sim = kw["simulator"] = WaveWhatIf(self)
            if hasattr(sim, "set_requests"):
                self._whatif = sim
        # any make_policy name works here, incl. "Hybrid"; the reward may be
        # a serving-centric registry entry ("p95", "throughput", "LT+LIB")
        self.service = SelectionService(selector, reward=reward, **kw)
        self.stats: List[WaveStats] = []
        self._replica_free = np.zeros(n_replicas)
        #: (R,) availability mask while a masked wave is in flight, so the
        #: wave's what-if pricing routes around failed replicas too
        self._wave_active: Optional[np.ndarray] = None

    def _wave_prefix(self, requests: List[Request]) -> np.ndarray:
        """(N+1,) cumulative batch-cost model over the request sequence:
        cost of chunk [a, b) = prefix[b] - prefix[a] (+ the fixed term per
        dispatch, folded into the per-chunk overhead)."""
        tokens = np.array([r.prompt_len + r.gen_len for r in requests],
                          dtype=np.float64)
        return (self.cost.per_token * np.concatenate([[0.0],
                                                      np.cumsum(tokens)])
                + self.cost.per_request * np.arange(len(tokens) + 1))

    def what_if(self, requests: List[Request],
                algs: Optional[Sequence[int]] = None,
                chunk_param: Optional[int] = None) -> np.ndarray:
        """Batched what-if: predicted wave makespan for each candidate
        scheduling algorithm over the *current* replica busy-state, without
        dispatching anything (the SimAS-style consultation a policy can use
        to rank its candidate set before committing).  ``chunk_param``
        prices a chunk-parameter variant (default: the dispatcher's own)."""
        algs = list(algs) if algs is not None else list(range(N_ALGORITHMS))
        if chunk_param is None:
            chunk_param = self.chunk_param
        free = self._replica_free - self._replica_free.min()
        if self._wave_active is not None:
            # masked (failed) replicas cannot serve this wave: push their
            # availability past the whole wave's work so priced schedules
            # route around them, exactly like the dispatch loop will
            free = free.copy()
            free[~self._wave_active] += self._wave_prefix(requests)[-1] \
                + self.cost.fixed * len(requests)
        return get_backend(self.backend).what_if_wave(
            self._wave_prefix(requests), self.R, free, self.h,
            self.cost.fixed, algs, chunk_param=chunk_param)

    def run_wave(self, requests: List[Request], wave_id: int = 0,
                 active: Optional[np.ndarray] = None,
                 replica_scale: Optional[np.ndarray] = None) -> WaveStats:
        """One loop instance: dispatch all pending requests with the selected
        scheduling algorithm; replicas self-assign request-chunks.

        ``active`` — optional (R,) mask: failed replicas receive no chunks
        (their carried busy offsets pass through untouched); ``replica_scale``
        — optional (R,) per-replica service-time multipliers (stragglers).
        Both default to the exact historical homogeneous path.
        """
        if active is not None:
            active = np.asarray(active, dtype=bool)
            if active.shape != (self.R,):
                raise ValueError(f"active mask must have shape ({self.R},)")
            if not active.any():
                raise ValueError("run_wave needs at least one active replica")
            if active.all():
                active = None           # clean path, bit-identical
        if replica_scale is not None:
            replica_scale = np.asarray(replica_scale, dtype=np.float64)
            if replica_scale.shape != (self.R,):
                raise ValueError(f"replica_scale must have shape ({self.R},)")
            if np.all(replica_scale == 1.0):
                replica_scale = None    # clean path, bit-identical
        self._wave_active = active
        try:
            return self._run_wave(requests, wave_id, active, replica_scale)
        finally:
            self._wave_active = None

    def _run_wave(self, requests: List[Request], wave_id: int,
                  active: Optional[np.ndarray],
                  replica_scale: Optional[np.ndarray]) -> WaveStats:
        if self._whatif is not None:    # bind the wave the decision is about
            self._whatif.set_requests(requests)
        ranks = np.arange(self.R) if active is None else \
            np.flatnonzero(active)
        P = len(ranks)                  # replicas that can take work
        inst = self.service.instance(self.region)
        with inst:
            d = inst.decision.with_instance_defaults(self.chunk_param)
            alg_idx = d.action
            chunk_param = d.chunk_param
            tokens = np.array([r.prompt_len + r.gen_len for r in requests])
            N = len(tokens)
            alg = make_algorithm(alg_idx)
            alg.reset(N, P, chunk_param)

            free = self._replica_free - self._replica_free.min()
            cursor = 0
            chunks = 0
            if alg_idx == 0 and chunk_param <= 0:
                bounds = np.linspace(0, N, P + 1).round().astype(int)
                for k, r in enumerate(ranks):
                    if bounds[k + 1] > bounds[k]:
                        dt = self.cost.cost(tokens[bounds[k]:bounds[k + 1]])
                        if replica_scale is not None:
                            dt *= replica_scale[r]
                        free[r] += dt
                chunks = P
            else:
                # self-scheduling argmin restricted to active replicas;
                # algorithms see contiguous PE ranks 0..P-1
                while alg.remaining > 0:
                    k = int(np.argmin(free[ranks]))
                    r = int(ranks[k])
                    c = alg.next_chunk(k)
                    if c <= 0:
                        break
                    batch = tokens[cursor:cursor + c]
                    cursor += c
                    dt = self.cost.cost(batch)
                    if replica_scale is not None:
                        dt *= replica_scale[r]
                    alg.report(k, c, dt, dt + self.h)
                    free[r] += self.h + dt
                    chunks += 1

            makespan = float(free[ranks].max())
            lib = percent_load_imbalance(free[ranks])
            # full structured observation: the policy's reward function can
            # draw on tail latency / throughput, not just (LT, LIB)
            inst.report(loop_time=makespan, lib=lib,
                        throughput=N / max(makespan, 1e-12),
                        tail_latency=float(np.percentile(free[ranks], 95)),
                        pe_times=free[ranks].tolist())
        self._replica_free = free
        st = WaveStats(wave=wave_id, algorithm=alg_idx, n_requests=N,
                       makespan=makespan, lib=lib, chunks=chunks)
        self.stats.append(st)
        return st

    @property
    def busy(self) -> np.ndarray:
        """Per-replica busy offsets carried into the next wave (relative:
        ``run_wave`` re-bases them so the minimum is the dispatch origin).
        The fleet simulator reads/writes this around each routed shard to
        keep its absolute clock and the dispatcher's relative one in sync."""
        return self._replica_free.copy()

    @busy.setter
    def busy(self, offsets) -> None:
        offsets = np.asarray(offsets, dtype=np.float64)
        if offsets.shape != (self.R,):
            raise ValueError(f"busy offsets must have shape ({self.R},)")
        self._replica_free = offsets.copy()

    def run(self, requests: List[Request], wave_size: int = 256
            ) -> List[WaveStats]:
        out = []
        for w, i in enumerate(range(0, len(requests), wave_size)):
            out.append(self.run_wave(requests[i:i + wave_size], w))
        return out

    def summary(self) -> Dict[str, float]:
        mk = np.array([s.makespan for s in self.stats])
        lib = np.array([s.lib for s in self.stats])
        return {"total_makespan": float(mk.sum()),
                "mean_lib": float(lib.mean()),
                "waves": len(self.stats)}


class ContinuousBatcher:
    """Live continuous batching over a real jitted decode step (single
    replica group; the examples drive this with a reduced model)."""

    def __init__(self, serve_step, init_cache_fn, batch_slots: int,
                 eos_check: Optional[Callable] = None):
        self.serve_step = serve_step
        self.init_cache_fn = init_cache_fn
        self.slots = batch_slots
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.remaining = np.zeros(batch_slots, np.int64)
        # deque: _refill pops from the head every decode step — list.pop(0)
        # was O(queue) per refill
        self.queue: Deque[Request] = deque()
        self.completed: List[Tuple[int, float]] = []
        self.tokens_out = 0

    def submit(self, requests: List[Request]):
        self.queue.extend(requests)

    def _refill(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                r = self.queue.popleft()
                self.active[i] = r
                self.remaining[i] = r.gen_len

    def run(self, params, cache, tokens, max_steps: int = 1000):
        """Decode until queue + slots drain (or max_steps)."""
        import jax
        steps = 0
        t0 = time.perf_counter()
        self._refill()
        while steps < max_steps and any(a is not None for a in self.active):
            logits, cache = self.serve_step(params, cache, tokens)
            tokens = logits.argmax(-1).astype(tokens.dtype)
            steps += 1
            self.tokens_out += int(sum(a is not None for a in self.active))
            for i, a in enumerate(self.active):
                if a is None:
                    continue
                self.remaining[i] -= 1
                if self.remaining[i] <= 0:
                    self.completed.append((a.rid, time.perf_counter() - t0))
                    self.active[i] = None
            self._refill()
        jax.block_until_ready(cache)
        dt = time.perf_counter() - t0
        return {"steps": steps, "tokens": self.tokens_out,
                "tokens_per_s": self.tokens_out / max(dt, 1e-9),
                "completed": len(self.completed), "wall": dt}
