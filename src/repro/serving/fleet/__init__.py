"""Fleet-scale serving: trace-driven routing over continuous-batching
replica groups (see ``simulator``/``router``/``traces``)."""

from .router import (ROUTERS, LeastOutstandingRouter, RoundRobinRouter,
                     RouterPolicy, WhatIfRouter, make_router)
from .simulator import (AdmissionControl, FleetReport, FleetSimulator,
                        FleetView)
from .traces import (TRACE_KINDS, ArrivalTrace, bursty_trace, diurnal_trace,
                     make_trace, poisson_trace)

__all__ = [
    "ArrivalTrace", "TRACE_KINDS", "make_trace", "poisson_trace",
    "bursty_trace", "diurnal_trace",
    "RouterPolicy", "RoundRobinRouter", "LeastOutstandingRouter",
    "WhatIfRouter", "ROUTERS", "make_router",
    "FleetSimulator", "FleetView", "FleetReport", "AdmissionControl",
]
