"""Fleet-scale serving: trace-driven routing over continuous-batching
replica groups (see ``simulator``/``router``/``traces``), with fault
injection, recovery, and crash-safe journaled resume (``recovery``/
``journal``)."""

from .journal import RunJournal
from .recovery import (BASELINE_RECOVERY, RecoveryLedger, RecoveryPolicy,
                       RetryEntry)
from .router import (ROUTERS, LeastOutstandingRouter, RoundRobinRouter,
                     RouterPolicy, WhatIfRouter, make_router)
from .simulator import (AdmissionControl, FleetReport, FleetSimulator,
                        FleetView)
from .traces import (TRACE_KINDS, ArrivalTrace, bursty_trace, diurnal_trace,
                     make_trace, poisson_trace)

__all__ = [
    "ArrivalTrace", "TRACE_KINDS", "make_trace", "poisson_trace",
    "bursty_trace", "diurnal_trace",
    "RouterPolicy", "RoundRobinRouter", "LeastOutstandingRouter",
    "WhatIfRouter", "ROUTERS", "make_router",
    "FleetSimulator", "FleetView", "FleetReport", "AdmissionControl",
    "RecoveryPolicy", "RecoveryLedger", "RetryEntry", "BASELINE_RECOVERY",
    "RunJournal",
]
