"""Crash-safe wave-granularity journaling for ``FleetSimulator.run``.

A :class:`RunJournal` persists one atomic snapshot of the full fleet run
state per wave (or every ``every`` waves): fleet clock, trace cursor,
per-group replica finish times and busy accounting, the pending/retry
queues and attempt ledger, committed latency segments, per-group wave
stats, router state, and each region policy's ``state_dict``.  A
``bench_fleet``-scale (>=1M request) run killed at ANY point resumes from
its newest snapshot and finishes **bit-identically** to an uninterrupted
run — the run loop is deterministic given the snapshot, retry jitter is
stateless, so replaying the remaining waves reproduces every latency,
counter, and report field exactly (test-enforced).

Atomicity follows ``checkpoint.manager``: each snapshot is serialized to a
``.tmp`` sibling and ``os.replace``d into place — a crash mid-write can
truncate only the temp file, never a committed snapshot.  ``latest()``
additionally skips unreadable snapshots (defense against torn filesystems)
with a warning instead of refusing to resume.

Snapshots are ``.npz`` bundles: numpy arrays for the bulky state (latency
segments, queues, replica matrices) plus one JSON-encoded ``meta`` array
for scalars and nested records.  Retention keeps the newest ``keep``
snapshots (``keep=0`` keeps everything — tests resume from arbitrary
waves that way).
"""

from __future__ import annotations

import json
import os
import warnings
from typing import Dict, List, Optional

import numpy as np

__all__ = ["RunJournal"]

_PREFIX = "wave_"
_VERSION = 1


class RunJournal:
    """Atomic per-wave snapshots of one fleet run under ``directory``."""

    def __init__(self, directory: str, every: int = 1, keep: int = 2):
        if every < 1:
            raise ValueError("journal cadence `every` must be >= 1")
        self.dir = directory
        self.every = int(every)
        self.keep = int(keep)
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------
    def save(self, wave: int, meta: Dict, arrays: Dict[str, np.ndarray]
             ) -> str:
        """Atomically write snapshot ``wave``: ``meta`` is JSON-able scalar
        /nested state, ``arrays`` the numpy bulk."""
        meta = dict(meta)
        meta["version"] = _VERSION
        meta["wave"] = int(wave)
        final = os.path.join(self.dir, f"{_PREFIX}{wave:09d}.npz")
        tmp = final + ".tmp"
        payload = {k: np.asarray(v) for k, v in arrays.items()}
        payload["meta"] = np.frombuffer(
            json.dumps(meta).encode("utf-8"), dtype=np.uint8)
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)          # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        if self.keep <= 0:
            return
        for w in self.waves()[:-self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"{_PREFIX}{w:09d}.npz"))
            except OSError:
                pass

    # -- restore -------------------------------------------------------------
    def waves(self) -> List[int]:
        out = []
        try:
            names = os.listdir(self.dir)
        except OSError:
            return []
        for name in names:
            if name.startswith(_PREFIX) and name.endswith(".npz"):
                try:
                    out.append(int(name[len(_PREFIX):-4]))
                except ValueError:
                    continue
        return sorted(out)

    def load(self, wave: int) -> Dict:
        """Load snapshot ``wave`` into ``{"meta": dict, <array fields>}``."""
        path = os.path.join(self.dir, f"{_PREFIX}{wave:09d}.npz")
        with np.load(path) as z:
            out = {k: z[k] for k in z.files if k != "meta"}
            meta = json.loads(bytes(z["meta"].tobytes()).decode("utf-8"))
        if meta.get("version") != _VERSION:
            raise ValueError(f"journal snapshot {path} has version "
                             f"{meta.get('version')}, expected {_VERSION}")
        out["meta"] = meta
        return out

    def latest(self) -> Optional[Dict]:
        """Newest loadable snapshot (corrupt ones are skipped with a
        warning), or ``None`` when the journal is empty."""
        for w in reversed(self.waves()):
            try:
                return self.load(w)
            except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
                warnings.warn(f"skipping unreadable journal snapshot "
                              f"wave {w}: {e}", stacklevel=2)
        return None

    def clear(self) -> None:
        """Drop every snapshot (a completed run's journal is spent)."""
        for w in self.waves():
            try:
                os.remove(os.path.join(self.dir, f"{_PREFIX}{w:09d}.npz"))
            except OSError:
                pass
