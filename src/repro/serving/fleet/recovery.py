"""Fault recovery for fleet serving: retry, hedge, migrate, dead-letter.

The fleet's fault model (see :mod:`repro.sim.perturb`) injects wall-clock
:class:`~repro.sim.perturb.ReplicaFailure` / ``ReplicaStraggler`` events.
This module is the *policy* layer deciding what happens to the work those
events touch:

* **retry with capped exponential backoff** — a request whose shard was
  interrupted by a whole-group failure (or cancelled by its per-dispatch
  ``timeout`` deadline) re-enters the pending queue at
  ``t_fail + backoff(attempt)`` and is re-routed through the ordinary
  :class:`~repro.serving.fleet.router.RouterPolicy` pricing path — with a
  ``WhatIfRouter`` that means recovery decisions are what-if-priced too;
* **hedged duplicates** — a retried request can additionally be dispatched
  as a single-request mini-dispatch on the best *other* routable group;
  first finish wins and the loser's service time is refunded to its
  replica (``hedge=True``);
* **migration** — ``migrate=True`` (default) lets the router re-place
  retried work on any routable group; ``migrate=False`` pins each retry to
  the group that failed (work returns only when the group rejoins) — this
  is the recovery-*off* baseline the CI gate compares against;
* **load shedding** — with ``shed_wait`` set, requests that have already
  waited longer than the bound are dead-lettered deterministically at wave
  formation instead of being admitted into a fleet that cannot meet its
  SLO (graceful degradation, never a livelock);
* **dead-lettering** — a request that exhausts ``max_retries`` is recorded
  in the dead-letter ledger with its reason.  The fleet's accounting
  invariant is: every admitted request is completed exactly once OR
  dead-lettered, never lost and never double-counted —
  :meth:`RecoveryLedger.check` enforces it at the end of every run.

Backoff jitter is *stateless*: a CRC-32 fold of ``(seed, rid, attempt)``,
so resuming a journaled run replays identical retry times with no RNG
cursor to checkpoint.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["RecoveryPolicy", "RecoveryLedger", "RetryEntry",
           "BASELINE_RECOVERY"]


@dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for the fleet's fault-recovery behavior.

    ``timeout``
        Per-dispatch service deadline in seconds: a request whose shard is
        predicted to drain later than ``dispatch + timeout`` is cancelled
        at the deadline and retried (the group's chunk work is sunk — only
        the completion is voided).  ``None`` disables deadlines.
    ``max_retries``
        Retry budget per request; exceeding it dead-letters the request.
        A negative budget means unbounded (the recovery-off baseline, where
        interrupted work must eventually complete on its own group).
    ``backoff_base`` / ``backoff_factor`` / ``backoff_cap`` / ``jitter``
        Capped exponential backoff: attempt ``a`` waits
        ``min(cap, base * factor**a) * (1 + jitter * u(rid, a))`` with a
        stateless uniform ``u`` in [0, 1).
    ``hedge``
        Dispatch retried requests twice (primary shard + a single-request
        hedge on the best other routable group); first finish wins, the
        losing hedge's cost is refunded.
    ``migrate``
        Allow retried work to be re-routed to other groups.  ``False``
        pins retries to the failed group (rejoin-and-replay baseline).
    ``visible``
        Whether routers/admission see the failure state (routable mask and
        degraded per-group capacity).  The recovery-off baseline runs
        blind: it keeps routing into the failed group's void.
    ``shed_wait``
        Dead-letter pending requests that have waited longer than this
        (seconds) at wave formation.  ``None`` never sheds.
    """

    timeout: Optional[float] = None
    max_retries: int = 3
    backoff_base: float = 0.02
    backoff_factor: float = 2.0
    backoff_cap: float = 0.5
    jitter: float = 0.0
    hedge: bool = False
    migrate: bool = True
    visible: bool = True
    shed_wait: Optional[float] = None

    def __post_init__(self):
        if self.timeout is not None and self.timeout <= 0.0:
            raise ValueError("timeout must be positive (or None)")
        if self.backoff_base < 0.0 or self.backoff_cap < 0.0:
            raise ValueError("backoff terms must be non-negative")

    def backoff(self, rid: int, attempt: int, seed: int = 0) -> float:
        """Deterministic capped exponential backoff for retry ``attempt``
        (1-based) of request ``rid``."""
        base = min(self.backoff_cap,
                   self.backoff_base * self.backoff_factor ** (attempt - 1))
        if self.jitter <= 0.0 or base <= 0.0:
            return base
        digest = zlib.crc32(f"{seed}|{rid}|{attempt}".encode("utf-8"))
        u = digest / 2 ** 32
        return base * (1.0 + self.jitter * u)

    def exhausted(self, attempt: int) -> bool:
        """True once ``attempt`` retries have failed and the budget is
        spent (never, for the unbounded baseline)."""
        return self.max_retries >= 0 and attempt > self.max_retries


#: recovery-off physics: interrupted work is NOT abandoned (the accounting
#: invariant still holds) — it replays on its own group when the group
#: rejoins, with no deadline, no re-routing, no failure-aware view.  This
#: is what ``FleetSimulator(recovery=None)`` runs under fault injection,
#: and the baseline the bench_faults CI gate measures recovery against.
BASELINE_RECOVERY = RecoveryPolicy(timeout=None, max_retries=-1,
                                   backoff_base=0.0, backoff_cap=0.0,
                                   hedge=False, migrate=False, visible=False,
                                   shed_wait=None)


@dataclass(frozen=True)
class RetryEntry:
    """One queued retry: request ``rid`` becomes dispatchable at ``ready``;
    ``seq`` breaks ties deterministically (FIFO per ready instant);
    ``pin_group`` forces the retry back onto one group (``migrate=False``)."""

    ready: float
    seq: int
    rid: int
    attempt: int
    pin_group: Optional[int] = None

    def sort_key(self) -> Tuple[float, int]:
        return (self.ready, self.seq)


@dataclass
class RecoveryLedger:
    """Accounting of every recovery action in one fleet run."""

    attempts: Dict[int, int] = field(default_factory=dict)
    dead: Dict[int, str] = field(default_factory=dict)     # rid -> reason
    retries: int = 0
    interrupted: int = 0
    timeouts: int = 0
    migrated: int = 0
    hedges: int = 0
    hedge_wins: int = 0
    shed: int = 0

    def attempt_of(self, rid: int) -> int:
        return self.attempts.get(rid, 0)

    def record_retry(self, rid: int) -> int:
        """Bump and return the request's attempt counter."""
        a = self.attempts.get(rid, 0) + 1
        self.attempts[rid] = a
        self.retries += 1
        return a

    def dead_letter(self, rid: int, reason: str) -> None:
        self.dead[rid] = reason

    def summary(self) -> Dict:
        reasons: Dict[str, int] = {}
        for r in self.dead.values():
            reasons[r] = reasons.get(r, 0) + 1
        return {"retries": self.retries, "interrupted": self.interrupted,
                "timeouts": self.timeouts, "migrated": self.migrated,
                "hedges": self.hedges, "hedge_wins": self.hedge_wins,
                "shed": self.shed, "dead_lettered": len(self.dead),
                "dead_by_reason": reasons}

    def check(self, n: int, completed: int) -> None:
        """The trust anchor: completed + dead-lettered == admitted."""
        if completed + len(self.dead) != n:
            raise AssertionError(
                f"fleet accounting broken: {completed} completed + "
                f"{len(self.dead)} dead-lettered != {n} admitted")
