"""Routing policies over continuous-batching replica groups.

A router turns one fleet admission wave into per-group request shards:
``route(requests, view) -> List[List[Request]]`` (one, possibly empty, shard
per replica group).  ``view`` is the fleet's dispatch-time snapshot (a
:class:`~repro.serving.fleet.simulator.FleetView`): per-group busy offsets,
the shared replica cost model, and the batched what-if pricing hook.

``RoundRobinRouter`` and ``LeastOutstandingRouter`` are the classic
load-balancing baselines.  ``WhatIfRouter`` is the simulation-assisted one:
it builds a small set of candidate *partitions* of the wave, prices every
(replica-group, algorithm, chunk) assignment of every partition through ONE
batched ``what_if_routes`` call (SimAS-style consultation, on the JAX
backend a single jitted ``_route_eval``), and commits to the partition with
the lowest predicted fleet completion.  On a multi-device host that pricing
call shards its candidate axis over the backend's campaign mesh
(``REPRO_DATA_PARALLEL``) — candidates are padded to the mesh extent with
empty lanes, so the prices are bit-identical to single-device.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

from ...core import exp_chunk
from ...data.pipeline import Request


def request_cost(r: Request, cost) -> float:
    """Marginal predicted service seconds of one request under the replica
    cost model (the per-dispatch fixed term is amortized over a whole chunk
    and excluded here)."""
    return cost.per_token * (r.prompt_len + r.gen_len) + cost.per_request


def _routable_index(view) -> Optional[np.ndarray]:
    """Indices of the groups this wave may dispatch to, or ``None`` when
    every group is routable (the clean, bit-identical path).  A fleet view
    without failure awareness (``routable is None``) routes everywhere."""
    mask = getattr(view, "routable", None)
    if mask is None or bool(np.all(mask)):
        return None
    idx = np.flatnonzero(np.asarray(mask, dtype=bool))
    if idx.size == 0:
        raise ValueError("route() called with no routable group")
    return idx


def _subview(view, idx: np.ndarray):
    """The fleet view restricted to the routable groups ``idx``."""
    return dataclasses.replace(
        view, busy=[view.busy[int(g)] for g in idx],
        capacity=None if view.capacity is None else view.capacity[idx],
        routable=None)


def _scatter(shards: List[List[Request]], idx: np.ndarray, G: int
             ) -> List[List[Request]]:
    """Re-place sub-fleet shards onto the full group axis (dead groups get
    empty shards)."""
    out: List[List[Request]] = [[] for _ in range(G)]
    for k, g in enumerate(idx):
        out[int(g)] = shards[k]
    return out


class RouterPolicy:
    """Protocol: stateful per-fleet routing policy."""

    name = "router"

    def route(self, requests: List[Request], view) -> List[List[Request]]:
        raise NotImplementedError

    # journalable state (crash-safe resume): stateless routers return {}
    def state_dict(self) -> Dict:
        return {}

    def load_state_dict(self, state: Dict) -> None:
        pass


class RoundRobinRouter(RouterPolicy):
    """Stripe requests over the groups in arrival order, carrying the
    cursor across waves — size- and busy-state-blind.  With a failure-aware
    view, dead groups are simply skipped in the stripe."""

    name = "round_robin"

    def __init__(self):
        self._cursor = 0

    def route(self, requests: List[Request], view) -> List[List[Request]]:
        G = len(view.busy)
        idx = _routable_index(view)
        lanes = np.arange(G) if idx is None else idx
        L = len(lanes)
        shards: List[List[Request]] = [[] for _ in range(G)]
        for j, r in enumerate(requests):
            shards[int(lanes[(self._cursor + j) % L])].append(r)
        self._cursor = (self._cursor + len(requests)) % L
        return shards

    def state_dict(self) -> Dict:
        return {"cursor": int(self._cursor)}

    def load_state_dict(self, state: Dict) -> None:
        self._cursor = int(state.get("cursor", 0))


class LeastOutstandingRouter(RouterPolicy):
    """Join-shortest-queue on predicted outstanding work: each request (in
    arrival order) goes to the group with the least outstanding service
    seconds, counting both the busy-state and what this wave already
    assigned — size-aware, but blind to chunked-dispatch dynamics."""

    name = "least_outstanding"

    def route(self, requests: List[Request], view) -> List[List[Request]]:
        G = len(view.busy)
        load = np.array([b.sum() for b in view.busy])
        # on a skewed fleet the same request costs more service seconds on
        # a slowed group (capacity < 1); uniform fleets take the exact
        # historical path
        slow = (np.ones(G) if getattr(view, "capacity", None) is None
                else 1.0 / np.maximum(np.asarray(view.capacity), 1e-9))
        idx = _routable_index(view)
        if idx is not None:
            dead = np.ones(G, dtype=bool)
            dead[idx] = False
            load[dead] = np.inf         # JSQ never joins a dead group
        shards: List[List[Request]] = [[] for _ in range(G)]
        for r in requests:
            g = int(np.argmin(load))
            shards[g].append(r)
            load[g] += request_cost(r, view.cost) * slow[g]
        return shards


class WhatIfRouter(RouterPolicy):
    """What-if-priced routing: choose among candidate partitions of the
    admission wave by predicted fleet completion.

    Candidate partitions (the routing search space, all O(n) to build):

    - ``stripe``   — round-robin striping (the baseline itself);
    - ``lpt``      — longest-processing-time greedy onto the least-loaded
      group (size- and busy-aware);
    - ``waterfill``— contiguous shards sized to equalize predicted per-group
      work including the carried busy-state;
    - ``focus``    — the whole wave to the least-busy group (wins when the
      wave is small against the busy-state spread).

    Every (partition, group) shard is priced for every candidate
    ``(algorithm, chunk)`` in one batched ``what_if_routes`` call against
    the group's *current* busy offsets; a partition's predicted completion
    is the max over groups of the per-shard minimum (the group's own
    sim-assisted policy picks its algorithm, so the achievable makespan is
    the candidate-set argmin).  One consultation per admission wave.

    ``algs`` defaults to a pruned pricing portfolio spread across the
    static-to-dynamic axis — STATIC / GSS / TSS / mFAC2 — which ranks
    partitions as well as the full set at a quarter of the schedule-building
    cost; pass ``range(12)`` to price every portfolio algorithm.
    """

    name = "whatif"

    #: default pricing portfolio: a static/dynamic/adaptive spread with
    #: O(P log N) chunk counts (no SS chunk-of-1 rows, no steal replays)
    PRICING_ALGS = (0, 2, 4, 6)

    def __init__(self, algs: Optional[Sequence[int]] = None,
                 chunk_variants: bool = True):
        self.algs = list(algs) if algs is not None else list(self.PRICING_ALGS)
        self.chunk_variants = chunk_variants
        #: last wave's (partition -> predicted completion), for
        #: introspection and tests
        self.last_prices: Dict[str, float] = {}
        self.choices: List[str] = []

    # -- candidate partitions ------------------------------------------------
    def _partitions(self, requests: List[Request], view
                    ) -> Dict[str, List[List[Request]]]:
        G = len(view.busy)
        costs = np.array([request_cost(r, view.cost) for r in requests])
        base = np.array([b.sum() for b in view.busy])

        stripe: List[List[Request]] = [[] for _ in range(G)]
        for j, r in enumerate(requests):
            stripe[j % G].append(r)

        # LPT greedy: heaviest first onto the least-loaded group, shards
        # restored to arrival order
        lpt_idx: List[List[int]] = [[] for _ in range(G)]
        load = base.copy()
        for j in np.argsort(-costs, kind="stable"):
            g = int(np.argmin(load))
            lpt_idx[g].append(int(j))
            load[g] += costs[j]
        lpt = [[requests[j] for j in sorted(ix)] for ix in lpt_idx]

        # waterfill: contiguous arrival-order shards sized so that
        # busy + shard work equalizes across groups
        total = base.sum() + costs.sum()
        cap = np.maximum(total / G - base, 0.0)
        cap = cap / cap.sum() if cap.sum() > 0 else np.full(G, 1.0 / G)
        cuts = np.searchsorted(np.cumsum(costs),
                               np.cumsum(cap)[:-1] * costs.sum())
        water = [list(s) for s in np.split(np.asarray(requests, dtype=object),
                                           cuts)]

        focus: List[List[Request]] = [[] for _ in range(G)]
        focus[int(np.argmin([b.max() for b in view.busy]))] = list(requests)

        return {"stripe": stripe, "lpt": lpt, "waterfill": water,
                "focus": focus}

    # -- routing -------------------------------------------------------------
    def route(self, requests: List[Request], view) -> List[List[Request]]:
        G = len(view.busy)
        idx = _routable_index(view)
        if idx is not None:
            # price partitions over the live sub-fleet only; dead groups
            # receive empty shards (their queued work was already migrated)
            shards = self.route(requests, _subview(view, idx))
            return _scatter(shards, idx, G)
        if not requests or G == 1:
            return [list(requests)] + [[] for _ in range(G - 1)]
        parts = self._partitions(requests, view)

        slots: List[Tuple[str, int]] = []      # (partition, group) per slot
        prefixes: List[np.ndarray] = []
        avails: List[np.ndarray] = []
        cands: List[Tuple[int, int, int]] = []
        for pname, shards in parts.items():
            for g, shard in enumerate(shards):
                if not shard:
                    continue
                slot = len(slots)
                slots.append((pname, g))
                pref = view.cost_prefix(shard)
                if getattr(view, "capacity", None) is not None:
                    # a slowed group serves the same shard 1/capacity times
                    # slower — scale its what-if cost prefix so the pricing
                    # pass sees the perturbed fleet, not the nominal one
                    pref = pref * (1.0 / float(view.capacity[g]))
                prefixes.append(pref)
                avails.append(view.busy[g])
                chunks = [0]
                if self.chunk_variants:
                    ec = exp_chunk(len(shard), view.n_replicas)
                    if ec != 0:
                        chunks.append(ec)
                cands.extend((slot, a, cp) for a in self.algs
                             for cp in chunks)

        mks = view.price_routes(prefixes, avails, cands)
        best_slot = np.full(len(slots), np.inf)
        for (slot, _a, _cp), mk in zip(cands, mks):
            best_slot[slot] = min(best_slot[slot], mk)

        completion = {p: max(b.max(initial=0.0) for b in view.busy)
                      for p in parts}  # floor: groups left untouched drain
        for (pname, g), mk in zip(slots, best_slot):
            completion[pname] = max(completion[pname], float(mk))
        self.last_prices = dict(completion)
        best = min(completion, key=completion.get)
        self.choices.append(best)
        return parts[best]


#: router registry (aliases included); ``make_router`` resolves these
ROUTERS: Dict[str, Type[RouterPolicy]] = {
    "round_robin": RoundRobinRouter, "rr": RoundRobinRouter,
    "least_outstanding": LeastOutstandingRouter,
    "lor": LeastOutstandingRouter,
    "whatif": WhatIfRouter, "what_if": WhatIfRouter,
}


def make_router(router: Union[str, RouterPolicy, None], **kw) -> RouterPolicy:
    """Resolve a router: an instance passes through, a name builds one."""
    if router is None:
        router = "whatif"
    if isinstance(router, RouterPolicy):
        return router
    try:
        cls = ROUTERS[str(router).lower()]
    except KeyError:
        raise ValueError(f"unknown router {router!r}; "
                         f"available: {sorted(ROUTERS)}") from None
    return cls(**kw)
