"""Fleet-scale serving simulator: replica groups behind a router.

``FleetSimulator`` composes the repo's four existing layers into one region:

- **data** — an :class:`~repro.serving.fleet.traces.ArrivalTrace` drives
  asynchronous admissions (Poisson / bursty / diurnal);
- **serving** — each replica group is a
  :class:`~repro.serving.engine.DispatchSimulator` (chunk-self-scheduled
  continuous-batching waves over R replicas);
- **sim backends** — routing decisions are priced through the backends'
  batched ``what_if_routes`` (one call per admission wave);
- **core policies** — every group owns a per-region
  :class:`~repro.core.service.SelectionService` region (``region{g}``), so
  SimPolicy/SimHybrid/QLearn state is group-local and warm-start snapshots
  (``store_dir``) round-trip per group.

Time model: the fleet clock advances wave-by-wave.  Each iteration admits
up to the controller's budget from the pending queue, routes the admitted
batch, and dispatches every shard on its group with the group's *absolute*
per-replica finish times converted to the dispatcher's relative busy
offsets (idle time between waves really elapses).  While a backlog remains
the next wave opens when the earliest replica anywhere frees — the
continuous-batching refill trigger.  A request's latency is its group's
wave-drain time minus its arrival (wave granularity, matching the per-wave
LIB/makespan the selection layer observes).
"""

from __future__ import annotations

import os
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ...core import percent_load_imbalance
from ...data.pipeline import Request
from ...sim.backends import get_backend
from ...sim.perturb import FleetPerturb
from ..engine import DispatchSimulator, ReplicaCostModel
from .router import RouterPolicy, make_router, request_cost
from .traces import ArrivalTrace


@dataclass
class FleetView:
    """Dispatch-time snapshot handed to routers and admission control."""

    now: float
    busy: List[np.ndarray]          # per-group (R,) offsets relative to now
    n_replicas: int
    cost: ReplicaCostModel
    h: float                        # per-chunk dispatch overhead
    backend: object = None          # SimBackend for what-if pricing
    #: (G,) relative service-rate capacity per group (1.0 nominal, < 1 for
    #: a slowed group); None = homogeneous — routers and admission control
    #: then take their exact historical paths
    capacity: Optional[np.ndarray] = None

    def cost_prefix(self, requests: Sequence[Request]) -> np.ndarray:
        """(N+1,) cumulative service-cost prefix of a request shard (the
        same token cost model ``DispatchSimulator`` dispatches under)."""
        tokens = np.array([r.prompt_len + r.gen_len for r in requests],
                          dtype=np.float64)
        return (self.cost.per_token
                * np.concatenate([[0.0], np.cumsum(tokens)])
                + self.cost.per_request * np.arange(len(tokens) + 1))

    def price_routes(self, prefixes, avails, cands) -> np.ndarray:
        """One batched (slot, algorithm, chunk) pricing call — the fleet's
        SimAS-style consultation."""
        return self.backend.what_if_routes(prefixes, self.n_replicas,
                                           avails, self.h, self.cost.fixed,
                                           cands)


@dataclass
class AdmissionControl:
    """Deadlock-free backpressure: shapes (never fully stalls) each wave.

    - ``wave_quota`` — per-group admission cap per wave (decision
      granularity);
    - ``batch_window`` — wave-formation window in seconds: an underloaded
      fleet waits up to this long past the oldest pending arrival for the
      wave to fill before dispatching (in the saturated regime the window
      has already elapsed, so waves go out full and immediately);
    - ``queue_depth`` — per-replica outstanding-work bound in seconds: a
      wave may not push any further work once the fleet-wide outstanding
      budget ``queue_depth * replicas`` is full (queue-depth backpressure);
    - ``p95_slo`` — predicted-p95 backpressure: while the oldest pending
      wait plus the predicted service horizon of the admitted batch exceeds
      the SLO, the wave is halved (down to ``min_admit``, so the queue
      always drains).
    """

    wave_quota: int = 256
    batch_window: float = 0.05
    queue_depth: float = float("inf")
    p95_slo: Optional[float] = None
    min_admit: int = 8

    def admit(self, pending: Sequence[Request], now: float,
              view: FleetView) -> int:
        if not pending:
            return 0
        G = len(view.busy)
        R = view.n_replicas
        k = min(len(pending), self.wave_quota * G)
        head_costs = np.array([request_cost(r, view.cost)
                               for r in list(pending)[:k]])
        mean_cost = float(head_costs.mean()) if len(head_costs) else 0.0
        outstanding = float(sum(b.sum() for b in view.busy))
        if np.isfinite(self.queue_depth):
            budget = max(0.0, self.queue_depth * G * R - outstanding)
            k = min(k, int(budget / max(mean_cost, 1e-12)))
        if self.p95_slo is not None and k > self.min_admit:
            oldest = now - pending[0].arrival
            busy_p95 = float(np.percentile(np.concatenate(view.busy), 95))
            # aggregate service rate in replica-equivalents: on a skewed
            # fleet a slowed group drains fewer requests per second, so the
            # horizon must weight by per-group capacity (uniform capacity
            # reduces to the historical G * R exactly)
            cap = view.capacity if view.capacity is not None else np.ones(G)
            rate = float(cap.sum()) * R
            while k > self.min_admit:
                pred = oldest + busy_p95 \
                    + float(head_costs[:k].sum()) / rate
                if pred <= self.p95_slo:
                    break
                k //= 2
        if outstanding <= 0.0:
            # idle-fleet floor only: with work still outstanding, a k the
            # backpressure terms drove to 0 must STAY 0 — re-admitting
            # min_admit here defeated queue-depth backpressure entirely
            k = max(k, min(self.min_admit, len(pending)))
        return max(k, 0)


@dataclass
class FleetReport:
    """Fleet-level outcome of one trace run."""

    n_requests: int
    makespan: float                 # last drain time minus first arrival
    throughput: float               # requests / makespan
    p50: float
    p95: float
    p99: float
    mean_latency: float
    fleet_lib: float                # Eq. 8 LIB over all fleet replicas
    mean_wave_lib: float            # mean per-wave LIB across group waves
    waves: int
    mean_wave_size: float
    deferred: int                   # pending-request-waves held back
    per_group: List[Dict] = field(default_factory=list)
    latencies: Optional[np.ndarray] = None

    def summary(self) -> Dict:
        return {k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in self.__dict__.items()
                if k not in ("per_group", "latencies")}


class FleetSimulator:
    """N ``DispatchSimulator`` replica groups behind a pluggable router."""

    def __init__(self, n_groups: int = 4, replicas_per_group: int = 8,
                 router: Union[str, RouterPolicy, None] = "whatif",
                 selector: Optional[str] = None, reward: str = "LT",
                 chunk_param: int = 0, seed: int = 0,
                 cost_model: Optional[ReplicaCostModel] = None,
                 dispatch_overhead: float = 0.2e-3,
                 backend: Optional[str] = None,
                 admission: Optional[AdmissionControl] = None,
                 store_dir: Optional[str] = None,
                 selector_kw: Optional[dict] = None,
                 group_slowdown: Optional[Sequence[float]] = None,
                 perturb: Optional[FleetPerturb] = None):
        self.G = n_groups
        self.R = replicas_per_group
        self.cost = cost_model or ReplicaCostModel()
        self.h = dispatch_overhead
        self.router = make_router(router)
        self.admission = admission or AdmissionControl()
        self.backend = get_backend(backend)
        self.store_dir = store_dir
        # persistent per-group service-time slowdowns (heterogeneous fleet)
        # composed with time-windowed FleetPerturb events per wave
        self.group_slowdown = None if group_slowdown is None else \
            np.asarray(group_slowdown, np.float64)
        if self.group_slowdown is not None and \
                len(self.group_slowdown) != self.G:
            raise ValueError(
                f"group_slowdown has {len(self.group_slowdown)} entries "
                f"for {self.G} groups")
        self.perturb = perturb
        self._cost_scale = np.ones(self.G)
        kw = dict(selector_kw or {})
        if store_dir is not None:
            os.makedirs(store_dir, exist_ok=True)
            kw.setdefault("store_dir", store_dir)
        # one region per group: distinct warm-start keys AND decorrelated
        # policy rng streams from the same base seed
        self.groups = [
            DispatchSimulator(replicas_per_group, selector=selector,
                              reward=reward, chunk_param=chunk_param,
                              seed=seed, cost_model=self.cost,
                              dispatch_overhead=dispatch_overhead,
                              selector_kw=dict(kw), backend=backend,
                              region=f"region{g}")
            for g in range(n_groups)]

    # -- warm-start round-trip ----------------------------------------------
    def save_state(self) -> List[str]:
        """Persist every group's region policy (requires ``store_dir``);
        a fresh fleet on the same store_dir warm-starts each region."""
        paths: List[str] = []
        for sim in self.groups:
            if sim.service.store_dir is not None and sim.service.regions:
                paths.extend(sim.service.save())
        return paths

    def warm_started(self) -> List[bool]:
        return [sim.service.warm_started(sim.region) for sim in self.groups]

    # -- simulation ----------------------------------------------------------
    def _slowdowns(self, now: float) -> Optional[np.ndarray]:
        """(G,) service-time slowdowns active at ``now``; None when the
        fleet is exactly homogeneous (the bit-identical clean path)."""
        f = self.group_slowdown
        if self.perturb is not None:
            p = self.perturb.slowdowns(now, self.G)
            f = p if f is None else f * p
        if f is None or bool(np.all(f == 1.0)):
            return None
        return f

    def _apply_slowdowns(self, f: Optional[np.ndarray]) -> None:
        """Rescale each group's dispatch cost model to the slowdowns active
        this wave (no-op — object-identical cost models — while uniform)."""
        want = np.ones(self.G) if f is None else f
        for g, sim in enumerate(self.groups):
            if want[g] == self._cost_scale[g]:
                continue
            s = float(want[g])
            sim.cost = self.cost if s == 1.0 else ReplicaCostModel(
                fixed=self.cost.fixed * s,
                per_token=self.cost.per_token * s,
                per_request=self.cost.per_request * s)
            self._cost_scale[g] = s

    def _view(self, now: float, finish: np.ndarray,
              f: Optional[np.ndarray] = None) -> FleetView:
        busy = [np.maximum(finish[g] - now, 0.0) for g in range(self.G)]
        return FleetView(now=now, busy=busy, n_replicas=self.R,
                         cost=self.cost, h=self.h, backend=self.backend,
                         capacity=None if f is None else 1.0 / f)

    def run(self, trace: Union[ArrivalTrace, Sequence[Request]],
            keep_latencies: bool = False) -> FleetReport:
        reqs = trace.requests if isinstance(trace, ArrivalTrace) \
            else list(trace)
        n = len(reqs)
        finish = np.zeros((self.G, self.R))     # absolute replica finishes
        busy_tot = np.zeros((self.G, self.R))   # accumulated work seconds
        lats: List[np.ndarray] = []
        pending: deque = deque()
        i = 0
        now = 0.0
        waves = 0
        admitted = 0
        deferred = 0
        t0 = reqs[0].arrival if reqs else 0.0
        quota = self.admission.wave_quota * self.G
        window = self.admission.batch_window
        while i < n or pending:
            if not pending and reqs[i].arrival > now:
                now = reqs[i].arrival
            while i < n and reqs[i].arrival <= now:
                pending.append(reqs[i])
                i += 1
            if i < n and len(pending) < quota and window > 0.0:
                # wave formation: wait for the quota to fill or the batch
                # window (measured from the oldest pending arrival) to
                # close, whichever is first — a no-op once saturated
                t_close = pending[0].arrival + window
                t_full = reqs[min(i + quota - len(pending), n) - 1].arrival
                t_open = min(t_close, t_full)
                if t_open > now:
                    now = t_open
                    while i < n and reqs[i].arrival <= now:
                        pending.append(reqs[i])
                        i += 1
            f = self._slowdowns(now)
            self._apply_slowdowns(f)
            view = self._view(now, finish, f)
            k = self.admission.admit(pending, now, view)
            if k <= 0 and pending:
                # backpressure holds the whole wave: let the fleet drain to
                # the next replica-free instant and re-evaluate (never
                # busy-spin — admit() floors to min_admit once idle)
                deferred += len(pending)
                future = finish[finish > now]
                if future.size:
                    now = float(future.min())
                    continue
                k = min(len(pending), max(1, self.admission.min_admit))
            batch = [pending.popleft() for _ in range(k)]
            deferred += len(pending)
            shards = self.router.route(batch, view)
            wave_lat = np.empty(len(batch))
            w = 0
            for g, shard in enumerate(shards):
                if not shard:
                    continue
                busy = view.busy[g]
                base = float(busy.min())
                sim = self.groups[g]
                # re-base to the dispatcher's relative origin (= the time
                # its earliest replica frees)
                sim.busy = busy - base
                st = sim.run_wave(shard, waves)
                new_busy = sim.busy
                busy_tot[g] += new_busy - (busy - base)
                finish[g] = (now + base) + new_busy
                done = now + base + st.makespan
                for r in shard:
                    wave_lat[w] = done - r.arrival
                    w += 1
            lats.append(wave_lat)
            admitted += len(batch)
            waves += 1
            if pending:
                # saturated: reopen when the earliest replica frees
                now = max(now, float(finish.min(axis=1).min()))
        lat = np.concatenate(lats) if lats else np.empty(0)
        makespan = float(finish.max() - t0) if n else 0.0
        wave_libs = np.array([s.lib for sim in self.groups
                              for s in sim.stats])
        report = FleetReport(
            n_requests=n,
            makespan=makespan,
            throughput=n / max(makespan, 1e-12),
            p50=float(np.percentile(lat, 50)) if n else 0.0,
            p95=float(np.percentile(lat, 95)) if n else 0.0,
            p99=float(np.percentile(lat, 99)) if n else 0.0,
            mean_latency=float(lat.mean()) if n else 0.0,
            fleet_lib=percent_load_imbalance(busy_tot.ravel()),
            mean_wave_lib=float(wave_libs.mean()) if len(wave_libs) else 0.0,
            waves=waves,
            mean_wave_size=admitted / max(waves, 1),
            deferred=deferred,
            per_group=[{"region": sim.region,
                        "waves": len(sim.stats),
                        "requests": int(sum(s.n_requests
                                            for s in sim.stats)),
                        "busy_s": float(busy_tot[g].sum()),
                        "lib": percent_load_imbalance(busy_tot[g])}
                       for g, sim in enumerate(self.groups)],
            latencies=lat if keep_latencies else None)
        return report
