"""Fleet-scale serving simulator: replica groups behind a router.

``FleetSimulator`` composes the repo's four existing layers into one region:

- **data** — an :class:`~repro.serving.fleet.traces.ArrivalTrace` drives
  asynchronous admissions (Poisson / bursty / diurnal);
- **serving** — each replica group is a
  :class:`~repro.serving.engine.DispatchSimulator` (chunk-self-scheduled
  continuous-batching waves over R replicas);
- **sim backends** — routing decisions are priced through the backends'
  batched ``what_if_routes`` (one call per admission wave);
- **core policies** — every group owns a per-region
  :class:`~repro.core.service.SelectionService` region (``region{g}``), so
  SimPolicy/SimHybrid/QLearn state is group-local and warm-start snapshots
  (``store_dir``) round-trip per group.

Time model: the fleet clock advances wave-by-wave.  Each iteration admits
up to the controller's budget from the pending queue, routes the admitted
batch, and dispatches every shard on its group with the group's *absolute*
per-replica finish times converted to the dispatcher's relative busy
offsets (idle time between waves really elapses).  While a backlog remains
the next wave opens when the earliest replica anywhere frees — the
continuous-batching refill trigger.  A request's latency is its group's
wave-drain time minus its arrival (wave granularity, matching the per-wave
LIB/makespan the selection layer observes).

Fault tolerance (see :mod:`repro.serving.fleet.recovery`): wall-clock
:class:`~repro.sim.perturb.ReplicaFailure` / ``ReplicaStraggler`` events in
``perturb`` mask replicas out of dispatch and degrade per-group capacity; a
whole-group failure interrupts in-flight shards, whose requests the
:class:`~repro.serving.fleet.recovery.RecoveryPolicy` retries with capped
backoff, optionally hedges, and re-routes (migrates) through the ordinary
router pricing path.  With ``recovery=None`` the baseline physics still
hold — interrupted work replays on its own group when it rejoins — but
routing stays blind to failures.  Every admitted request is completed
exactly once or explicitly dead-lettered (ledger-checked).

Crash safety: pass ``journal=RunJournal(dir)`` and ``run`` snapshots its
full state atomically at wave granularity; ``run(..., resume=True)`` on a
fresh simulator restores the newest snapshot and finishes bit-identically
to an uninterrupted run (see :mod:`repro.serving.fleet.journal`).

``run`` is single-shot: it mutates group busy-state and region policies,
so a second call on the same simulator raises — build a fresh one (resume
does exactly that around a journal).
"""

from __future__ import annotations

import heapq
import json
import os
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ...core import percent_load_imbalance
from ...data.pipeline import Request
from ...sim.backends import get_backend
from ...sim.perturb import FleetPerturb
from ..engine import DispatchSimulator, ReplicaCostModel, WaveStats
from .journal import RunJournal
from .recovery import BASELINE_RECOVERY, RecoveryLedger, RecoveryPolicy
from .router import RouterPolicy, make_router, request_cost
from .traces import ArrivalTrace


@dataclass
class FleetView:
    """Dispatch-time snapshot handed to routers and admission control."""

    now: float
    busy: List[np.ndarray]          # per-group (R,) offsets relative to now
    n_replicas: int
    cost: ReplicaCostModel
    h: float                        # per-chunk dispatch overhead
    backend: object = None          # SimBackend for what-if pricing
    #: (G,) relative service-rate capacity per group (1.0 nominal, < 1 for
    #: a slowed group); None = homogeneous — routers and admission control
    #: then take their exact historical paths
    capacity: Optional[np.ndarray] = None
    #: (G,) routability mask under a failure-aware view (None = every
    #: group accepts work — the exact historical path)
    routable: Optional[np.ndarray] = None

    def cost_prefix(self, requests: Sequence[Request]) -> np.ndarray:
        """(N+1,) cumulative service-cost prefix of a request shard (the
        same token cost model ``DispatchSimulator`` dispatches under)."""
        tokens = np.array([r.prompt_len + r.gen_len for r in requests],
                          dtype=np.float64)
        return (self.cost.per_token
                * np.concatenate([[0.0], np.cumsum(tokens)])
                + self.cost.per_request * np.arange(len(tokens) + 1))

    def price_routes(self, prefixes, avails, cands) -> np.ndarray:
        """One batched (slot, algorithm, chunk) pricing call — the fleet's
        SimAS-style consultation."""
        return self.backend.what_if_routes(prefixes, self.n_replicas,
                                           avails, self.h, self.cost.fixed,
                                           cands)


@dataclass
class AdmissionControl:
    """Deadlock-free backpressure: shapes (never fully stalls) each wave.

    - ``wave_quota`` — per-group admission cap per wave (decision
      granularity);
    - ``batch_window`` — wave-formation window in seconds: an underloaded
      fleet waits up to this long past the oldest pending arrival for the
      wave to fill before dispatching (in the saturated regime the window
      has already elapsed, so waves go out full and immediately);
    - ``queue_depth`` — per-replica outstanding-work bound in seconds: a
      wave may not push any further work once the fleet-wide outstanding
      budget ``queue_depth * replicas`` is full (queue-depth backpressure);
    - ``p95_slo`` — predicted-p95 backpressure: while the oldest pending
      wait plus the predicted service horizon of the admitted batch exceeds
      the SLO, the wave is halved (down to ``min_admit``, so the queue
      always drains).
    """

    wave_quota: int = 256
    batch_window: float = 0.05
    queue_depth: float = float("inf")
    p95_slo: Optional[float] = None
    min_admit: int = 8

    def admit(self, pending: Sequence[Request], now: float,
              view: FleetView) -> int:
        if not pending:
            return 0
        G = len(view.busy)
        R = view.n_replicas
        k = min(len(pending), self.wave_quota * G)
        head_costs = np.array([request_cost(r, view.cost)
                               for r in list(pending)[:k]])
        mean_cost = float(head_costs.mean()) if len(head_costs) else 0.0
        outstanding = float(sum(b.sum() for b in view.busy))
        if np.isfinite(self.queue_depth):
            budget = max(0.0, self.queue_depth * G * R - outstanding)
            k = min(k, int(budget / max(mean_cost, 1e-12)))
        if self.p95_slo is not None and k > self.min_admit:
            oldest = now - pending[0].arrival
            busy_p95 = float(np.percentile(np.concatenate(view.busy), 95))
            # aggregate service rate in replica-equivalents: on a skewed
            # fleet a slowed group drains fewer requests per second, so the
            # horizon must weight by per-group capacity (uniform capacity
            # reduces to the historical G * R exactly)
            cap = view.capacity if view.capacity is not None else np.ones(G)
            rate = max(float(cap.sum()) * R, 1e-9)
            while k > self.min_admit:
                pred = oldest + busy_p95 \
                    + float(head_costs[:k].sum()) / rate
                if pred <= self.p95_slo:
                    break
                k //= 2
        if outstanding <= 0.0:
            # idle-fleet floor only: with work still outstanding, a k the
            # backpressure terms drove to 0 must STAY 0 — re-admitting
            # min_admit here defeated queue-depth backpressure entirely
            k = max(k, min(self.min_admit, len(pending)))
        return max(k, 0)


@dataclass
class FleetReport:
    """Fleet-level outcome of one trace run."""

    n_requests: int
    makespan: float                 # last drain time minus first arrival
    throughput: float               # completed requests / makespan
    p50: float
    p95: float
    p99: float
    mean_latency: float
    fleet_lib: float                # Eq. 8 LIB over all fleet replicas
    mean_wave_lib: float            # mean per-wave LIB across group waves
    waves: int
    mean_wave_size: float
    deferred: int                   # pending-request-waves held back
    per_group: List[Dict] = field(default_factory=list)
    latencies: Optional[np.ndarray] = None
    #: fault-recovery accounting (completed / dead-lettered / retries /
    #: hedges / migrations); None on a fault-free run
    recovery: Optional[Dict] = None

    def summary(self) -> Dict:
        out = {k: (round(v, 6) if isinstance(v, float) else v)
               for k, v in self.__dict__.items()
               if k not in ("per_group", "latencies")}
        if out.get("recovery") is None:
            out.pop("recovery", None)   # fault-free summaries stay as-is
        return out


def _trace_signature(trace, reqs: List[Request]) -> str:
    """Stable digest of the request stream — the journal's guard against
    resuming one trace's snapshot under a different trace."""
    if isinstance(trace, ArrivalTrace):
        return trace.signature
    head = reqs[:64]
    ident = json.dumps([len(reqs),
                        [(r.rid, r.prompt_len, r.gen_len, round(r.arrival, 9))
                         for r in head]])
    return f"list-{zlib.crc32(ident.encode('utf-8')):08x}"


class _RunState:
    """Every mutable datum of one fleet run — exactly what the journal
    snapshots and what resume restores."""

    def __init__(self, G: int, R: int, n: int, reqs: List[Request],
                 fault_mode: bool):
        self.now = 0.0
        self.i = 0                      # trace cursor (admitted watermark)
        self.waves = 0
        self.admitted = 0
        self.deferred = 0
        self.t0 = reqs[0].arrival if reqs else 0.0
        self.finish = np.zeros((G, R))  # absolute replica finishes
        self.busy_tot = np.zeros((G, R))  # accumulated work seconds
        self.lats: List[np.ndarray] = []
        self.pending: deque = deque()
        # fault state (inert on the clean path)
        self.retryq: List[Tuple[float, int, int, int, int]] = []  # heap
        self.seq = 0                    # retry FIFO tiebreaker
        self.completed = np.zeros(n, dtype=bool) if fault_mode else None
        self.ledger = RecoveryLedger()
        self.retry_from: Dict[int, int] = {}   # rid -> group it failed on
        self.retry_pin: Dict[int, int] = {}    # rid -> pinned group
        self.resets: List[Tuple[float, int]] = []  # (t_star, group) pending

    def push_retry(self, ready: float, rid: int, attempt: int,
                   pin: Optional[int]) -> None:
        heapq.heappush(self.retryq,
                       (float(ready), self.seq, int(rid), int(attempt),
                        -1 if pin is None else int(pin)))
        self.seq += 1


class FleetSimulator:
    """N ``DispatchSimulator`` replica groups behind a pluggable router."""

    def __init__(self, n_groups: int = 4, replicas_per_group: int = 8,
                 router: Union[str, RouterPolicy, None] = "whatif",
                 selector: Optional[str] = None, reward: str = "LT",
                 chunk_param: int = 0, seed: int = 0,
                 cost_model: Optional[ReplicaCostModel] = None,
                 dispatch_overhead: float = 0.2e-3,
                 backend: Optional[str] = None,
                 admission: Optional[AdmissionControl] = None,
                 store_dir: Optional[str] = None,
                 selector_kw: Optional[dict] = None,
                 group_slowdown: Optional[Sequence[float]] = None,
                 perturb: Optional[FleetPerturb] = None,
                 recovery: Optional[RecoveryPolicy] = None):
        self.G = n_groups
        self.R = replicas_per_group
        self.cost = cost_model or ReplicaCostModel()
        self.h = dispatch_overhead
        self.router = make_router(router)
        self.admission = admission or AdmissionControl()
        self.backend = get_backend(backend)
        self.store_dir = store_dir
        self.seed = seed
        # persistent per-group service-time slowdowns (heterogeneous fleet)
        # composed with time-windowed FleetPerturb events per wave
        self.group_slowdown = None if group_slowdown is None else \
            np.asarray(group_slowdown, np.float64)
        if self.group_slowdown is not None and \
                len(self.group_slowdown) != self.G:
            raise ValueError(
                f"group_slowdown has {len(self.group_slowdown)} entries "
                f"for {self.G} groups")
        self.perturb = perturb
        self.recovery = recovery
        self._cost_scale = np.ones(self.G)
        self._ran = False
        kw = dict(selector_kw or {})
        if store_dir is not None:
            os.makedirs(store_dir, exist_ok=True)
            kw.setdefault("store_dir", store_dir)
        # one region per group: distinct warm-start keys AND decorrelated
        # policy rng streams from the same base seed
        self.groups = [
            DispatchSimulator(replicas_per_group, selector=selector,
                              reward=reward, chunk_param=chunk_param,
                              seed=seed, cost_model=self.cost,
                              dispatch_overhead=dispatch_overhead,
                              selector_kw=dict(kw), backend=backend,
                              region=f"region{g}")
            for g in range(n_groups)]

    # -- warm-start round-trip ----------------------------------------------
    def save_state(self) -> List[str]:
        """Persist every group's region policy (requires ``store_dir``);
        a fresh fleet on the same store_dir warm-starts each region."""
        paths: List[str] = []
        for sim in self.groups:
            if sim.service.store_dir is not None and sim.service.regions:
                paths.extend(sim.service.save())
        return paths

    def warm_started(self) -> List[bool]:
        return [sim.service.warm_started(sim.region) for sim in self.groups]

    # -- simulation ----------------------------------------------------------
    def _slowdowns(self, now: float) -> Optional[np.ndarray]:
        """(G,) service-time slowdowns active at ``now``; None when the
        fleet is exactly homogeneous (the bit-identical clean path)."""
        f = self.group_slowdown
        if self.perturb is not None:
            p = self.perturb.slowdowns(now, self.G)
            f = p if f is None else f * p
        if f is None or bool(np.all(f == 1.0)):
            return None
        return f

    def _apply_slowdowns(self, f: Optional[np.ndarray]) -> None:
        """Rescale each group's dispatch cost model to the slowdowns active
        this wave (no-op — object-identical cost models — while uniform)."""
        want = np.ones(self.G) if f is None else f
        for g, sim in enumerate(self.groups):
            if want[g] == self._cost_scale[g]:
                continue
            s = float(want[g])
            sim.cost = self.cost if s == 1.0 else ReplicaCostModel(
                fixed=self.cost.fixed * s,
                per_token=self.cost.per_token * s,
                per_request=self.cost.per_request * s)
            self._cost_scale[g] = s

    def _view(self, now: float, finish: np.ndarray,
              f: Optional[np.ndarray] = None) -> FleetView:
        busy = [np.maximum(finish[g] - now, 0.0) for g in range(self.G)]
        return FleetView(now=now, busy=busy, n_replicas=self.R,
                         cost=self.cost, h=self.h, backend=self.backend,
                         capacity=None if f is None else 1.0 / f)

    def _fault_view(self, now: float, finish: np.ndarray,
                    f: Optional[np.ndarray],
                    rep: Optional[Tuple[np.ndarray, np.ndarray]],
                    visible: bool) -> FleetView:
        """The wave's view under the fault model: a failure-aware (visible)
        view folds dead/straggling replicas into per-group capacity and a
        routable mask; a blind view is exactly the historical one."""
        if rep is None or not visible:
            return self._view(now, finish, f)
        alive, scale = rep
        eff = (alive / scale).mean(axis=1)      # (G,) service-rate fraction
        base = np.ones(self.G) if f is None else 1.0 / f
        view = self._view(now, finish, f)
        view.capacity = base * eff
        view.routable = alive.any(axis=1)
        return view

    # -- fault helpers -------------------------------------------------------
    def _interrupt_group(self, st: _RunState, g: int, t_star: float) -> None:
        """Register the lazy whole-group reset at ``t_star`` (replicas stop
        there; in-flight beyond it is void)."""
        if (t_star, g) not in st.resets:
            st.resets.append((t_star, g))

    def _apply_resets(self, st: _RunState) -> None:
        """Apply pending group resets the clock has reached: replicas of an
        interrupted group stop at the failure instant — the voided tail of
        their schedule is refunded from finish and busy accounting."""
        due = [(t, g) for (t, g) in st.resets if t <= st.now]
        for (t, g) in sorted(due):
            over = np.maximum(st.finish[g] - t, 0.0)
            st.finish[g] -= over
            st.busy_tot[g] = np.maximum(st.busy_tot[g] - over, 0.0)
        st.resets = [x for x in st.resets if x not in due]

    def _schedule_retry(self, st: _RunState, rec: RecoveryPolicy, rid: int,
                        group: int, t_fail: float, kind: str) -> None:
        """Void request ``rid``'s service on ``group`` at ``t_fail`` and
        either queue its retry (backoff; pinned when migration is off) or
        dead-letter it once the budget is spent."""
        a = st.ledger.attempt_of(rid) + 1
        if rec.exhausted(a):
            st.ledger.attempts[rid] = a
            st.ledger.dead_letter(rid, "max_retries")
            st.retry_from.pop(rid, None)
            return
        st.ledger.record_retry(rid)
        if kind == "interrupt":
            st.ledger.interrupted += 1
        elif kind == "timeout":
            st.ledger.timeouts += 1
        st.retry_from[rid] = group
        pin = group if not rec.migrate else None
        st.push_retry(t_fail + rec.backoff(rid, a, self.seed), rid, a, pin)

    def _merge_ready_retries(self, st: _RunState, reqs: List[Request],
                             rid_index: Dict[int, int]) -> None:
        """Move every retry whose backoff elapsed to the FRONT of the
        pending queue (they are the oldest work), FIFO by (ready, seq)."""
        ready: List[Tuple[float, int, int, int, int]] = []
        while st.retryq and st.retryq[0][0] <= st.now:
            ready.append(heapq.heappop(st.retryq))
        for (_rdy, _seq, rid, _a, pin) in reversed(ready):
            if pin >= 0:
                st.retry_pin[rid] = pin
            st.pending.appendleft(reqs[rid_index[rid]])

    def _next_fault_event(self, st: _RunState) -> Optional[float]:
        """Earliest instant after ``now`` at which anything can change:
        a replica frees, a retry becomes ready, a pending group reset
        lands, or a perturbation window opens/closes."""
        cands: List[float] = []
        future = st.finish[st.finish > st.now]
        if future.size:
            cands.append(float(future.min()))
        if st.retryq:
            cands.append(max(st.retryq[0][0], np.nextafter(st.now, np.inf)))
        cands.extend(t for (t, _g) in st.resets if t > st.now)
        if self.perturb is not None:
            nc = self.perturb.next_change(st.now)
            if nc is not None:
                cands.append(nc)
        return min(cands) if cands else None

    def _shed(self, st: _RunState, rec: RecoveryPolicy) -> None:
        """Graceful degradation: dead-letter pending requests that already
        waited past ``shed_wait`` (deterministic head-of-queue scan)."""
        if rec.shed_wait is None:
            return
        while st.pending and \
                st.now - st.pending[0].arrival > rec.shed_wait:
            r = st.pending.popleft()
            st.ledger.dead_letter(r.rid, "shed")
            st.ledger.shed += 1
            st.retry_from.pop(r.rid, None)
            st.retry_pin.pop(r.rid, None)

    def _hedge_plan(self, st: _RunState, rec: RecoveryPolicy, r: Request,
                    g: int, f: Optional[np.ndarray],
                    rep: Optional[Tuple[np.ndarray, np.ndarray]]
                    ) -> Optional[Tuple[int, int, float, float]]:
        """Price a single-request hedge duplicate on the best group other
        than ``g``: returns ``(group, replica, service, done)`` or None.
        The hedge itself is voided if its group fails before it drains."""
        best = None
        slow = np.ones(self.G) if f is None else f
        for h in range(self.G):
            if h == g:
                continue
            alive = None if rep is None else rep[0][h]
            if alive is not None and not alive.any():
                continue
            fin = st.finish[h] if alive is None else st.finish[h][alive]
            ranks = np.arange(self.R) if alive is None \
                else np.flatnonzero(alive)
            k = int(np.argmin(fin))
            rr = int(ranks[k])
            scale = 1.0 if rep is None else float(rep[1][h, rr])
            service = (self.cost.fixed + request_cost(r, self.cost)) \
                * float(slow[h]) * scale
            start = max(float(st.finish[h, rr]), st.now)
            done = start + service
            if self.perturb is not None and \
                    self.perturb.failure_start(h, self.G, self.R,
                                               st.now, done) is not None:
                continue                # hedge would be interrupted too
            if best is None or done < best[3]:
                best = (h, rr, service, done)
        return best

    # -- journaling ----------------------------------------------------------
    def _snapshot(self, journal: RunJournal, st: _RunState, sig: str,
                  fault_mode: bool) -> None:
        meta = {
            "sig": sig, "G": self.G, "R": self.R, "seed": self.seed,
            "router": self.router.name,
            "router_state": self.router.state_dict(),
            "fault_mode": bool(fault_mode),
            "now": st.now, "i": st.i, "waves": st.waves,
            "admitted": st.admitted, "deferred": st.deferred, "t0": st.t0,
            "seq": st.seq,
            "resets": [[float(t), int(g)] for (t, g) in st.resets],
            "retry_from": {str(k): int(v)
                           for k, v in st.retry_from.items()},
            "retry_pin": {str(k): int(v) for k, v in st.retry_pin.items()},
            "attempts": {str(k): int(v)
                         for k, v in st.ledger.attempts.items()},
            "dead": {str(k): v for k, v in st.ledger.dead.items()},
            "counters": {k: getattr(st.ledger, k) for k in
                         ("retries", "interrupted", "timeouts", "migrated",
                          "hedges", "hedge_wins", "shed")},
            "policies": self._policy_states(),
        }
        retry = np.array(sorted(st.retryq), dtype=np.float64).reshape(-1, 5)
        arrays = {
            "finish": st.finish, "busy_tot": st.busy_tot,
            "lat_data": (np.concatenate(st.lats) if st.lats
                         else np.empty(0)),
            "lat_lens": np.array([len(a) for a in st.lats], dtype=np.int64),
            "pending": np.array([r.rid for r in st.pending],
                                dtype=np.int64),
            "retry": retry,
            "completed": (np.packbits(st.completed)
                          if st.completed is not None
                          else np.empty(0, dtype=np.uint8)),
            "stats_i": np.array(
                [[s.wave, s.algorithm, s.n_requests, s.chunks]
                 for sim in self.groups for s in sim.stats],
                dtype=np.int64).reshape(-1, 4),
            "stats_f": np.array(
                [[s.makespan, s.lib]
                 for sim in self.groups for s in sim.stats],
                dtype=np.float64).reshape(-1, 2),
            "stats_lens": np.array([len(sim.stats) for sim in self.groups],
                                   dtype=np.int64),
        }
        journal.save(st.waves, meta, arrays)

    def _policy_states(self) -> List[Dict]:
        out = []
        for sim in self.groups:
            rec = sim.service._regions.get(sim.region)
            if rec is None:
                out.append({})
                continue
            state = rec.policy.state_dict()
            out.append({"method": rec.policy.name, "state": state,
                        "instances": rec.instances})
        return out

    def _restore(self, snap: Dict, st: _RunState, sig: str, n: int,
                 fault_mode: bool) -> None:
        meta = snap["meta"]
        if meta["sig"] != sig:
            raise ValueError(
                f"journal snapshot was taken for trace {meta['sig']}, "
                f"cannot resume trace {sig}")
        if meta["G"] != self.G or meta["R"] != self.R:
            raise ValueError(
                f"journal fleet shape ({meta['G']}x{meta['R']}) does not "
                f"match this fleet ({self.G}x{self.R})")
        if meta["router"] != self.router.name:
            raise ValueError(
                f"journal was written under router {meta['router']!r}, "
                f"this fleet runs {self.router.name!r}")
        self.router.load_state_dict(meta.get("router_state", {}))
        st.now = float(meta["now"])
        st.i = int(meta["i"])
        st.waves = int(meta["waves"])
        st.admitted = int(meta["admitted"])
        st.deferred = int(meta["deferred"])
        st.t0 = float(meta["t0"])
        st.seq = int(meta["seq"])
        st.finish = np.array(snap["finish"], dtype=np.float64)
        st.busy_tot = np.array(snap["busy_tot"], dtype=np.float64)
        lat_data = np.asarray(snap["lat_data"], dtype=np.float64)
        st.lats = list(np.split(lat_data,
                                np.cumsum(snap["lat_lens"])[:-1])) \
            if len(snap["lat_lens"]) else []
        st.resets = [(float(t), int(g)) for t, g in meta.get("resets", [])]
        st.retry_from = {int(k): int(v)
                         for k, v in meta.get("retry_from", {}).items()}
        st.retry_pin = {int(k): int(v)
                        for k, v in meta.get("retry_pin", {}).items()}
        st.retryq = [(float(r[0]), int(r[1]), int(r[2]), int(r[3]),
                      int(r[4])) for r in snap["retry"]]
        heapq.heapify(st.retryq)
        if fault_mode:
            packed = np.asarray(snap["completed"], dtype=np.uint8)
            st.completed = np.unpackbits(packed, count=n).astype(bool) \
                if packed.size else np.zeros(n, dtype=bool)
        st.ledger.attempts = {int(k): int(v)
                              for k, v in meta.get("attempts", {}).items()}
        st.ledger.dead = {int(k): str(v)
                          for k, v in meta.get("dead", {}).items()}
        for k, v in meta.get("counters", {}).items():
            setattr(st.ledger, k, int(v))
        # per-group wave stats + region policy state
        lens = np.asarray(snap["stats_lens"], dtype=np.int64)
        si, sf = snap["stats_i"], snap["stats_f"]
        off = 0
        for g, sim in enumerate(self.groups):
            w = int(lens[g])
            sim.stats = [
                WaveStats(wave=int(si[off + j, 0]),
                          algorithm=int(si[off + j, 1]),
                          n_requests=int(si[off + j, 2]),
                          makespan=float(sf[off + j, 0]),
                          lib=float(sf[off + j, 1]),
                          chunks=int(si[off + j, 3]))
                for j in range(w)]
            off += w
        for sim, pol in zip(self.groups, meta.get("policies", [])):
            if not pol:
                continue
            rec = sim.service._record(sim.region)
            if pol.get("state") is not None and \
                    pol.get("method") == rec.policy.name:
                try:
                    rec.policy.load_state_dict(pol["state"])
                except (KeyError, ValueError, TypeError):
                    pass                # stateless-compatible policies
            rec.instances = int(pol.get("instances", 0))

    # -- the run loop --------------------------------------------------------
    def run(self, trace: Union[ArrivalTrace, Sequence[Request]],
            keep_latencies: bool = False,
            journal: Optional[RunJournal] = None,
            resume: bool = False) -> FleetReport:
        if self._ran:
            raise RuntimeError(
                "FleetSimulator.run is single-shot: a run mutates group "
                "busy-state and region policies — build a fresh "
                "FleetSimulator per run (resume=True restores a journal "
                "into a fresh instance)")
        self._ran = True
        reqs = trace.requests if isinstance(trace, ArrivalTrace) \
            else list(trace)
        n = len(reqs)
        sig = _trace_signature(trace, reqs)
        rec_pol = self.recovery
        fault_mode = rec_pol is not None or (
            self.perturb is not None and self.perturb.has_replica_events)
        if rec_pol is None:
            rec_pol = BASELINE_RECOVERY
        rid_index = {r.rid: j for j, r in enumerate(reqs)} if fault_mode \
            else None

        st = _RunState(self.G, self.R, n, reqs, fault_mode)
        if resume:
            if journal is None:
                raise ValueError("resume=True needs a journal")
            snap = journal.latest()
            if snap is None:
                raise ValueError(f"no journal snapshot under {journal.dir}")
            self._restore(snap, st, sig, n, fault_mode)

        quota = self.admission.wave_quota * self.G
        window = self.admission.batch_window
        visible = rec_pol.visible

        while st.i < n or st.pending or st.retryq:
            if fault_mode:
                self._merge_ready_retries(st, reqs, rid_index)
                if not st.pending:
                    nxt = []
                    if st.i < n:
                        nxt.append(reqs[st.i].arrival)
                    if st.retryq:
                        nxt.append(st.retryq[0][0])
                    t_next = min(nxt)
                    if t_next > st.now:
                        st.now = t_next
                        self._merge_ready_retries(st, reqs, rid_index)
            elif not st.pending and reqs[st.i].arrival > st.now:
                st.now = reqs[st.i].arrival
            while st.i < n and reqs[st.i].arrival <= st.now:
                st.pending.append(reqs[st.i])
                st.i += 1
            if st.i < n and len(st.pending) < quota and window > 0.0:
                # wave formation: wait for the quota to fill or the batch
                # window (measured from the oldest pending arrival) to
                # close, whichever is first — a no-op once saturated
                t_close = st.pending[0].arrival + window
                t_full = reqs[min(st.i + quota - len(st.pending), n)
                              - 1].arrival
                t_open = min(t_close, t_full)
                if t_open > st.now:
                    st.now = t_open
                    while st.i < n and reqs[st.i].arrival <= st.now:
                        st.pending.append(reqs[st.i])
                        st.i += 1
            if fault_mode:
                self._apply_resets(st)
                self._merge_ready_retries(st, reqs, rid_index)
                self._shed(st, rec_pol)
                if not st.pending:
                    continue            # everything shed / waiting retries
            f = self._slowdowns(st.now)
            self._apply_slowdowns(f)
            rep = self.perturb.replica_state(st.now, self.G, self.R) \
                if (fault_mode and self.perturb is not None) else None
            view = self._fault_view(st.now, st.finish, f, rep, visible) \
                if fault_mode else self._view(st.now, st.finish, f)
            if view.routable is not None and not view.routable.any():
                # every group is down: wait out the failure window (or the
                # next state change) instead of livelocking
                st.deferred += len(st.pending)
                t_next = self._next_fault_event(st)
                if t_next is None:
                    raise RuntimeError(
                        "fleet is permanently failed with work pending "
                        "and no future event — cannot complete the run")
                st.now = t_next
                continue
            k = self.admission.admit(st.pending, st.now, view)
            if k <= 0 and st.pending:
                # backpressure holds the whole wave: let the fleet drain to
                # the next replica-free instant and re-evaluate (never
                # busy-spin — admit() floors to min_admit once idle)
                st.deferred += len(st.pending)
                if fault_mode:
                    t_next = self._next_fault_event(st)
                    if t_next is not None:
                        st.now = t_next
                        continue
                else:
                    future = st.finish[st.finish > st.now]
                    if future.size:
                        st.now = float(future.min())
                        continue
                k = min(len(st.pending), max(1, self.admission.min_admit))
            batch = [st.pending.popleft() for _ in range(k)]
            st.deferred += len(st.pending)
            shards = self.router.route(batch, view)
            if fault_mode and st.retry_pin:
                # migration off: retries go back to the group they failed
                # on, bypassing the router's placement
                for g in range(self.G):
                    kept = []
                    for r in shards[g]:
                        pin = st.retry_pin.get(r.rid)
                        if pin is not None and pin != g:
                            shards[pin].append(r)
                        else:
                            kept.append(r)
                    shards[g] = kept
                for r in batch:
                    st.retry_pin.pop(r.rid, None)
            if fault_mode:
                self._dispatch_faulty(st, rec_pol, reqs, rid_index, shards,
                                      batch, view, f, rep)
            else:
                self._dispatch_clean(st, shards, batch, view)
            st.admitted += len(batch)
            st.waves += 1
            if journal is not None and st.waves % journal.every == 0:
                self._snapshot(journal, st, sig, fault_mode)
            if st.pending:
                # saturated: reopen when the earliest replica frees
                st.now = max(st.now,
                             float(st.finish.min(axis=1).min()))
        if journal is not None:
            self._snapshot(journal, st, sig, fault_mode)
        return self._report(st, n, keep_latencies, fault_mode)

    # -- dispatch paths ------------------------------------------------------
    def _dispatch_clean(self, st: _RunState, shards, batch, view) -> None:
        """The historical fault-free wave dispatch (bit-exact legacy path)."""
        wave_lat = np.empty(len(batch))
        w = 0
        for g, shard in enumerate(shards):
            if not shard:
                continue
            busy = view.busy[g]
            base = float(busy.min())
            sim = self.groups[g]
            # re-base to the dispatcher's relative origin (= the time
            # its earliest replica frees)
            sim.busy = busy - base
            stat = sim.run_wave(shard, st.waves)
            new_busy = sim.busy
            st.busy_tot[g] += new_busy - (busy - base)
            st.finish[g] = (st.now + base) + new_busy
            done = st.now + base + stat.makespan
            for r in shard:
                wave_lat[w] = done - r.arrival
                w += 1
        st.lats.append(wave_lat)

    def _dispatch_faulty(self, st: _RunState, rec: RecoveryPolicy,
                         reqs, rid_index, shards, batch, view, f, rep
                         ) -> None:
        """Wave dispatch under the fault model: masked/straggling replicas,
        whole-group interruption, timeouts, hedges, and the retry ledger."""
        records: List[Tuple[int, List[Request], float]] = []
        for g, shard in enumerate(shards):
            if not shard:
                continue
            alive_g = None if rep is None else rep[0][g]
            if alive_g is not None and not alive_g.any():
                # dispatched into a dead group (blind baseline, or a retry
                # pinned to it): the work queues until the fleet next
                # changes state, then replays.  With no future event a
                # bounded budget burns down to a dead letter; the unbounded
                # baseline could never complete, so it fails loudly.
                rejoin = self.perturb.next_change(st.now) \
                    if self.perturb is not None else None
                if rejoin is None and rec.max_retries < 0:
                    raise RuntimeError(
                        f"group {g} failed permanently with recovery "
                        f"disabled — queued work can never complete")
                t_fail = st.now if rejoin is None else rejoin
                for r in shard:
                    self._schedule_retry(st, rec, r.rid, g, t_fail,
                                         "interrupt")
                continue
            busy = view.busy[g]
            base = float(busy.min())
            sim = self.groups[g]
            sim.busy = busy - base
            stat = sim.run_wave(
                shard, st.waves, active=alive_g,
                replica_scale=None if rep is None else rep[1][g])
            new_busy = sim.busy
            st.busy_tot[g] += new_busy - (busy - base)
            st.finish[g] = (st.now + base) + new_busy
            records.append((g, shard, st.now + base + stat.makespan))

        # hedged duplicates for retried requests: a single-request
        # mini-dispatch on the best OTHER group; first finish wins, and a
        # losing hedge is never charged (its cost is refunded by
        # construction at wave granularity)
        hedge_done: Dict[int, float] = {}
        if rec.hedge:
            for g, shard, done_g in records:
                for r in shard:
                    if st.ledger.attempt_of(r.rid) == 0:
                        continue
                    plan = self._hedge_plan(st, rec, r, g, f, rep)
                    if plan is None:
                        continue
                    st.ledger.hedges += 1
                    h, rr, service, done_h = plan
                    fail = None if self.perturb is None else \
                        self.perturb.failure_start(g, self.G, self.R,
                                                   st.now, done_g)
                    p_done = done_g if fail is None else np.inf
                    if done_h < p_done:
                        st.ledger.hedge_wins += 1
                        st.finish[h, rr] = max(float(st.finish[h, rr]),
                                               st.now) + service
                        st.busy_tot[h, rr] += service
                        hedge_done[r.rid] = done_h

        # resolution: complete, retry, or dead-letter every routed request
        wave_lat: List[float] = []
        for g, shard, done_g in records:
            fail = None if self.perturb is None else \
                self.perturb.failure_start(g, self.G, self.R, st.now,
                                           done_g)
            if fail is not None:
                self._interrupt_group(st, g, fail[0])
            for r in shard:
                was_retry = r.rid in st.retry_from
                if was_retry and st.retry_from.get(r.rid) != g:
                    st.ledger.migrated += 1
                h_done = hedge_done.get(r.rid)
                if fail is None or h_done is not None:
                    eff = done_g if fail is None else np.inf
                    if h_done is not None:
                        eff = min(eff, h_done)
                    if rec.timeout is not None and \
                            eff - st.now > rec.timeout:
                        self._schedule_retry(st, rec, r.rid, g,
                                             st.now + rec.timeout,
                                             "timeout")
                        continue
                    j = rid_index[r.rid]
                    if st.completed[j]:
                        raise AssertionError(
                            f"request {r.rid} completed twice")
                    st.completed[j] = True
                    st.retry_from.pop(r.rid, None)
                    wave_lat.append(eff - r.arrival)
                else:
                    # in-flight on the failed group, no hedge to fall
                    # back on: void at the failure instant and retry
                    self._schedule_retry(st, rec, r.rid, g, fail[0],
                                         "interrupt")
        st.lats.append(np.array(wave_lat, dtype=np.float64))

    # -- reporting -----------------------------------------------------------
    def _report(self, st: _RunState, n: int, keep_latencies: bool,
                fault_mode: bool) -> FleetReport:
        lat = np.concatenate(st.lats) if st.lats else np.empty(0)
        makespan = float(st.finish.max() - st.t0) if n else 0.0
        wave_libs = np.array([s.lib for sim in self.groups
                              for s in sim.stats])
        recovery = None
        served = n
        if fault_mode:
            served = int(st.completed.sum())
            st.ledger.check(n, served)
            if lat.size != served:
                raise AssertionError(
                    f"{lat.size} latencies recorded for {served} "
                    f"completed requests")
            recovery = {"completed": served, **st.ledger.summary()}
        report = FleetReport(
            n_requests=n,
            makespan=makespan,
            throughput=served / max(makespan, 1e-12),
            p50=float(np.percentile(lat, 50)) if lat.size else 0.0,
            p95=float(np.percentile(lat, 95)) if lat.size else 0.0,
            p99=float(np.percentile(lat, 99)) if lat.size else 0.0,
            mean_latency=float(lat.mean()) if lat.size else 0.0,
            fleet_lib=percent_load_imbalance(st.busy_tot.ravel()),
            mean_wave_lib=float(wave_libs.mean()) if len(wave_libs) else 0.0,
            waves=st.waves,
            mean_wave_size=st.admitted / max(st.waves, 1),
            deferred=st.deferred,
            per_group=[{"region": sim.region,
                        "waves": len(sim.stats),
                        "requests": int(sum(s.n_requests
                                            for s in sim.stats)),
                        "busy_s": float(st.busy_tot[g].sum()),
                        "lib": percent_load_imbalance(st.busy_tot[g])}
                       for g, sim in enumerate(self.groups)],
            latencies=lat if keep_latencies else None,
            recovery=recovery)
        return report
