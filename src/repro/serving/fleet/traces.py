"""Replayable arrival traces — the fleet's stand-in for millions of users.

An :class:`ArrivalTrace` is a fully materialized, deterministic request
stream: heterogeneous Pareto-tailed prompt/gen lengths (the serving
adaptation's imbalance source, shared with ``data.pipeline``) under a
pluggable arrival *process*.  Every generator is a pure function of
``(kind, n, seed, params)`` — replaying a trace is just calling
:func:`make_trace` with the same arguments, and every random field draws
from its own named substream (:func:`~repro.data.pipeline.field_rng`), so
arrival times are bit-identical across length re-parameterizations.

Three processes cover the regimes the router study needs:

``poisson``
    Constant-rate exponential gaps — the stationary baseline (exactly
    ``data.pipeline.synthetic_requests``).
``bursty``
    2-state MMPP: a background rate with exponential-gap arrivals, and a
    burst state at ``burst_factor`` times that rate; state dwell times are
    geometric in *arrivals* (per-arrival Markov switching).  This is the
    non-stationary regime where what-if-priced routing pays: bursts leave
    replica groups unevenly loaded, so busy-state-blind policies misroute.
``diurnal``
    Sinusoidal rate ``base_rate * (1 + amplitude * sin(2*pi*t/period))``
    realized by thinning a max-rate Poisson stream — the slow day/night
    swing over which per-region selection policies must re-adapt.
"""

from __future__ import annotations

import json
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List

import numpy as np

from ...data.pipeline import Request, field_rng, synthetic_requests


@dataclass
class ArrivalTrace:
    """A materialized request stream: ``requests`` are arrival-sorted and
    ``rid``-indexed 0..n-1.  ``kind``/``seed``/``params`` are the complete
    replay recipe (``make_trace(kind, n, seed, **params)`` rebuilds the
    trace bit-identically)."""

    kind: str
    seed: int
    requests: List[Request]
    params: Dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def signature(self) -> str:
        """Stable digest of the replay recipe — the run journal records it
        so a snapshot can never be resumed under a different trace."""
        ident = json.dumps([self.kind, self.seed, len(self.requests),
                            sorted((k, repr(v))
                                   for k, v in self.params.items())])
        return f"{self.kind}-{zlib.crc32(ident.encode('utf-8')):08x}"

    @property
    def duration(self) -> float:
        """Span of the arrival process (time of the last arrival)."""
        return self.requests[-1].arrival if self.requests else 0.0

    @property
    def mean_rate(self) -> float:
        return len(self.requests) / max(self.duration, 1e-12)

    def offered_tokens(self) -> int:
        return sum(r.prompt_len + r.gen_len for r in self.requests)


def poisson_trace(n: int, seed: int = 0, rate: float = 256.0,
                  mean_prompt: int = 512, mean_gen: int = 128,
                  heavy_tail: float = 1.3) -> ArrivalTrace:
    """Stationary Poisson arrivals at ``rate`` requests/second."""
    reqs = synthetic_requests(n, seed=seed, mean_prompt=mean_prompt,
                              mean_gen=mean_gen, heavy_tail=heavy_tail,
                              arrival_rate=rate)
    return ArrivalTrace("poisson", seed, reqs,
                        {"rate": rate, "mean_prompt": mean_prompt,
                         "mean_gen": mean_gen, "heavy_tail": heavy_tail})


def _mmpp_states(n: int, rng: np.random.Generator, p_enter: float,
                 p_exit: float) -> np.ndarray:
    """Per-arrival 2-state Markov chain (0 = background, 1 = burst),
    vectorized as alternating geometric dwell counts."""
    states = np.empty(n, dtype=np.int8)
    filled = 0
    state = 0
    while filled < n:
        # geometric dwell (in arrivals) before switching out of `state`
        p = p_enter if state == 0 else p_exit
        dwell = int(rng.geometric(min(max(p, 1e-9), 1.0)))
        take = min(dwell, n - filled)
        states[filled:filled + take] = state
        filled += take
        state = 1 - state
    return states


def bursty_trace(n: int, seed: int = 0, base_rate: float = 256.0,
                 burst_factor: float = 8.0, p_enter: float = 0.02,
                 p_exit: float = 0.1, mean_prompt: int = 512,
                 mean_gen: int = 128, heavy_tail: float = 1.3
                 ) -> ArrivalTrace:
    """2-state MMPP arrivals: background ``base_rate`` with bursts at
    ``burst_factor *  base_rate``; expected dwell is ``1/p_enter`` arrivals
    of background per ``1/p_exit`` arrivals of burst."""
    rng = field_rng(seed, "arrival")
    states = _mmpp_states(n, rng, p_enter, p_exit)
    rates = np.where(states == 1, base_rate * burst_factor, base_rate)
    arrivals = np.cumsum(rng.exponential(1.0, n) / rates)
    reqs = synthetic_requests(n, seed=seed, mean_prompt=mean_prompt,
                              mean_gen=mean_gen, heavy_tail=heavy_tail,
                              arrivals=arrivals)
    return ArrivalTrace("bursty", seed, reqs,
                        {"base_rate": base_rate, "burst_factor": burst_factor,
                         "p_enter": p_enter, "p_exit": p_exit,
                         "mean_prompt": mean_prompt, "mean_gen": mean_gen,
                         "heavy_tail": heavy_tail})


def diurnal_trace(n: int, seed: int = 0, base_rate: float = 256.0,
                  amplitude: float = 0.8, period: float = 120.0,
                  mean_prompt: int = 512, mean_gen: int = 128,
                  heavy_tail: float = 1.3) -> ArrivalTrace:
    """Sinusoidal-rate arrivals via thinning: candidates at the peak rate
    ``base_rate * (1 + amplitude)``, each kept with probability
    ``rate(t) / peak`` — an exact non-homogeneous Poisson realization."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError("diurnal amplitude must be in [0, 1)")
    rng = field_rng(seed, "arrival")
    peak = base_rate * (1.0 + amplitude)
    arrivals = np.empty(0)
    t = 0.0
    while len(arrivals) < n:
        m = max(1024, int((n - len(arrivals)) * (1.0 + amplitude) * 1.2))
        cand = t + np.cumsum(rng.exponential(1.0 / peak, m))
        rate = base_rate * (1.0 + amplitude
                            * np.sin(2.0 * np.pi * cand / period))
        keep = rng.random(m) < rate / peak
        arrivals = np.concatenate([arrivals, cand[keep]])
        t = float(cand[-1])
    arrivals = arrivals[:n]
    reqs = synthetic_requests(n, seed=seed, mean_prompt=mean_prompt,
                              mean_gen=mean_gen, heavy_tail=heavy_tail,
                              arrivals=arrivals)
    return ArrivalTrace("diurnal", seed, reqs,
                        {"base_rate": base_rate, "amplitude": amplitude,
                         "period": period, "mean_prompt": mean_prompt,
                         "mean_gen": mean_gen, "heavy_tail": heavy_tail})


#: registry of trace generators (the fleet benchmark and CLI key off these)
TRACE_KINDS: Dict[str, Callable[..., ArrivalTrace]] = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}


def make_trace(kind: str, n: int, seed: int = 0, **params) -> ArrivalTrace:
    """Build (or replay) a trace by kind name."""
    try:
        gen = TRACE_KINDS[kind.lower()]
    except KeyError:
        raise ValueError(f"unknown trace kind {kind!r}; "
                         f"available: {sorted(TRACE_KINDS)}") from None
    return gen(n, seed=seed, **params)
