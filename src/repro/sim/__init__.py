"""repro.sim — discrete-event simulator of the paper's experiment campaign.

Simulation engines are pluggable (``repro.sim.backends``): the reference
Python event loop and a batched vmapped JAX engine share one protocol, one
event cap, and one noise model contract.
"""

from .systems import (HETERO_SYSTEMS, SYSTEMS, SystemModel, get_system,
                      hetero_system)
from .workloads import (APPLICATIONS, Application, LoopProfile, ProfileStack,
                        get_application, stack_prefix_grids)
from .engine import InstanceResult, run_instance
from .backends import (EVENT_CAP, BatchResult, InstancePerturb, InstanceSpec,
                       LockstepRequest, SimBackend, backend_names,
                       get_backend, register_backend)
from .perturb import (FleetPerturb, GroupSlowdown, NoiseBurst, PEFailure,
                      PESlowdown, PerturbationSpec, WorkloadDrift,
                      drift_spec, noise_burst_spec, pe_slowdown_spec)
from .whatif import LoopWhatIf, noise_free
from .translog import (TRANSLOG_VERSION, TransitionLogger, load_shards,
                       load_translog, save_translog)
from .campaign import (CampaignResult, CellSpec, FixedRun, PortfolioSweep,
                       ReplayBatch, SelectorRun, run_campaign,
                       run_campaign_cell, run_fixed, run_selector,
                       run_selector_sequential, sweep_portfolio,
                       chunk_param_for, CHUNK_MODES, SELECTOR_GRID,
                       EXTENDED_SELECTOR_GRID, SIM_SELECTOR_GRID)

__all__ = [
    "SYSTEMS", "HETERO_SYSTEMS", "SystemModel", "get_system",
    "hetero_system", "APPLICATIONS", "Application",
    "LoopProfile", "ProfileStack", "stack_prefix_grids", "get_application",
    "InstanceResult",
    "run_instance", "EVENT_CAP", "BatchResult", "InstancePerturb",
    "InstanceSpec", "LockstepRequest", "SimBackend",
    "PerturbationSpec", "PESlowdown", "PEFailure", "NoiseBurst",
    "WorkloadDrift", "FleetPerturb", "GroupSlowdown",
    "pe_slowdown_spec", "noise_burst_spec", "drift_spec",
    "backend_names", "get_backend", "register_backend",
    "CampaignResult", "CellSpec", "FixedRun", "PortfolioSweep",
    "ReplayBatch", "SelectorRun",
    "run_campaign", "run_campaign_cell", "run_fixed", "run_selector",
    "run_selector_sequential", "sweep_portfolio",
    "chunk_param_for", "CHUNK_MODES", "SELECTOR_GRID",
    "EXTENDED_SELECTOR_GRID", "SIM_SELECTOR_GRID",
    "LoopWhatIf", "noise_free",
    "TransitionLogger", "TRANSLOG_VERSION", "load_translog", "load_shards",
    "save_translog",
]
