"""repro.sim — discrete-event simulator of the paper's experiment campaign."""

from .systems import SYSTEMS, SystemModel, get_system
from .workloads import APPLICATIONS, Application, LoopProfile, get_application
from .engine import InstanceResult, run_instance
from .campaign import (CampaignResult, FixedRun, PortfolioSweep, SelectorRun,
                       run_campaign_cell, run_fixed, run_selector,
                       sweep_portfolio, chunk_param_for, CHUNK_MODES,
                       SELECTOR_GRID, EXTENDED_SELECTOR_GRID)

__all__ = [
    "SYSTEMS", "SystemModel", "get_system", "APPLICATIONS", "Application",
    "LoopProfile", "get_application", "InstanceResult", "run_instance",
    "CampaignResult", "FixedRun", "PortfolioSweep", "SelectorRun",
    "run_campaign_cell", "run_fixed", "run_selector", "sweep_portfolio",
    "chunk_param_for", "CHUNK_MODES", "SELECTOR_GRID",
    "EXTENDED_SELECTOR_GRID",
]
