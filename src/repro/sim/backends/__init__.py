"""Pluggable simulation backends.

>>> from repro.sim.backends import get_backend
>>> get_backend("python")      # reference event-loop engine
>>> get_backend("jax")         # batched vmapped engine (campaign sweeps)
>>> get_backend("jax-pallas")  # same engine, fused Pallas event core

``get_backend(None)`` resolves the default from the ``REPRO_SIM_BACKEND``
environment variable (falling back to ``python``), so scripts and
subprocess drivers can switch engines without threading a flag through
every call site.  Backends are process-wide singletons — the JAX backend's
schedule caches persist across sweeps.

The JAX engine's sequential event core is itself pluggable
(``REPRO_EVENT_CORE`` / ``JaxBatchedBackend(kernel=...)``): ``jax`` keeps
the vmapped ``lax.while_loop`` reference, ``jax-pallas`` is the same
backend constructed with the fused on-chip Pallas kernel
(``repro.kernels.event_loop``).
"""

from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Union

from .base import (EVENT_CAP, BatchResult, InstancePerturb, InstanceSpec,
                   LockstepRequest, SimBackend, combined_pe_scale,
                   needs_closed_form, sigma_scale_of)

_FACTORIES: Dict[str, Callable[[], SimBackend]] = {}
_INSTANCES: Dict[str, SimBackend] = {}

#: env var naming the default backend
BACKEND_ENV = "REPRO_SIM_BACKEND"


def register_backend(name: str, factory: Callable[[], SimBackend]) -> None:
    _FACTORIES[name] = factory


def backend_names():
    return sorted(_FACTORIES)


def get_backend(name: Union[str, SimBackend, None] = None) -> SimBackend:
    """Resolve a backend by name (or pass an instance through)."""
    if isinstance(name, SimBackend):
        return name
    if name is None:
        name = os.environ.get(BACKEND_ENV, "python")
    name = name.lower()
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown simulation backend {name!r}; "
            f"available: {backend_names()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _FACTORIES[name]()
    return _INSTANCES[name]


def _make_python() -> SimBackend:
    from .python import PythonBackend
    return PythonBackend()


def _make_jax() -> SimBackend:
    from .jax_batched import JaxBatchedBackend
    return JaxBatchedBackend()


def _make_jax_pallas() -> SimBackend:
    from .jax_batched import JaxBatchedBackend
    return JaxBatchedBackend(kernel="pallas")


register_backend("python", _make_python)
register_backend("jax", _make_jax)
register_backend("jax-pallas", _make_jax_pallas)

__all__ = [
    "EVENT_CAP", "BatchResult", "InstancePerturb", "InstanceSpec",
    "LockstepRequest", "SimBackend", "combined_pe_scale", "needs_closed_form",
    "sigma_scale_of", "get_backend", "register_backend", "backend_names",
    "BACKEND_ENV",
]
