"""Simulation-backend protocol: the contract every DES engine implements.

A backend evaluates loop instances — one at a time (``run_instance``, the
selector path) or as a whole batch (``run_batch``, the campaign path) — and
what-if dispatch waves for the serving layer (``what_if_wave``).  The
campaign, serving dispatcher, and benchmarks only ever talk to this surface,
so engines are interchangeable: the reference Python event loop
(``backends.python``) and the batched vmapped JAX engine
(``backends.jax_batched``) must agree noise-free (``tests/test_backends.py``).
The JAX engine additionally keeps its *sequential event core* pluggable
behind a ``(eff_costs, forced, count) -> finish`` contract — a vmapped
``lax.while_loop`` reference and a fused Pallas kernel that must match it
bit-for-bit (``tests/test_event_kernel.py``).

``EVENT_CAP`` is the *shared* event budget: both backends switch SS /
StaticSteal to the analytic closed form when one instance would exceed it,
so the cutover point is identical everywhere (the paper's STREAM blowup is
always computed analytically, never stepped).
"""

from __future__ import annotations

import abc
import zlib
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

#: Max dispatch events one instance may generate before SS/StaticSteal go
#: analytic.  Single source of truth — ``engine.EVENT_CAP`` and
#: ``engine_jax.MAX_EVENTS`` are re-exports of this value.
EVENT_CAP = 120_000


def needs_closed_form(alg: int, N: int, chunk_param: int,
                      cap: int = EVENT_CAP) -> bool:
    """True when a constant-chunk algorithm (SS/StaticSteal) would blow the
    event budget and must be evaluated with the analytic closed form."""
    if alg not in (1, 5):
        return False
    c_floor = max(1, chunk_param)
    return N / c_floor > cap


@dataclass(frozen=True)
class InstancePerturb:
    """Per-instance view of an injected perturbation (``repro.sim.perturb``
    resolves a time-windowed :class:`PerturbationSpec` into one of these per
    time step).

    ``pe_scale`` multiplies each PE's execution time (1.0 nominal, > 1
    slower, ~1e4 models a failed PE the dynamic algorithms must route
    around); ``sigma_scale`` multiplies the machine's lognormal noise sigma
    (bursty noise).  ``None`` / 1.0 are exact no-ops: both backends apply
    the multipliers as IEEE ``x * 1.0`` identities without consuming any
    extra rng draws, so a neutral perturbation is bit-equal to no
    perturbation at all (test-enforced).
    """

    pe_scale: Optional[Tuple[float, ...]] = None
    sigma_scale: float = 1.0

    def __post_init__(self):
        if self.pe_scale is not None:
            object.__setattr__(self, "pe_scale",
                               tuple(float(x) for x in self.pe_scale))
        object.__setattr__(self, "sigma_scale", float(self.sigma_scale))

    @property
    def neutral(self) -> bool:
        return self.sigma_scale == 1.0 and (
            self.pe_scale is None
            or all(x == 1.0 for x in self.pe_scale))

    def key(self) -> Tuple:
        """Hashable cache-key component (pricing caches must not alias a
        perturbed run with a clean one)."""
        return (self.pe_scale, self.sigma_scale)


def combined_pe_scale(system, perturb: Optional[InstancePerturb]
                      ) -> Optional[np.ndarray]:
    """Per-PE execution-time multipliers: the machine model's persistent
    heterogeneity (``SystemModel.pe_speeds``) composed with any instance
    perturbation.  ``None`` means exactly uniform — callers skip the
    multiply entirely, keeping clean runs bit-identical."""
    speeds = getattr(system, "pe_speeds", None)
    out = None if speeds is None else np.asarray(speeds, np.float64)
    if perturb is not None and perturb.pe_scale is not None:
        ps = np.asarray(perturb.pe_scale, np.float64)
        out = ps if out is None else out * ps
    return out


def sigma_scale_of(perturb: Optional[InstancePerturb]) -> float:
    return 1.0 if perturb is None else perturb.sigma_scale


@dataclass(frozen=True)
class InstanceSpec:
    """One loop instance inside a batch: which profile, which algorithm,
    which chunk parameter, and the full rng seed tuple (the campaign's
    crc32-label convention).  ``fold_seed`` collapses the tuple into one
    stateless uint32 for counter-based (JAX) rng streams.

    ``perturb`` is deliberately excluded from ``fold_seed``: a perturbed
    instance keeps the exact noise stream of its clean twin, so enabling a
    perturbation never shifts any other lane's (or its own) draws.
    """

    profile_id: int
    alg: int
    chunk_param: int
    seed: Tuple[int, ...]
    perturb: Optional[InstancePerturb] = None

    def fold_seed(self) -> int:
        return zlib.crc32(np.asarray(self.seed, dtype=np.int64).tobytes())


@dataclass
class BatchResult:
    """Per-instance outputs in spec order."""

    loop_time: np.ndarray      # (B,)
    lib: np.ndarray            # (B,)
    n_chunks: np.ndarray       # (B,) int


@dataclass
class LockstepRequest:
    """One lane's loop instance inside a lockstep replay step.

    Unlike :class:`InstanceSpec` (stateless seed tuples), a lockstep request
    carries the lane's *live* numpy Generator: selector replays are
    sequential across time steps, and every instance must consume the lane's
    noise stream exactly where the historical per-cell loop would have — the
    Python backend stays bit-identical to ``run_selector``'s sequential
    replay, and the JAX backend draws its stateless fold seed from the same
    stream position its ``run_instance`` path would.
    """

    profile_id: int
    alg: int
    chunk_param: int
    rng: np.random.Generator
    perturb: Optional[InstancePerturb] = None


class SimBackend(abc.ABC):
    """Protocol for pluggable simulation engines."""

    name: str = "base"
    event_cap: int = EVENT_CAP

    @abc.abstractmethod
    def run_instance(self, profile, system, alg: int, chunk_param: int,
                     rng, record_chunks: bool = False,
                     perturb: Optional[InstancePerturb] = None):
        """Simulate one loop instance; returns an ``InstanceResult``."""

    @abc.abstractmethod
    def run_batch(self, profiles: Sequence, system,
                  specs: Sequence[InstanceSpec]) -> BatchResult:
        """Evaluate a batch of instances over a shared profile set."""

    def run_lockstep(self, profiles: Sequence, system,
                     requests: Sequence["LockstepRequest"]) -> BatchResult:
        """Execute one lockstep replay step: every lane's loop instance for
        the current time step, each drawing from its own lane rng.

        Lane rng streams MUST be consumed in request order (lanes are
        independent generators, so only the *within-lane* order is
        observable).  This base implementation steps ``run_instance``
        sequentially — bit-identical to the historical per-cell replay loop;
        batched engines override it to fan the event-loop instances into one
        device call while preserving each lane's stream position.
        """
        B = len(requests)
        lt = np.zeros(B)
        lib = np.zeros(B)
        nc = np.zeros(B, np.int64)
        for i, q in enumerate(requests):
            r = self.run_instance(profiles[q.profile_id], system, q.alg,
                                  q.chunk_param, q.rng, perturb=q.perturb)
            lt[i], lib[i], nc[i] = r.loop_time, r.lib, r.n_chunks
        return BatchResult(loop_time=lt, lib=lib, n_chunks=nc)

    @abc.abstractmethod
    def what_if_wave(self, prefix: np.ndarray, n_replicas: int,
                     init_avail: np.ndarray, h: float, fixed: float,
                     algs: Sequence[int], chunk_param: int = 0
                     ) -> np.ndarray:
        """Predicted wave makespan for each candidate algorithm.

        ``prefix``: (N+1,) cumulative request cost (token cost model);
        ``init_avail``: (R,) current replica busy-offsets; ``h`` the
        dispatch overhead per self-assigned chunk; ``fixed`` the cost
        model's per-batch constant (paid by every chunk, including
        STATIC's pre-assigned ranges, which skip ``h``).  Returns one
        makespan per entry of ``algs`` — the serving policy's batched
        what-if query (SimAS-style online consultation).
        """

    def what_if_routes(self, prefixes: Sequence[np.ndarray],
                       n_replicas: int,
                       init_avails: Sequence[np.ndarray], h: float,
                       fixed: float,
                       cands: Sequence[Tuple[int, int, int]]) -> np.ndarray:
        """Fleet-batched what-if: candidates span (routing slot, algorithm,
        chunk parameter).

        A *slot* is one replica group handed one candidate request shard:
        ``prefixes[s]`` is that shard's (N_s+1,) cumulative cost prefix and
        ``init_avails[s]`` the group's (R,) busy offsets at dispatch time.
        ``cands`` rows are ``(slot, alg, chunk_param)``; the return value is
        one predicted makespan per row — what the fleet router consumes to
        price candidate (replica-group, algorithm, chunk) assignments in a
        single consultation per admission wave.

        This base implementation fans out over :meth:`what_if_wave` (one
        call per distinct (slot, chunk) pair); batched engines override it
        to evaluate every candidate row in one device call.
        """
        out = np.zeros(len(cands))
        groups: dict = {}
        for i, (slot, alg, cp) in enumerate(cands):
            groups.setdefault((int(slot), int(cp)), []).append((i, int(alg)))
        for (slot, cp), rows in groups.items():
            mk = self.what_if_wave(prefixes[slot], n_replicas,
                                   init_avails[slot], h, fixed,
                                   [a for _, a in rows], chunk_param=cp)
            for (i, _), m in zip(rows, mk):
                out[i] = m
        return out
