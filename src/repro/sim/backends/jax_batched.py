"""Batched vmapped JAX simulation backend — campaign-scale sweeps in a
handful of jitted calls.

Where the reference engine steps one Python event loop per instance, this
backend evaluates *whole batches* of instances — (algorithm x chunk-mode x
rep x time-step) — at once:

1.  Chunk schedules are precomputed through ``repro.core.jaxsched``
    (non-adaptive algorithms exactly; AWF-*/mAF via their telemetry-free
    surrogate recurrences; StaticSteal via the quantum-serving replay that
    yields explicit (start, size, pe) triples) and cached by
    (alg, N, P, chunk_param) — one schedule serves every rep and time-step
    (LRU-bounded so long campaign processes stay flat).
2.  Everything data-parallel runs in ONE vectorized precompute shared by
    every event core: gathered linear interpolation over the stacked prefix
    grids (device upload cached per profile stack), locality inflation, and
    the counter-based jitter/speed/log-normal-noise draws.
3.  The sequential event loop itself is a minimal pluggable core
    ``(eff_costs, forced, count) -> finish`` with two interchangeable
    implementations: the vmapped ``lax.while_loop`` reference (argmin
    assignment, exactly the reference heap policy: one entry per PE, ties
    to the lowest index) and the fused on-chip Pallas kernel
    (``repro.kernels.event_loop``), selected via the ``kernel=``
    constructor argument / the ``REPRO_EVENT_CORE`` env var.  The Pallas
    core is bit-identical to the while-loop core in interpret mode
    (``tests/test_event_kernel.py``) and additionally fuses the prefix
    gather + locality/noise application on-chip for the campaign path.
    ``run_batch``, ``run_lockstep`` and ``what_if_wave`` all route through
    the selected core.

4.  On a multi-device host every batched lane dimension — ``run_batch`` /
    ``run_lockstep`` instances and the serving what-if candidate rows —
    executes under ``jax.shard_map`` over the campaign mesh's ``data``
    axis (``launch.mesh.campaign_mesh`` + ``distributed.sharding`` lane
    specs): lanes are embarrassingly parallel, so each device runs the
    identical per-lane computation on its shard and the results are
    bit-identical to the single-device path (lane counts are padded to the
    mesh extent and masked with ``count == 0``; ``tests/test_shard.py``).
    ``data_parallel=`` / ``REPRO_DATA_PARALLEL`` clamp the mesh.  The host
    side is double-buffered (``async_dispatch=`` / ``REPRO_ASYNC_DISPATCH``):
    ragged-to-padded packing of dispatch t+1 overlaps the device executing
    dispatch t.

STATIC and over-``EVENT_CAP`` SS/StaticSteal instances are delegated to the
reference closed forms with the *same* numpy rng streams, so those results
are bit-identical to the Python backend.  Event-loop instances draw their
jitter/speed/noise from counter-based JAX streams folded statelessly from
the campaign's crc32 seed tuples — reproducible across processes, batch
orders and event cores, but a *different* (equally valid) noise realization
than numpy.

Accuracy contract (see tests/test_backends.py): noise-free, the chunk
sequences and makespans match the Python backend exactly for the
non-adaptive algorithms and StaticSteal on uniform loops; the adaptive
family follows its constant-telemetry surrogate — faithful when per-chunk
rates are homogeneous, approximate under strong noise/imbalance.  Serving
what-ifs gather their per-chunk request costs from the float64 host prefix
(exact integer indexing) before the float32 device recurrence, so large
request totals no longer lose precision against the float64 closed-form
STATIC branch.
"""

from __future__ import annotations

import functools
import os
import warnings
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as Pspec

from ...core.jaxsched import (chunk_schedule, staticsteal_schedule,
                              weighted_adaptive_schedule)
from ...core.portfolio import ADAPTIVE_SET
from ...distributed.sharding import lane_count, lane_spec, pad_lanes
from ...launch.mesh import campaign_mesh
from ..workloads import profile_digest as _profile_digest
from ..workloads import stack_prefix_grids
from .base import (BatchResult, InstancePerturb, InstanceSpec, LockstepRequest,
                   SimBackend, combined_pe_scale, needs_closed_form,
                   sigma_scale_of)
from .python import InstanceResult, _h_eff, run_instance as _py_run_instance

#: lax.while_loop buffer buckets for schedule length (powers of four keep
#: jit recompiles bounded); the last bucket must exceed EVENT_CAP plus
#: StaticSteal's steal-split slack.
_K_BUCKETS = (256, 1024, 4096, 16384, 65536, 262144)
#: max elements per (B, K) device array in one call (~16 MB float32)
_MAX_ELEMS = 1 << 22

#: env var naming the default sequential event core
EVENT_CORE_ENV = "REPRO_EVENT_CORE"
EVENT_CORES = ("while_loop", "pallas")
#: env var clamping the campaign mesh's data axis (lanes shard over it);
#: unset means "all local devices", 1 disables sharding entirely
DATA_PARALLEL_ENV = "REPRO_DATA_PARALLEL"
#: env var toggling double-buffered async dispatch ("0" restores the
#: synchronous pack -> dispatch -> drain loop)
ASYNC_DISPATCH_ENV = "REPRO_ASYNC_DISPATCH"
#: env var toggling the weighted adaptive surrogates under perturbed /
#: heterogeneous PE speeds ("0" keeps the weights-at-1 recurrences — the
#: A/B knob for the two-pass fidelity benchmarks)
ADAPTIVE_REWEIGHT_ENV = "REPRO_ADAPTIVE_REWEIGHT"


def _next_bucket(n: int) -> int:
    for b in _K_BUCKETS:
        if n <= b:
            return b
    raise ValueError(f"schedule length {n} exceeds largest bucket")


def _pow2_rows(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


def _pallas_available() -> bool:
    try:
        from ...kernels import ops  # noqa: F401  (the routed kernel path)
        return True
    except Exception:       # pragma: no cover - exotic builds without pallas
        return False


def resolve_event_core(kernel: Optional[str] = None) -> str:
    """Resolve the sequential event core: explicit ``kernel=`` argument,
    else ``REPRO_EVENT_CORE``, else the platform default (``"auto"``):
    the Mosaic-compiled Pallas kernel on accelerator platforms, the vmapped
    ``lax.while_loop`` reference on CPU, where Pallas only interprets (the
    policy lives in ``kernels.ops.preferred_event_core``).  Falls back
    (with a warning) when Pallas is unavailable in this jax build."""
    name = (kernel or os.environ.get(EVENT_CORE_ENV) or "auto").lower()
    if name == "auto":
        if not _pallas_available():     # pragma: no cover - exotic builds
            return "while_loop"
        from ...kernels.ops import preferred_event_core
        return preferred_event_core()
    if name not in EVENT_CORES:
        raise ValueError(f"unknown event core {name!r}; "
                         f"available: ['auto', *{list(EVENT_CORES)}]")
    if name == "pallas" and not _pallas_available():
        warnings.warn("Pallas unavailable; falling back to the "
                      "while_loop event core", RuntimeWarning)
        name = "while_loop"
    return name


def resolve_data_parallel(data_parallel: Optional[int] = None) -> int:
    """Resolve the campaign mesh's data extent: explicit argument, else
    ``REPRO_DATA_PARALLEL``, else every local device.  Always clamped to
    the local device count (``make_host_mesh`` clamps again on its side)."""
    if data_parallel is None:
        env = os.environ.get(DATA_PARALLEL_ENV)
        data_parallel = int(env) if env else len(jax.devices())
    if data_parallel < 1:
        raise ValueError(f"data_parallel must be >= 1, got {data_parallel}")
    return min(data_parallel, len(jax.devices()))


def resolve_async_dispatch(async_dispatch: Optional[bool] = None) -> bool:
    if async_dispatch is None:
        return os.environ.get(ASYNC_DISPATCH_ENV, "1") != "0"
    return bool(async_dispatch)


def resolve_adaptive_reweight(adaptive_reweight: Optional[bool] = None
                              ) -> bool:
    if adaptive_reweight is None:
        return os.environ.get(ADAPTIVE_REWEIGHT_ENV, "1") != "0"
    return bool(adaptive_reweight)


class _LRU:
    """Tiny LRU mapping bounding the process-wide caches (schedules, steal
    replays, device-resident grid stacks) of the singleton backend."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: OrderedDict = OrderedDict()

    def get(self, key, default=None):
        try:
            self._d.move_to_end(key)
            return self._d[key]
        except KeyError:
            return default

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def __len__(self) -> int:
        return len(self._d)


# ---------------------------------------------------------------------------
# jitted cores (module-level so the compile cache is shared across backends)
# ---------------------------------------------------------------------------

def _core_while(eff, speed, jitter, h_eff, bcost, forced, count):
    """Reference sequential core: vmapped ``lax.while_loop`` over per-PE
    finish times — argmin assignment (ties to the lowest index), forced-PE
    rows for StaticSteal.  The accuracy oracle every other core must match
    bit-for-bit: ``fin[pe] += h_eff + eff[i] * speed[pe] + bcost``."""

    def one(eff, speed, jitter, h_eff, bc, forced, cnt):
        def body(carry):
            i, fin = carry
            pe = jnp.where(forced[i] >= 0, forced[i], jnp.argmin(fin))
            fin = fin.at[pe].add(h_eff + eff[i] * speed[pe] + bc)
            return i + 1, fin

        _, fin = lax.while_loop(lambda c: c[0] < cnt, body,
                                (jnp.asarray(0, jnp.int32), jitter))
        return fin

    return jax.vmap(one)(eff, speed, jitter, h_eff, bcost, forced, count)


def _core_finish(core: str, eff, speed, jitter, h_eff, bcost, forced,
                 count):
    """Dispatch to the selected sequential core (``core`` is static).

    The Pallas path goes through ``kernels.ops`` so the interpret-on-CPU /
    Mosaic-on-TPU policy stays in one place."""
    if core == "pallas":
        from ...kernels.ops import event_finish
        return event_finish(eff, speed, jitter, h_eff, bcost, forced, count)
    return _core_while(eff, speed, jitter, h_eff, bcost, forced, count)


def _batched_events_impl(P: int, core: str, grids, grid_id, inv_n, starts,
                         sizes, loc, count, forced, seeds, h_eff, bcost,
                         pe_mult, sig_scale, sigma, jitter_max,
                         speed_spread):
    """Batched event loop: shared data-parallel precompute + one sequential
    core call.

    grids (S, G+1) f32; per-lane arrays: grid_id (B,), inv_n (B,),
    starts/sizes (B, K) i32, loc (B, K) f32, count (B,), forced (B, K) i32
    (-1 = argmin assignment), seeds (B,) u32, h_eff/bcost (B,),
    pe_mult (B, P) f32 per-PE execution-time multipliers and sig_scale (B,)
    f32 noise-sigma scales (the perturbation-injection lanes — all-1.0 rows
    are exact IEEE no-ops, so unperturbed lanes stay bit-identical and the
    event cores never see perturbation state).
    Returns (makespan (B,), lib (B,), finish (B, P)).
    """
    G = grids.shape[1] - 1
    K = starts.shape[1]

    def draws(seed, ss):
        key = jax.random.PRNGKey(seed)
        kj, ks, kn = jax.random.split(key, 3)
        jitter = jax.random.uniform(kj, (P,)) * jitter_max
        speed = jnp.clip(1.0 + speed_spread * jax.random.normal(ks, (P,)),
                         0.8, 1.25)
        noise = jnp.exp((sigma * ss) * jax.random.normal(kn, (K,)))
        return jitter, speed, noise

    jitter, speed, noise = jax.vmap(draws)(seeds, sig_scale)
    # perturbation / heterogeneity enters HERE, in the shared precompute —
    # upstream of every event core, so while_loop and Pallas stay identical
    speed = speed * pe_mult
    gscale = G * inv_n

    if core == "pallas":
        # full fusion: the prefix gather + locality/noise application run
        # inside the kernel (rows scalar-prefetched per lane from the
        # shared stack); eff never materializes to HBM
        from ...kernels.ops import event_finish_fused
        fin = event_finish_fused(grids, grid_id, gscale, starts, sizes, loc,
                                 noise, speed, jitter, h_eff, bcost, forced,
                                 count)
    else:
        def eff_one(gid, gs, starts, sizes, loc, noise):
            def pref(x):
                pos = x.astype(jnp.float32) * gs
                i = jnp.clip(pos.astype(jnp.int32), 0, G - 1)
                lo = grids[gid, i]
                return lo + (pos - i) * (grids[gid, i + 1] - lo)

            return (pref(starts + sizes) - pref(starts)) * loc * noise

        eff = jax.vmap(eff_one)(grid_id, gscale, starts, sizes, loc, noise)
        fin = _core_while(eff, speed, jitter, h_eff, bcost, forced, count)

    mk = fin.max(axis=1)
    lib = jnp.where(mk > 0.0, (1.0 - fin.mean(axis=1) / mk) * 100.0, 0.0)
    return mk, lib, fin


def _wave_eval_impl(R: int, core: str, eff, count, forced, init_avail, h):
    """Batched what-if over precomputed per-chunk request costs.

    eff (A, K) f32 — gathered host-side from the float64 cost prefix with
    exact integer indexing, so no interpolation and no float32 prefix
    cancellation; init_avail (R,) busy offsets shared by every candidate.
    Runs the same sequential core as the campaign path (unit speeds, zero
    jitter beyond the busy offsets)."""
    A = eff.shape[0]
    speed = jnp.ones((A, R), jnp.float32)
    jitter = jnp.broadcast_to(init_avail.astype(jnp.float32), (A, R))
    h_eff = jnp.full((A,), h, jnp.float32)
    bc = jnp.zeros((A,), jnp.float32)
    fin = _core_finish(core, eff, speed, jitter, h_eff, bc, forced, count)
    return fin.max(axis=1)


def _route_eval_impl(R: int, core: str, eff, count, forced, init_avails, h):
    """Fleet variant of :func:`_wave_eval_impl`: every candidate row carries
    its OWN (R,) busy-offset vector (rows span replica groups with distinct
    busy-states, not just algorithms over one wave), so ``init_avails`` is
    (A, R) instead of a shared broadcast."""
    A = eff.shape[0]
    speed = jnp.ones((A, R), jnp.float32)
    jitter = init_avails.astype(jnp.float32)
    h_eff = jnp.full((A,), h, jnp.float32)
    bc = jnp.zeros((A,), jnp.float32)
    fin = _core_finish(core, eff, speed, jitter, h_eff, bc, forced, count)
    return fin.max(axis=1)


# donate_argnums was evaluated for both cores and rejected: donation only
# pays when an output can alias a donated input, and every output here —
# mk/lib (B,), finish (B, P), wave makespans (A,) — is orders of magnitude
# smaller than the (B, K) schedule buffers, so donation would be a no-op
# that warns per compiled shape on every platform.
_batched_events = jax.jit(_batched_events_impl, static_argnums=(0, 1))
_wave_eval = jax.jit(_wave_eval_impl, static_argnums=(0, 1))
_route_eval = jax.jit(_route_eval_impl, static_argnums=(0, 1))


# ---------------------------------------------------------------------------
# mesh-sharded cores
# ---------------------------------------------------------------------------
#
# Lanes are embarrassingly parallel over the leading batch axis, so every
# jitted core also exists shard_map'd over the campaign mesh's ``data``
# axis: each device runs the identical per-lane computation on its B/ndev
# shard, no collectives anywhere, and per-lane arithmetic (including the
# counter-based noise draws folded from per-lane seeds) is untouched — the
# sharded results are bit-identical to the single-device path by
# construction.  Callers pad the lane axis to a multiple of the mesh's data
# extent with ``count == 0`` rows and slice the padding off host-side.
# Builders are cached per (mesh, statics) so each compiled executable is
# reused across dispatches exactly like the unsharded jits.

@functools.lru_cache(maxsize=32)
def _sharded_events(mesh, P: int, core: str):
    lane, rep = lane_spec(mesh), Pspec()
    fn = shard_map(functools.partial(_batched_events_impl, P, core),
                   mesh=mesh,
                   in_specs=(rep,) + (lane,) * 12 + (rep,) * 3,
                   out_specs=(lane, lane, lane),
                   check_rep=False)   # no replicated outputs, no collectives
    return jax.jit(fn)


@functools.lru_cache(maxsize=32)
def _sharded_wave(mesh, R: int, core: str):
    lane, rep = lane_spec(mesh), Pspec()
    fn = shard_map(functools.partial(_wave_eval_impl, R, core),
                   mesh=mesh,
                   in_specs=(lane, lane, lane, rep, rep),
                   out_specs=lane, check_rep=False)
    return jax.jit(fn)


@functools.lru_cache(maxsize=32)
def _sharded_route(mesh, R: int, core: str):
    lane, rep = lane_spec(mesh), Pspec()
    fn = shard_map(functools.partial(_route_eval_impl, R, core),
                   mesh=mesh,
                   in_specs=(lane, lane, lane, lane, rep),
                   out_specs=lane, check_rep=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# backend
# ---------------------------------------------------------------------------

class JaxBatchedBackend(SimBackend):
    """Campaign-scale batched engine (see module docstring).

    ``kernel`` selects the sequential event core (``"while_loop"`` /
    ``"pallas"`` / ``"auto"``); ``None`` resolves ``REPRO_EVENT_CORE`` at
    construction time (backends are process-wide singletons).

    ``data_parallel`` sets the campaign mesh's data extent (``None``
    resolves ``REPRO_DATA_PARALLEL``, defaulting to every local device):
    with more than one device the batched lane dimension of every core —
    ``run_batch`` / ``run_lockstep`` instances and what-if candidate rows —
    executes under ``shard_map``, bit-identical to the single-device path
    (lanes padded to the mesh extent, padding masked by ``count == 0``).

    ``async_dispatch`` (``None`` resolves ``REPRO_ASYNC_DISPATCH``, default
    on) double-buffers the host side: the ragged-to-padded packing of batch
    t+1 overlaps the device executing batch t.
    """

    name = "jax"

    def __init__(self, kernel: Optional[str] = None,
                 data_parallel: Optional[int] = None,
                 async_dispatch: Optional[bool] = None,
                 adaptive_reweight: Optional[bool] = None):
        self.event_core = resolve_event_core(kernel)
        if self.event_core != "while_loop":
            self.name = f"jax-{self.event_core}"
        self.data_parallel = resolve_data_parallel(data_parallel)
        self.mesh = (campaign_mesh(self.data_parallel)
                     if self.data_parallel > 1 else None)
        self.async_dispatch = resolve_async_dispatch(async_dispatch)
        # weighted adaptive surrogates under non-uniform PE speeds (the
        # two-pass scheme's second pass); "off" keeps the weights-at-1
        # recurrences for fidelity A/B comparisons
        self.adaptive_reweight = resolve_adaptive_reweight(adaptive_reweight)
        # (alg, N, P, cp) -> sizes ndarray, for central-queue algorithms
        self._sched_cache = _LRU(512)
        # StaticSteal replays keyed additionally by the cost/locality params
        self._steal_cache = _LRU(128)
        # profile-stack digest -> padded device-resident (Sp, G+1) grids
        self._grids_cache = _LRU(4)

    # ---- mesh dispatch -----------------------------------------------------

    @property
    def _shards(self) -> int:
        return 1 if self.mesh is None else lane_count(self.mesh)

    def _pad_rows(self, n: int) -> int:
        """Lane-axis padding: the power-of-two row bucket (compile-cache
        friendly), rounded up to a multiple of the mesh's data extent so
        ``shard_map`` splits it evenly."""
        rows = _pow2_rows(n)
        return pad_lanes(rows, self.mesh) if self.mesh is not None else rows

    def _events_call(self, P: int, *args):
        if self.mesh is None:
            return _batched_events(P, self.event_core, *args)
        return _sharded_events(self.mesh, P, self.event_core)(*args)

    def _wave_call(self, R: int, *args):
        if self.mesh is None:
            return _wave_eval(R, self.event_core, *args)
        return _sharded_wave(self.mesh, R, self.event_core)(*args)

    def _route_call(self, R: int, *args):
        if self.mesh is None:
            return _route_eval(R, self.event_core, *args)
        return _sharded_route(self.mesh, R, self.event_core)(*args)

    # ---- schedule precompute ---------------------------------------------

    def _central_schedule(self, alg: int, N: int, P: int, cp: int,
                          cache: bool = True) -> np.ndarray:
        key = (alg, N, P, cp)
        hit = self._sched_cache.get(key)
        if hit is not None:
            return hit
        guess = -(-N // max(1, cp)) if alg == 1 else 256
        mc = _next_bucket(min(guess, _K_BUCKETS[-1]))
        while True:
            sizes, count = chunk_schedule(alg, N, P, cp, max_chunks=mc)
            # slice host-side: eager jnp slicing compiles per output shape
            sizes = np.asarray(sizes, dtype=np.int64)[: int(count)]
            if sizes.sum() == N or mc >= _K_BUCKETS[-1]:
                break
            mc = _next_bucket(mc + 1)       # truncated: retry wider buffer
        if sizes.sum() != N:
            raise RuntimeError(
                f"schedule truncated: alg={alg} N={N} P={P} cp={cp}")
        if cache:
            self._sched_cache.put(key, sizes)
        return sizes

    def _steal_schedule(self, N: int, P: int, cp: int, profile, system,
                        cache: bool = True):
        unit = profile.total / N
        key = (N, P, cp, round(unit, 18), round(profile.locality_sens, 6),
               profile.c_loc, round(profile.memory_bound, 6), system.name)
        hit = self._steal_cache.get(key)
        if hit is not None:
            return hit
        ls = profile.locality_sens
        mc = _next_bucket(min(-(-N // max(1, cp)) + 8 * P * 34,
                              _K_BUCKETS[-1]))
        while True:
            starts, sizes, pes, own, count = staticsteal_schedule(
                N, P, cp, max_chunks=mc, unit=unit, h=system.h,
                bcost=profile.memory_bound * system.boundary_cost,
                base_infl=1.0 + ls * system.dyn_locality,
                amp=ls * system.loc_amp, c_loc=float(profile.c_loc))
            count = int(count)
            sizes_np = np.asarray(sizes, dtype=np.int64)[:count]
            if sizes_np.sum() == N or mc >= _K_BUCKETS[-1]:
                break
            mc = _next_bucket(mc + 1)
        if sizes_np.sum() != N:
            raise RuntimeError(f"steal schedule truncated: N={N} P={P}")
        out = (np.asarray(starts, np.int32)[:count],
               sizes_np.astype(np.int32),
               np.asarray(pes, np.int32)[:count],
               np.asarray(own)[:count])
        if cache:
            self._steal_cache.put(key, out)
        return out

    def _weighted_schedule(self, alg: int, N: int, P: int, cp: int,
                           scale: np.ndarray):
        """Weighted adaptive schedule under a non-uniform PE-speed vector
        (the two-pass re-estimation: weights are the converged mean-1
        inverse speeds).  Cached under a 5-tuple key — the clean 4-tuple
        ``(alg, N, P, cp)`` entries can never collide with it, so perturbed
        lanes never poison unperturbed ones (test-enforced)."""
        w = 1.0 / scale
        w *= P / w.sum()
        wkey = tuple(np.round(w, 9))
        key = (alg, N, P, cp, wkey)
        hit = self._sched_cache.get(key)
        if hit is None:
            hit = weighted_adaptive_schedule(alg, N, P, cp, w)
            self._sched_cache.put(key, hit)
        return hit

    def _event_rows(self, spec: InstanceSpec, profile, system):
        """(starts, sizes, loc, forced) numpy rows for one event instance."""
        N, P = profile.N, system.P
        ls = profile.locality_sens
        base_infl = 1.0 + ls * system.dyn_locality
        amp = ls * system.loc_amp
        c_loc = profile.c_loc
        if spec.alg == 5:
            starts, sizes, pes, own = self._steal_schedule(
                N, P, spec.chunk_param, profile, system)
            loc = np.where(own, 1.0,
                           base_infl + amp * c_loc / (sizes + c_loc))
            return starts, sizes, loc.astype(np.float32), pes
        scale = combined_pe_scale(system, spec.perturb)
        if (self.adaptive_reweight and spec.alg in ADAPTIVE_SET
                and scale is not None and not np.all(scale == 1.0)):
            sizes, pes = self._weighted_schedule(
                spec.alg, N, P, spec.chunk_param, scale)
            starts = np.concatenate(
                [[0], np.cumsum(sizes)[:-1]]).astype(np.int32)
            loc = (base_infl + amp * c_loc / (sizes + c_loc)).astype(
                np.float32)
            return starts, sizes.astype(np.int32), loc, pes
        sizes = self._central_schedule(spec.alg, N, P, spec.chunk_param)
        starts = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)
        loc = (base_infl + amp * c_loc / (sizes + c_loc)).astype(np.float32)
        return starts, sizes.astype(np.int32), loc, None

    def _grids_dev(self, profiles):
        """Device-resident padded grid stack, cached by profile content.

        The profile axis is padded to a power-of-two row bucket: a
        different number of (t, loop) rows must not recompile the jitted
        cores (padding rows are never gathered — grid_id only points at
        real profiles).  Caching keys on per-profile content digests, so
        lockstep replays that rebuild equal ``LoopProfile`` objects every
        time step still hit the same upload.
        """
        key = tuple(_profile_digest(p) for p in profiles)
        hit = self._grids_cache.get(key)
        if hit is not None:
            return hit
        grids = stack_prefix_grids(profiles)
        Sp = _pow2_rows(len(profiles))
        if Sp > len(profiles):
            grids = np.vstack([grids, np.zeros((Sp - len(profiles),
                                                grids.shape[1]), np.float32)])
        dev = jnp.asarray(grids)
        self._grids_cache.put(key, dev)
        return dev

    # ---- batch execution --------------------------------------------------

    def run_batch(self, profiles: Sequence, system,
                  specs: Sequence[InstanceSpec]) -> BatchResult:
        B = len(specs)
        lt = np.zeros(B)
        lib = np.zeros(B)
        nc = np.zeros(B, np.int64)
        event_ids: List[int] = []
        for i, s in enumerate(specs):
            profile = profiles[s.profile_id]
            if s.alg == 0 or needs_closed_form(s.alg, profile.N,
                                               s.chunk_param):
                rng = np.random.default_rng(s.seed)
                r = _py_run_instance(profile, system, s.alg, s.chunk_param,
                                     rng, perturb=s.perturb)
                lt[i], lib[i], nc[i] = r.loop_time, r.lib, r.n_chunks
            else:
                event_ids.append(i)
        if event_ids:
            mks, libs, _, counts = self._run_events(
                profiles, system, [specs[i] for i in event_ids])
            for j, i in enumerate(event_ids):
                lt[i], lib[i], nc[i] = mks[j], libs[j], counts[j]
        return BatchResult(loop_time=lt, lib=lib, n_chunks=nc)

    def _run_events(self, profiles, system, specs):
        """Evaluate event-loop instances; returns (mk, lib, finish, count)
        arrays in spec order."""
        P = system.P
        grids_dev = self._grids_dev(profiles)
        rows = [self._event_rows(s, profiles[s.profile_id], system)
                for s in specs]
        counts = np.array([len(r[1]) for r in rows], np.int32)
        B = len(specs)
        mk = np.zeros(B)
        lb = np.zeros(B)
        fin = np.zeros((B, P))

        # per-spec scalar lanes (gathered per bucket below)
        gid_all = np.fromiter((s.profile_id for s in specs), np.int32, B)
        inv_all = np.fromiter((1.0 / profiles[s.profile_id].N
                               for s in specs), np.float32, B)
        seed_all = np.fromiter((s.fold_seed() for s in specs), np.uint32, B)
        h_all = np.fromiter((_h_eff(system, s.alg) for s in specs),
                            np.float32, B)
        bc_all = np.fromiter(
            (profiles[s.profile_id].memory_bound * system.boundary_cost
             for s in specs), np.float32, B)
        # perturbation lanes: per-PE multipliers and sigma scales (rows stay
        # exactly 1.0 for clean lanes — IEEE-identity multiplies downstream)
        pm_all = np.ones((B, P), np.float32)
        ss_all = np.ones(B, np.float32)
        for i, s in enumerate(specs):
            scale = combined_pe_scale(system, s.perturb)
            if scale is not None:
                pm_all[i] = scale
            ss_all[i] = sigma_scale_of(s.perturb)

        by_bucket: Dict[int, List[int]] = {}
        for i, c in enumerate(counts):
            by_bucket.setdefault(_next_bucket(int(c)), []).append(i)

        def packed():
            """Host-side ragged-to-padded assembly, one yielded batch per
            dispatch.  A generator so the async loop below interleaves the
            packing of batch t+1 with the device executing batch t."""
            for K, ids in sorted(by_bucket.items()):
                # per-device row budget: a mesh holds shards x _MAX_ELEMS
                max_rows = max(8, (_MAX_ELEMS // K) * self._shards)
                for off in range(0, len(ids), max_rows):
                    sub = np.asarray(ids[off:off + max_rows])
                    n = len(sub)
                    Bp = self._pad_rows(n)
                    # ragged-to-padded assembly: one boolean scatter per
                    # field instead of per-row element-wise packing loops
                    lens = counts[sub]
                    mask = (np.arange(K, dtype=np.int32)[None, :]
                            < lens[:, None])
                    starts = np.zeros((Bp, K), np.int32)
                    sizes = np.zeros((Bp, K), np.int32)
                    loc = np.zeros((Bp, K), np.float32)
                    forced = np.full((Bp, K), -1, np.int32)
                    starts[:n][mask] = np.concatenate(
                        [rows[i][0] for i in sub])
                    sizes[:n][mask] = np.concatenate(
                        [rows[i][1] for i in sub])
                    loc[:n][mask] = np.concatenate([rows[i][2] for i in sub])
                    forced[:n][mask] = np.concatenate(
                        [rows[i][3] if rows[i][3] is not None
                         else np.full(lens[j], -1, np.int32)
                         for j, i in enumerate(sub)])
                    gid = np.zeros(Bp, np.int32)
                    inv_n = np.ones(Bp, np.float32)
                    cnt = np.zeros(Bp, np.int32)
                    seeds = np.zeros(Bp, np.uint32)
                    h_eff = np.zeros(Bp, np.float32)
                    bcost = np.zeros(Bp, np.float32)
                    pe_mult = np.ones((Bp, P), np.float32)
                    sscale = np.ones(Bp, np.float32)
                    gid[:n] = gid_all[sub]
                    inv_n[:n] = inv_all[sub]
                    cnt[:n] = lens
                    seeds[:n] = seed_all[sub]
                    h_eff[:n] = h_all[sub]
                    bcost[:n] = bc_all[sub]
                    pe_mult[:n] = pm_all[sub]
                    sscale[:n] = ss_all[sub]
                    yield sub, (gid, inv_n, starts, sizes, loc, cnt, forced,
                                seeds, h_eff, bcost, pe_mult, sscale)

        def drain(sub, res):
            n = len(sub)
            m, l, f = (np.asarray(x) for x in res)
            mk[sub], lb[sub], fin[sub] = m[:n], l[:n], f[:n]

        # double-buffered async dispatch: jax dispatch is asynchronous, so
        # holding exactly one in-flight batch lets the packing of batch t+1
        # (numpy, host) overlap the device executing batch t; draining after
        # the NEXT dispatch keeps one buffer's latency hidden.  Buffers are
        # donation-safe by construction: each dispatch packs fresh host
        # arrays, nothing aliases an in-flight device buffer (donation
        # itself stays rejected — see the note above the jitted cores).
        pending = None
        for sub, lanes in packed():
            res = self._events_call(
                P, grids_dev, *lanes,
                np.float32(system.noise_sigma), np.float32(system.jitter),
                np.float32(system.speed_spread))
            if not self.async_dispatch:
                drain(sub, res)
                continue
            if pending is not None:
                drain(*pending)
            pending = (sub, res)
        if pending is not None:
            drain(*pending)
        return mk, lb, fin, counts

    def run_lockstep(self, profiles: Sequence, system,
                     requests: Sequence[LockstepRequest]) -> BatchResult:
        """One lockstep replay step as a single batched device call.

        Per request the lane rng is consumed exactly like the sequential
        ``run_instance`` path would at the same stream position: STATIC and
        over-cap SS/StaticSteal instances run the reference closed forms on
        the lane rng directly, every event-loop instance draws one integer
        as its stateless fold seed.  All event instances across all lanes
        then execute as one ``_run_events`` batch — results are bit-identical
        to sequential JAX replays because each lane's noise depends only on
        its fold seed, never on batch order or size.
        """
        B = len(requests)
        lt = np.zeros(B)
        lib = np.zeros(B)
        nc = np.zeros(B, np.int64)
        event_ids: List[int] = []
        specs: List[InstanceSpec] = []
        for i, q in enumerate(requests):
            profile = profiles[q.profile_id]
            if q.alg == 0 or needs_closed_form(q.alg, profile.N,
                                               q.chunk_param):
                r = _py_run_instance(profile, system, q.alg, q.chunk_param,
                                     q.rng, perturb=q.perturb)
                lt[i], lib[i], nc[i] = r.loop_time, r.lib, r.n_chunks
            else:
                seed = (int(q.rng.integers(0, 2**31 - 1)),)
                specs.append(InstanceSpec(profile_id=q.profile_id, alg=q.alg,
                                          chunk_param=q.chunk_param,
                                          seed=seed, perturb=q.perturb))
                event_ids.append(i)
        if specs:
            mks, libs, _, counts = self._run_events(profiles, system, specs)
            for j, i in enumerate(event_ids):
                lt[i], lib[i], nc[i] = mks[j], libs[j], counts[j]
        return BatchResult(loop_time=lt, lib=lib, n_chunks=nc)

    # ---- single instance (selector path) ----------------------------------

    def run_instance(self, profile, system, alg: int, chunk_param: int,
                     rng, record_chunks: bool = False,
                     perturb: Optional[InstancePerturb] = None
                     ) -> InstanceResult:
        if alg == 0 or needs_closed_form(alg, profile.N, chunk_param):
            return _py_run_instance(profile, system, alg, chunk_param, rng,
                                    record_chunks, perturb)
        # stateless fold seed drawn from the caller's stream so repeated
        # calls stay reproducible AND distinct
        seed = (int(rng.integers(0, 2**31 - 1)),)
        spec = InstanceSpec(profile_id=0, alg=alg, chunk_param=chunk_param,
                            seed=seed, perturb=perturb)
        mk, lib, fin, counts = self._run_events([profile], system, [spec])
        sizes = None
        if record_chunks:
            _, sz, _, _ = self._event_rows(spec, profile, system)
            sizes = [int(c) for c in sz]
        return InstanceResult(loop_time=float(mk[0]), finish=fin[0],
                              n_chunks=int(counts[0]), chunk_sizes=sizes)

    # ---- serving what-if ---------------------------------------------------

    def what_if_wave(self, prefix: np.ndarray, n_replicas: int,
                     init_avail: np.ndarray, h: float, fixed: float,
                     algs: Sequence[int], chunk_param: int = 0
                     ) -> np.ndarray:
        N = len(prefix) - 1
        R = n_replicas
        out = np.zeros(len(algs))
        prefix = np.asarray(prefix, dtype=np.float64)
        batched: List[Tuple[int, np.ndarray, np.ndarray,
                            Optional[np.ndarray]]] = []
        for k, alg in enumerate(algs):
            if alg == 0 and chunk_param <= 0:
                bounds = np.linspace(0, N, R + 1).round().astype(int)
                free = np.asarray(init_avail, dtype=np.float64).copy()
                nonempty = np.diff(bounds) > 0
                free[: R] += np.diff(prefix[bounds]) + fixed * nonempty
                out[k] = free.max()
                continue
            # cache=False: wave sizes and mean costs drift per dispatch, so
            # online what-ifs would fill the process-wide caches with
            # never-reused entries
            if alg == 5:
                unit = float(prefix[-1] - prefix[0]) / max(N, 1)
                st, sz, pes, _ = self._steal_schedule(
                    N, R, chunk_param, _UniformStub(N, unit), _NoLocStub(),
                    cache=False)
                batched.append((k, st.astype(np.int64), sz, pes))
            else:
                sz = self._central_schedule(alg, N, R, chunk_param,
                                            cache=False)
                st = np.concatenate([[0], np.cumsum(sz)[:-1]])
                batched.append((k, st, sz.astype(np.int32), None))
        if batched:
            # per-chunk costs gathered from the float64 prefix host-side
            # (exact integer indexing): the float32 rounding then happens on
            # the small per-chunk values, not on the large cumulative totals.
            # Schedule slots are padded to a power-of-two bucket so online
            # what-ifs with drifting wave sizes never recompile _wave_eval.
            K = _pow2_rows(max(len(b[2]) for b in batched))
            A = len(batched)
            # candidate rows shard over the mesh's data axis: pad to its
            # extent with count==0 rows (masked, sliced off below)
            Ap = pad_lanes(A, self.mesh) if self.mesh is not None else A
            eff = np.zeros((Ap, K), np.float32)
            forced = np.full((Ap, K), -1, np.int32)
            cnt = np.zeros(Ap, np.int32)
            for j, (_, st, sz, pes) in enumerate(batched):
                n = len(sz)
                eff[j, :n] = prefix[st + sz] - prefix[st]
                cnt[j] = n
                if pes is not None:
                    forced[j, :n] = pes
            mks = np.asarray(self._wave_call(
                R, eff, cnt, forced,
                np.asarray(init_avail, np.float32), np.float32(h + fixed)))
            for j, (k, *_rest) in enumerate(batched):
                out[k] = mks[j]
        return out

    def what_if_routes(self, prefixes: Sequence[np.ndarray],
                       n_replicas: int,
                       init_avails: Sequence[np.ndarray], h: float,
                       fixed: float,
                       cands: Sequence[Tuple[int, int, int]]) -> np.ndarray:
        """Every (slot, alg, chunk) candidate row of a fleet routing
        decision in ONE ``_route_eval`` call — the rows differ in busy-state
        as well as schedule, so each carries its own (R,) offset vector.
        STATIC default-chunk rows take the float64 closed form host-side,
        exactly like :meth:`what_if_wave`."""
        R = n_replicas
        prefixes = [np.asarray(p, dtype=np.float64) for p in prefixes]
        avails = [np.asarray(a, dtype=np.float64) for a in init_avails]
        out = np.zeros(len(cands))
        batched: List[Tuple[int, int, np.ndarray, np.ndarray,
                            Optional[np.ndarray]]] = []
        for i, (slot, alg, cp) in enumerate(cands):
            prefix = prefixes[slot]
            N = len(prefix) - 1
            if N <= 0:
                out[i] = avails[slot].max() if len(avails[slot]) else 0.0
                continue
            if alg == 0 and cp <= 0:
                bounds = np.linspace(0, N, R + 1).round().astype(int)
                free = avails[slot].copy()
                nonempty = np.diff(bounds) > 0
                free[: R] += np.diff(prefix[bounds]) + fixed * nonempty
                out[i] = free.max()
                continue
            if alg == 5:
                # steal cache keys include the per-wave unit cost, so it
                # would never hit — skip it
                unit = float(prefix[-1] - prefix[0]) / max(N, 1)
                st, sz, pes, _ = self._steal_schedule(
                    N, R, cp, _UniformStub(N, unit), _NoLocStub(),
                    cache=False)
                batched.append((i, slot, st.astype(np.int64), sz, pes))
            else:
                # cache=True (unlike what_if_wave): a saturated fleet
                # dispatches quota-sized shards wave after wave, so the
                # (alg, N, P, cp) keys DO repeat; the LRU bound caps the
                # drifting-size tail
                sz = self._central_schedule(alg, N, R, cp)
                st = np.concatenate([[0], np.cumsum(sz)[:-1]])
                batched.append((i, slot, st, sz.astype(np.int32), None))
        if batched:
            K = _pow2_rows(max(len(b[3]) for b in batched))
            A = len(batched)
            Ap = pad_lanes(A, self.mesh) if self.mesh is not None else A
            eff = np.zeros((Ap, K), np.float32)
            forced = np.full((Ap, K), -1, np.int32)
            cnt = np.zeros(Ap, np.int32)
            av = np.zeros((Ap, R), np.float32)
            for j, (_, slot, st, sz, pes) in enumerate(batched):
                n = len(sz)
                prefix = prefixes[slot]
                eff[j, :n] = prefix[st + sz] - prefix[st]
                cnt[j] = n
                av[j] = avails[slot]
                if pes is not None:
                    forced[j, :n] = pes
            mks = np.asarray(self._route_call(
                R, eff, cnt, forced, av, np.float32(h + fixed)))
            for j, (i, *_rest) in enumerate(batched):
                out[i] = mks[j]
        return out


class _UniformStub:
    """Minimal profile stand-in for serving what-if StaticSteal replays."""

    def __init__(self, N, unit):
        self.N, self.unit = N, unit
        self.total = N * unit
        self.locality_sens = 0.0
        self.c_loc = 64
        self.memory_bound = 0.0


class _NoLocStub:
    name = "wave"
    h = 0.0
    boundary_cost = 0.0
    dyn_locality = 0.0
    loc_amp = 0.0
