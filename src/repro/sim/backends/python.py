"""Reference Python backend: discrete-event simulator of OpenMP self-scheduled
loop execution (moved verbatim from ``repro.sim.engine``; behavior-identical).

Reproduces the execution model of LB4OMP (paper §2): P threads arrive at a
parallel loop with small jitter, self-assign chunks from a central queue
(dynamic algorithms) or execute pre-assigned ranges (STATIC / StaticSteal),
pay a dispatch overhead ``h`` per work request, and — on memory-bound loops —
a locality penalty for dynamic assignment and per-chunk stream restarts.

Three execution paths:

* ``STATIC`` — closed form over pre-assigned (contiguous or round-robin)
  ranges; no dispatch events.
* constant-chunk closed form — SS / StaticSteal whose chunk floor would
  generate more than ``EVENT_CAP`` dispatch events (e.g. SS on STREAM's 2e9
  iterations: the paper's orders-of-magnitude blowup, computed analytically).
* event loop — everything else (GSS/TSS/AutoLLVM/mFAC2/AWF-*/mAF and small-N
  SS/StaticSteal): a heap of thread-available times; chunk sizes come from
  the live algorithm objects, adaptive ones receive per-chunk telemetry.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from ...core.portfolio import make_algorithm
from ...core.metrics import percent_load_imbalance
from .base import (EVENT_CAP, BatchResult, InstancePerturb, InstanceSpec,
                   SimBackend, combined_pe_scale, needs_closed_form,
                   sigma_scale_of)

H_ATOMIC_ADAPTIVE = 2.0      # h multiplier for atomic-path adaptive algs (C/E/mAF)
MUTEX_ADAPTIVE = {7, 9}      # AWF-B, AWF-D: mutex-protected weight updates


@dataclass
class InstanceResult:
    loop_time: float
    finish: np.ndarray
    n_chunks: int
    lib: float = field(init=False)
    chunk_sizes: Optional[List[int]] = None

    def __post_init__(self):
        self.lib = percent_load_imbalance(self.finish)


def _thread_speeds(system, rng, perturb=None) -> np.ndarray:
    """Per-PE execution-time multipliers: the stochastic spread draw (always
    consumed, so perturbed runs never shift the noise stream), times any
    heterogeneity / injected perturbation.  The clip applies only to the
    stochastic part — persistent slow PEs and failures must not be clipped
    back to 1.25x."""
    s = 1.0 + rng.normal(0.0, system.speed_spread, system.P)
    s = np.clip(s, 0.8, 1.25)
    scale = combined_pe_scale(system, perturb)
    if scale is not None:
        s = s * scale
    return s


def _noise(system, rng, n: int = 1):
    return np.exp(rng.normal(0.0, system.noise_sigma, n))


def _h_eff(system, alg_idx: int) -> float:
    if alg_idx in MUTEX_ADAPTIVE:
        return system.h * system.h_adaptive_mult
    if alg_idx in (8, 10, 11):          # AWF-C/E, mAF (atomic path)
        return system.h * H_ATOMIC_ADAPTIVE
    return system.h


def run_instance(profile, system, alg_idx: int,
                 chunk_param: int, rng, record_chunks: bool = False,
                 perturb: Optional[InstancePerturb] = None
                 ) -> InstanceResult:
    N = profile.N

    if alg_idx == 0:
        return _run_static(profile, system, chunk_param, rng, record_chunks,
                           perturb)

    if needs_closed_form(alg_idx, N, chunk_param):
        return _run_constant_closed(profile, system, alg_idx,
                                    max(1, chunk_param), rng, perturb)

    return _run_events(profile, system, alg_idx, chunk_param, rng,
                       record_chunks, perturb)


# ---------------------------------------------------------------------------
# STATIC: pre-assigned ranges, no dispatch events
# ---------------------------------------------------------------------------

def _run_static(profile, system, chunk_param, rng, record_chunks,
                perturb=None):
    P, N, mb = system.P, profile.N, profile.memory_bound
    jitter = rng.uniform(0.0, system.jitter, P)
    speed = _thread_speeds(system, rng, perturb)

    if chunk_param <= 0:
        # P contiguous ranges of ceil/floor(N/P)
        bounds = np.linspace(0, N, P + 1).round().astype(np.int64)
        cost = np.diff(profile.prefix(bounds))
        n_chunks = P
        per_pe_chunks = np.ones(P)
        sizes = np.diff(bounds).tolist() if record_chunks else None
    else:
        c = min(chunk_param, N)
        n_chunks = -(-N // c)
        if profile.uniform and n_chunks > 2_000_000:
            # analytic round-robin on a uniform profile
            base = np.full(P, profile.total / P)
            cost = base
            per_pe_chunks = np.full(P, n_chunks / P)
            sizes = None
        else:
            bounds = np.arange(0, N + c, c, dtype=np.int64)
            bounds[-1] = N
            chunk_cost = np.diff(profile.prefix(bounds))
            pe = np.arange(n_chunks) % P
            cost = np.bincount(pe, weights=chunk_cost, minlength=P)
            per_pe_chunks = np.bincount(pe, minlength=P).astype(np.float64)
            sizes = np.diff(bounds).tolist() if record_chunks else None
    # interleaved static chunks restart memory streams at every boundary and
    # lose within-window reuse when chunks are smaller than c_loc (no dynamic
    # first-touch loss though: the assignment repeats every time-step)
    if chunk_param > 0:
        infl = 1.0 + profile.locality_sens * system.loc_amp * (
            profile.c_loc / (chunk_param + profile.c_loc))
    else:
        infl = 1.0
    boundary = mb * system.boundary_cost * per_pe_chunks
    agg_noise = np.exp(rng.normal(
        0.0, system.noise_sigma * 0.5 * sigma_scale_of(perturb), P))
    finish = jitter + (cost * infl * speed * agg_noise) + boundary
    return InstanceResult(loop_time=float(finish.max()), finish=finish,
                          n_chunks=int(n_chunks), chunk_sizes=sizes)


# ---------------------------------------------------------------------------
# constant-chunk closed form (SS / StaticSteal with tiny chunks on huge N)
# ---------------------------------------------------------------------------

def _run_constant_closed(profile, system, alg_idx, c, rng, perturb=None):
    P, N, mb = system.P, profile.N, profile.memory_bound
    ls = profile.locality_sens
    n_chunks = -(-N // c)
    h = _h_eff(system, alg_idx)
    work = profile.total * system.chunk_inflation(ls, c, profile.c_loc)
    overhead_par = n_chunks * (h + mb * system.boundary_cost) / P
    if alg_idx == 1:
        # SS hits ONE central queue: beyond saturation the critical section
        # serializes and the dispatch cost stops dividing by P (the paper's
        # orders-of-magnitude blowup on STREAM).
        overhead = max(overhead_par, n_chunks * h * system.h_serial_frac)
    else:
        # StaticSteal: per-thread deques, no central serialization
        overhead = n_chunks * (h * 0.6 + mb * system.boundary_cost) / P
    # tiny-chunk self-scheduling rebalances perfectly, so heterogeneity /
    # perturbation enters as aggregate capacity (sum of PE rates), not as a
    # per-PE finish multiplier; uniform scales reduce to the exact work / P
    scale = combined_pe_scale(system, perturb)
    if scale is None:
        base = work / P + overhead
    else:
        base = work / float((1.0 / scale).sum()) + overhead
    jitter = rng.uniform(0.0, system.jitter, P)
    speed = _thread_speeds(system, rng)
    agg_noise = np.exp(rng.normal(
        0.0, system.noise_sigma * 0.3 * sigma_scale_of(perturb), P))
    # self-scheduling balances up to one chunk of spread
    tail = rng.uniform(0.0, 1.0, P) * (work / n_chunks + h)
    finish = jitter.mean() + base * speed * agg_noise + tail
    return InstanceResult(loop_time=float(finish.max()), finish=finish,
                          n_chunks=int(n_chunks))


# ---------------------------------------------------------------------------
# event loop
# ---------------------------------------------------------------------------

def _run_events(profile, system, alg_idx, chunk_param, rng, record_chunks,
                perturb=None):
    P, N, mb = system.P, profile.N, profile.memory_bound
    h = _h_eff(system, alg_idx)
    alg = make_algorithm(alg_idx)
    alg.reset(N, P, chunk_param)

    jitter = rng.uniform(0.0, system.jitter, P)
    speed = _thread_speeds(system, rng, perturb)
    finish = jitter.copy()

    heap = [(jitter[i], i) for i in range(P)]
    heapq.heapify(heap)

    steal_bounds = None
    steal_ranges = None
    if alg_idx == 5:   # StaticSteal needs iteration *identity* per PE
        bounds = np.linspace(0, N, P + 1).round().astype(np.int64)
        steal_bounds = bounds
        steal_ranges = [[int(bounds[i]), int(bounds[i + 1])] for i in range(P)]

    # fast scalar prefix lookup (avoids np.interp per-call overhead)
    if profile.uniform:
        unit = profile.unit

        def pref(x):
            return x * unit
    else:
        grid = profile.prefix_grid
        gscale = len(grid[:-1]) / N    # GRID / N

        def pref(x):
            pos = x * gscale
            i = int(pos)
            if i >= len(grid) - 1:
                return float(grid[-1])
            lo = grid[i]
            return float(lo + (pos - i) * (grid[i + 1] - lo))

    # pre-drawn lognormal noise (scalar Generator calls are ~3us each)
    sigma = system.noise_sigma * sigma_scale_of(perturb)
    noise_buf = np.exp(rng.normal(0.0, sigma, 4096))
    noise_i = 0

    cursor = 0
    events = 0
    ls = profile.locality_sens
    base_infl = 1.0 + ls * system.dyn_locality
    amp = ls * system.loc_amp
    c_loc = profile.c_loc
    bcost = mb * system.boundary_cost
    sizes: Optional[List[int]] = [] if record_chunks else None
    pop, push = heapq.heappop, heapq.heappush

    while alg.remaining > 0:
        t, pe = pop(heap)
        if alg_idx == 5:
            c, a, b = _steal_next(alg, steal_ranges, pe)
            if c == 0:
                continue
            own_range = steal_bounds[pe] <= a < steal_bounds[pe + 1]
            loc = 1.0 if own_range else (base_infl + amp * c_loc / (c + c_loc))
        else:
            c = alg.next_chunk(pe)
            if c == 0:
                break
            a, b = cursor, cursor + c
            cursor += c
            loc = base_infl + amp * c_loc / (c + c_loc)
        raw = pref(b) - pref(a)
        if noise_i >= 4096:
            noise_buf = np.exp(rng.normal(0.0, sigma, 4096))
            noise_i = 0
        exec_t = raw * loc * speed[pe] * noise_buf[noise_i] + bcost
        noise_i += 1
        alg.report(pe, c, exec_t, exec_t + h)
        t_new = t + h + exec_t
        finish[pe] = t_new
        push(heap, (t_new, pe))
        if sizes is not None:
            sizes.append(c)
        events += 1
        if events > EVENT_CAP * 4:
            raise RuntimeError(
                f"event cap exceeded: alg={alg_idx} N={N} P={P} "
                f"chunk_param={chunk_param}")

    return InstanceResult(loop_time=float(finish.max()), finish=finish,
                          n_chunks=events, chunk_sizes=sizes)


def _steal_next(alg, ranges, pe):
    """Range-aware StaticSteal: serve own range in quanta; steal the richer
    half of the richest victim when empty.  Keeps ``alg`` bookkeeping in sync
    so ``alg.remaining`` stays authoritative."""
    q = max(1, alg.chunk_param)
    lo, hi = ranges[pe]
    if lo >= hi:
        victim = max(range(alg.P), key=lambda i: ranges[i][1] - ranges[i][0])
        vl, vh = ranges[victim]
        if vh - vl <= 0:
            return 0, 0, 0
        half = (vh - vl + 1) // 2
        ranges[victim][1] = vh - half      # victim keeps the front
        ranges[pe] = [vh - half, vh]       # thief takes the back half
        lo, hi = ranges[pe]
    c = min(q, hi - lo)
    ranges[pe][0] = lo + c
    alg.remaining -= c
    alg.scheduled += c
    return c, lo, lo + c


# ---------------------------------------------------------------------------
# backend wrapper
# ---------------------------------------------------------------------------

class PythonBackend(SimBackend):
    """The reference engine behind the ``SimBackend`` protocol."""

    name = "python"

    def run_instance(self, profile, system, alg: int, chunk_param: int,
                     rng, record_chunks: bool = False,
                     perturb: Optional[InstancePerturb] = None
                     ) -> InstanceResult:
        return run_instance(profile, system, alg, chunk_param, rng,
                            record_chunks, perturb)

    def run_batch(self, profiles: Sequence, system,
                  specs: Sequence[InstanceSpec]) -> BatchResult:
        B = len(specs)
        lt = np.zeros(B)
        lib = np.zeros(B)
        nc = np.zeros(B, np.int64)
        for i, s in enumerate(specs):
            rng = np.random.default_rng(s.seed)
            r = run_instance(profiles[s.profile_id], system, s.alg,
                             s.chunk_param, rng, perturb=s.perturb)
            lt[i], lib[i], nc[i] = r.loop_time, r.lib, r.n_chunks
        return BatchResult(loop_time=lt, lib=lib, n_chunks=nc)

    def what_if_wave(self, prefix: np.ndarray, n_replicas: int,
                     init_avail: np.ndarray, h: float, fixed: float,
                     algs: Sequence[int], chunk_param: int = 0
                     ) -> np.ndarray:
        """Greedy host replay of the serving dispatch loop per candidate —
        mirrors ``DispatchSimulator.run_wave`` (adaptive algorithms run their
        real telemetry-driven host classes here)."""
        N = len(prefix) - 1
        R = n_replicas
        out = np.zeros(len(algs))
        for k, alg_idx in enumerate(algs):
            free = np.asarray(init_avail, dtype=np.float64).copy()
            if alg_idx == 0 and chunk_param <= 0:
                bounds = np.linspace(0, N, R + 1).round().astype(int)
                for r in range(R):
                    if bounds[r + 1] > bounds[r]:
                        free[r] += fixed + prefix[bounds[r + 1]] \
                            - prefix[bounds[r]]
            else:
                alg = make_algorithm(alg_idx)
                alg.reset(N, R, chunk_param)
                cursor = 0
                while alg.remaining > 0:
                    r = int(np.argmin(free))
                    c = alg.next_chunk(r)
                    if c <= 0:
                        break
                    dt = fixed + float(prefix[cursor + c] - prefix[cursor])
                    cursor += c
                    alg.report(r, c, dt, dt + h)
                    free[r] += h + dt
            out[k] = free.max()
        return out
