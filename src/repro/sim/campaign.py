"""Factorial experiment campaign (paper §4.1, Table 2).

Drives the DES over {applications} x {systems} x {scheduling algorithms |
selection methods} x {chunk parameter: default | expChunk} x {RL reward: LT |
LIB}, computes the Oracle (per-loop, per-time-step best over all algorithm x
chunk combinations) and the performance-degradation table of Fig. 5, the
c.o.v. of Fig. 4, and the selection traces of Figs. 7-8.
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


def _digest(label: str) -> int:
    """Stable 16-bit label digest for rng seed tuples — ``hash()`` is salted
    per process for strings, which made campaign noise irreproducible."""
    return zlib.crc32(label.encode("utf-8")) & 0xFFFF

from ..core import (ALGORITHM_NAMES, N_ALGORITHMS, SelectionService,
                    coefficient_of_variation, exp_chunk)
from .engine import run_instance
from .systems import SYSTEMS, SystemModel, get_system
from .workloads import APPLICATIONS, Application, get_application

CHUNK_MODES = ("default", "expChunk")


def chunk_param_for(mode: str, N: int, P: int) -> int:
    if mode == "default":
        return 0
    if mode == "expChunk":
        return exp_chunk(N, P)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# fixed-algorithm runs (portfolio sweep → Oracle, c.o.v.)
# ---------------------------------------------------------------------------

@dataclass
class FixedRun:
    """Median per-time-step loop times for one (alg, chunk_mode)."""
    times: np.ndarray          # (T, n_loops) medians over reps
    libs: np.ndarray           # (T, n_loops)

    @property
    def total(self) -> float:
        return float(self.times.sum())


def run_fixed(app: Application, system: SystemModel, alg: int,
              chunk_mode: str, T: Optional[int] = None, reps: int = 3,
              seed: int = 0) -> FixedRun:
    T = T or app.T
    # time-invariant apps: simulate a window and tile (median statistics are
    # identical across steps; saves orders of magnitude of DES time)
    T_sim = min(T, 24) if app.time_invariant else T
    n_loops = len(app.loop_names)
    times = np.zeros((T_sim, n_loops))
    libs = np.zeros((T_sim, n_loops))
    for t in range(T_sim):
        for li, profile in enumerate(app.loops(t)):
            cp = chunk_param_for(chunk_mode, profile.N, system.P)
            samples = []
            for r in range(reps):
                rng = np.random.default_rng(
                    (seed, _digest(app.name), system.P, alg,
                     _digest(chunk_mode), t, r))
                res = run_instance(profile, system, alg, cp, rng)
                samples.append((res.loop_time, res.lib))
            lt = float(np.median([s[0] for s in samples]))
            lb = float(np.median([s[1] for s in samples]))
            times[t, li], libs[t, li] = lt, lb
    if T_sim < T:
        reps_needed = -(-T // T_sim)
        times = np.tile(times, (reps_needed, 1))[:T]
        libs = np.tile(libs, (reps_needed, 1))[:T]
    return FixedRun(times=times, libs=libs)


@dataclass
class PortfolioSweep:
    """All 12 algorithms x 2 chunk modes for one app-system pair."""
    app: str
    system: str
    runs: Dict[Tuple[int, str], FixedRun]

    def oracle_times(self) -> np.ndarray:
        """(T, n_loops) per-loop per-step best over the whole sweep (§3.3)."""
        stack = np.stack([r.times for r in self.runs.values()])
        return stack.min(axis=0)

    def oracle_total(self) -> float:
        return float(self.oracle_times().sum())

    def oracle_best_fn(self, loop_index: int = 0):
        """Per-step best algorithm index (default chunk-mode-agnostic)."""
        keys = list(self.runs.keys())
        stack = np.stack([self.runs[k].times[:, loop_index] for k in keys])
        arg = stack.argmin(axis=0)
        return lambda t: keys[arg[min(t, len(arg) - 1)]][0]

    def cov(self) -> float:
        """Fig. 4: c.o.v. of loop execution time over every algorithm and
        chunk parameter."""
        totals = np.array([r.total for r in self.runs.values()])
        return coefficient_of_variation(totals)


def sweep_portfolio(app_name: str, system_name: str, T: Optional[int] = None,
                    reps: int = 3, seed: int = 0) -> PortfolioSweep:
    app = get_application(app_name)
    system = get_system(system_name)
    runs = {}
    for alg in range(N_ALGORITHMS):
        for mode in CHUNK_MODES:
            runs[(alg, mode)] = run_fixed(app, system, alg, mode, T=T,
                                          reps=reps, seed=seed)
    return PortfolioSweep(app=app_name, system=system_name, runs=runs)


# ---------------------------------------------------------------------------
# selector runs
# ---------------------------------------------------------------------------

@dataclass
class SelectorRun:
    selector: str
    chunk_mode: str
    reward: Optional[str]
    total: float
    #: per loop name: list of (chosen alg, loop_time, lib) per time-step
    history: Dict[str, List[Tuple[int, float, float]]]

    def selection_shares(self, loop: Optional[str] = None) -> Dict[str, float]:
        """Fig. 7/8 pie charts: fraction of instances per selected algorithm."""
        hists = ([self.history[loop]] if loop else list(self.history.values()))
        counts = np.zeros(N_ALGORITHMS)
        for h in hists:
            for a, _, _ in h:
                counts[a] += 1
        tot = counts.sum() or 1.0
        return {ALGORITHM_NAMES[i]: counts[i] / tot
                for i in range(N_ALGORITHMS) if counts[i] > 0}


def run_selector(app_name: str, system_name: str, selector: str,
                 chunk_mode: str = "default", reward: Optional[str] = None,
                 T: Optional[int] = None, seed: int = 0,
                 sweep: Optional[PortfolioSweep] = None) -> SelectorRun:
    """Execute one selection method over the full time-stepped application.

    Every modified loop gets an independent policy via ``SelectionService``
    (LB4OMP loop ids); ``selector`` is any ``make_policy`` name, including
    "Hybrid" (expert-seeded RL) and "Oracle" (per-loop overrides carrying
    the per-step best; ``sweep`` is required for it)."""
    app = get_application(app_name)
    system = get_system(system_name)
    T = T or app.T

    if selector.lower() == "oracle":
        assert sweep is not None, "Oracle needs a portfolio sweep"
        service = SelectionService("Oracle", overrides={
            nm: {"best_fn": sweep.oracle_best_fn(li)}
            for li, nm in enumerate(app.loop_names)})
    else:
        service = SelectionService(selector, reward=reward, seed=seed)

    rng = np.random.default_rng((seed, _digest(app_name), system.P,
                                 _digest(selector), _digest(chunk_mode)))
    total = 0.0
    for t in range(T):
        for li, profile in enumerate(app.loops(t)):
            nm = app.loop_names[li]
            with service.instance(nm) as inst:
                # a policy may steer the chunk parameter; the campaign's
                # chunk mode fills the default
                d = inst.decision.with_instance_defaults(
                    chunk_param_for(chunk_mode, profile.N, system.P))
                res = run_instance(profile, system, d.action, d.chunk_param,
                                   rng)
                inst.report(loop_time=res.loop_time, lib=res.lib)
            total += res.loop_time
    # the service's per-region records ARE the selection traces
    history = {nm: list(service.history(nm)) for nm in app.loop_names}
    return SelectorRun(selector=selector, chunk_mode=chunk_mode,
                       reward=reward, total=total, history=history)


# ---------------------------------------------------------------------------
# the full factorial campaign (Fig. 5)
# ---------------------------------------------------------------------------

SELECTOR_GRID: List[Tuple[str, Optional[str]]] = [
    ("RandomSel", None), ("ExhaustiveSel", None), ("ExpertSel", None),
    ("QLearn", "LT"), ("QLearn", "LIB"), ("SARSA", "LT"), ("SARSA", "LIB"),
]

#: the paper grid plus the §6 expert-seeded RL combination
EXTENDED_SELECTOR_GRID: List[Tuple[str, Optional[str]]] = \
    SELECTOR_GRID + [("Hybrid", "LT"), ("Hybrid", "LT+LIB")]


@dataclass
class CampaignResult:
    app: str
    system: str
    sweep: PortfolioSweep
    oracle_total: float
    selector_runs: Dict[Tuple[str, str, Optional[str]], SelectorRun]

    def degradation(self) -> Dict[Tuple[str, str, Optional[str]], float]:
        """Fig. 5 cells: (T_method - T_oracle) / T_oracle * 100."""
        return {k: (r.total - self.oracle_total) / self.oracle_total * 100.0
                for k, r in self.selector_runs.items()}


def run_campaign_cell(app_name: str, system_name: str,
                      T: Optional[int] = None, reps: int = 3,
                      seed: int = 0,
                      selectors=SELECTOR_GRID,
                      chunk_modes=CHUNK_MODES) -> CampaignResult:
    sweep = sweep_portfolio(app_name, system_name, T=T, reps=reps, seed=seed)
    T_eff = T or get_application(app_name).T
    runs = {}
    for mode in chunk_modes:
        for sel, reward in selectors:
            runs[(sel, mode, reward)] = run_selector(
                app_name, system_name, sel, chunk_mode=mode, reward=reward,
                T=T_eff, seed=seed, sweep=sweep)
    oracle_total = float(sweep.oracle_times()[:T_eff].sum())
    return CampaignResult(app=app_name, system=system_name, sweep=sweep,
                          oracle_total=oracle_total, selector_runs=runs)
