"""Factorial experiment campaign (paper §4.1, Table 2).

Drives the DES over {applications} x {systems} x {scheduling algorithms |
selection methods} x {chunk parameter: default | expChunk} x {RL reward: LT |
LIB}, computes the Oracle (per-loop, per-time-step best over all algorithm x
chunk combinations) and the performance-degradation table of Fig. 5, the
c.o.v. of Fig. 4, and the selection traces of Figs. 7-8.

Two batched layers put the whole campaign on the active ``SimBackend``:

* the fixed-algorithm portfolio sweep fans (alg x chunk-mode x rep x
  time-step x loop) into ``run_batch`` (PR 2);
* the selector replays — sequential across time steps by nature — run in
  *lockstep across cells* through :class:`ReplayBatch`: a per-step
  decide / execute / learn cycle where every lane's loop execution for step
  ``t`` is one ``run_lockstep`` call per machine model.

On a multi-device host the JAX backend shards the lane axis of both layers
over the ``data`` axis of a host mesh and double-buffers host packing
against device compute (``data_parallel=`` / ``REPRO_DATA_PARALLEL``,
``async_dispatch=`` / ``REPRO_ASYNC_DISPATCH`` on
:class:`~repro.sim.backends.jax_batched.JaxBatchedBackend`) —
bit-identical to the single-device path, so nothing in this module
changes: campaign lanes scale out through the backend alone.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


from ..core import (ALGORITHM_NAMES, N_ALGORITHMS, SelectionService,
                    coefficient_of_variation, exp_chunk, is_learned_policy,
                    is_sim_policy)
from ..core.api import Observation
from ..core.learned import LoopFeaturizer
from ..core.simpolicy import _SIM_ALIASES
from .backends import (InstancePerturb, InstanceSpec, LockstepRequest,
                       get_backend)
from .perturb import PerturbationSpec
from .whatif import LoopWhatIf
from .systems import SystemModel, get_system
from .workloads import Application, get_application

CHUNK_MODES = ("default", "expChunk")


def _digest(label: str) -> int:
    """Stable 16-bit label digest for rng seed tuples — ``hash()`` is salted
    per process for strings, which made campaign noise irreproducible."""
    return zlib.crc32(label.encode("utf-8")) & 0xFFFF


def _lane_digest(selector: str, reward: Optional[str]) -> int:
    """Selector digest for a replay lane's rng seed tuple.

    The reward objective is part of the lane identity: ``_digest(selector)``
    alone made QLearn+LT and QLearn+LIB share one noise stream, which
    batching surfaced as perfectly correlated lanes inside a lockstep step.
    Reward-less selectors keep the bare-selector digest, so their historical
    seed tuples (and Figs. 7-8 traces) are unchanged."""
    return _digest(selector if reward is None else f"{selector}+{reward}")


def chunk_param_for(mode: str, N: int, P: int) -> int:
    if mode == "default":
        return 0
    if mode == "expChunk":
        return exp_chunk(N, P)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# fixed-algorithm runs (portfolio sweep → Oracle, c.o.v.)
# ---------------------------------------------------------------------------

@dataclass
class FixedRun:
    """Median per-time-step loop times for one (alg, chunk_mode)."""
    times: np.ndarray          # (T, n_loops) medians over reps
    libs: np.ndarray           # (T, n_loops)

    @property
    def total(self) -> float:
        return float(self.times.sum())


def _run_portfolio(app: Application, system: SystemModel,
                   pairs: List[Tuple[int, str]], T: int, reps: int,
                   seed: int, backend=None) -> Dict[Tuple[int, str],
                                                    "FixedRun"]:
    """Evaluate every (alg, chunk_mode) pair over the app's time-stepped
    loops through ONE backend batch (the campaign fan-out: alg x mode x
    time-step x loop x rep).  Seed tuples are the historical per-instance
    rng labels, so the Python backend reproduces ``run_fixed`` bit-exactly
    and the JAX backend folds the same tuples into its stateless streams."""
    bk = get_backend(backend)
    # time-invariant apps: simulate a window and tile (median statistics are
    # identical across steps; saves orders of magnitude of DES time)
    T_sim = min(T, 24) if app.time_invariant else T
    stack = app.profile_stack(T_sim)
    n_loops = stack.n_loops
    specs: List[InstanceSpec] = []
    for alg, mode in pairs:
        for t in range(T_sim):
            for li in range(n_loops):
                pid = stack.pid(t, li)
                cp = chunk_param_for(mode, stack.profiles[pid].N, system.P)
                for r in range(reps):
                    specs.append(InstanceSpec(
                        profile_id=pid, alg=alg, chunk_param=cp,
                        seed=(seed, _digest(app.name), system.P, alg,
                              _digest(mode), t, r)))
    res = bk.run_batch(stack.profiles, system, specs)
    lt = res.loop_time.reshape(len(pairs), T_sim, n_loops, reps)
    lb = res.lib.reshape(len(pairs), T_sim, n_loops, reps)
    out = {}
    for i, pair in enumerate(pairs):
        times = np.median(lt[i], axis=-1)
        libs = np.median(lb[i], axis=-1)
        if T_sim < T:
            reps_needed = -(-T // T_sim)
            times = np.tile(times, (reps_needed, 1))[:T]
            libs = np.tile(libs, (reps_needed, 1))[:T]
        out[pair] = FixedRun(times=times, libs=libs)
    return out


def run_fixed(app: Application, system: SystemModel, alg: int,
              chunk_mode: str, T: Optional[int] = None, reps: int = 3,
              seed: int = 0, backend=None) -> FixedRun:
    T = T or app.T
    return _run_portfolio(app, system, [(alg, chunk_mode)], T, reps, seed,
                          backend=backend)[(alg, chunk_mode)]


@dataclass
class PortfolioSweep:
    """All 12 algorithms x 2 chunk modes for one app-system pair."""
    app: str
    system: str
    runs: Dict[Tuple[int, str], FixedRun]

    def oracle_times(self) -> np.ndarray:
        """(T, n_loops) per-loop per-step best over the whole sweep (§3.3)."""
        stack = np.stack([r.times for r in self.runs.values()])
        return stack.min(axis=0)

    def oracle_total(self) -> float:
        return float(self.oracle_times().sum())

    def oracle_best_fn(self, loop_index: int = 0):
        """Per-step best algorithm index (default chunk-mode-agnostic)."""
        keys = list(self.runs.keys())
        stack = np.stack([self.runs[k].times[:, loop_index] for k in keys])
        arg = stack.argmin(axis=0)
        return lambda t: keys[arg[min(t, len(arg) - 1)]][0]

    def oracle_argmin(self) -> np.ndarray:
        """(T, n_loops) index into ``sorted run keys`` of the per-instance
        winner — the Oracle's selection trace (backend-equivalence tests
        compare these across engines)."""
        keys = sorted(self.runs.keys(), key=str)
        stack = np.stack([self.runs[k].times for k in keys])
        return stack.argmin(axis=0)

    def cov(self) -> float:
        """Fig. 4: c.o.v. of loop execution time over every algorithm and
        chunk parameter."""
        totals = np.array([r.total for r in self.runs.values()])
        return coefficient_of_variation(totals)


def sweep_portfolio(app_name: str, system_name: str, T: Optional[int] = None,
                    reps: int = 3, seed: int = 0,
                    backend=None) -> PortfolioSweep:
    """All 12 algorithms x 2 chunk modes, fanned into a single backend
    batch (with ``backend="jax"`` the whole sweep is a handful of jitted
    vmapped calls instead of tens of thousands of Python event loops)."""
    app = get_application(app_name)
    system = get_system(system_name)
    T_eff = T or app.T
    pairs = [(alg, mode) for alg in range(N_ALGORITHMS)
             for mode in CHUNK_MODES]
    runs = _run_portfolio(app, system, pairs, T_eff, reps, seed,
                          backend=backend)
    return PortfolioSweep(app=app_name, system=system_name, runs=runs)


# ---------------------------------------------------------------------------
# selector runs
# ---------------------------------------------------------------------------

@dataclass
class SelectorRun:
    selector: str
    chunk_mode: str
    reward: Optional[str]
    total: float
    #: per loop name: list of (chosen alg, loop_time, lib) per time-step
    history: Dict[str, List[Tuple[int, float, float]]]
    #: the live service that produced the run (per-loop policies, Q-tables);
    #: introspection only — equality and repr ignore it
    service: Optional[SelectionService] = field(default=None, repr=False,
                                                compare=False)

    def selection_shares(self, loop: Optional[str] = None) -> Dict[str, float]:
        """Fig. 7/8 pie charts: fraction of instances per selected algorithm."""
        hists = ([self.history[loop]] if loop else list(self.history.values()))
        counts = np.zeros(N_ALGORITHMS)
        for h in hists:
            for a, _, _ in h:
                counts[a] += 1
        tot = counts.sum() or 1.0
        return {ALGORITHM_NAMES[i]: counts[i] / tot
                for i in range(N_ALGORITHMS) if counts[i] > 0}


def _lane_service(app: Application, selector: str, reward: Optional[str],
                  seed: int, sweep: Optional[PortfolioSweep],
                  system: Optional[SystemModel] = None,
                  sim_backend=None, horizon: Optional[int] = None
                  ) -> Tuple[SelectionService, Optional[object]]:
    """Per-lane service: one independent policy per modified loop (LB4OMP
    loop ids).  Oracle lanes carry per-loop overrides with the per-step
    best from the portfolio sweep.  Simulation-assisted lanes (SimPolicy /
    SimHybrid) additionally get a :class:`LoopWhatIf` candidate pricer on
    ``sim_backend``, learned lanes a :class:`LoopFeaturizer` — both share
    the ``set_context`` surface and are returned so the replay can bind
    the current loop context before each decision."""
    if selector.lower() == "oracle":
        assert sweep is not None, "Oracle needs a portfolio sweep"
        return SelectionService("Oracle", overrides={
            nm: {"best_fn": sweep.oracle_best_fn(li)}
            for li, nm in enumerate(app.loop_names)}), None
    if is_sim_policy(selector):
        assert system is not None, "sim-assisted lanes need a machine model"
        # AwareSim lanes price through the two-pass adaptive surrogate
        # (clean pass → weight re-estimation → perturbed pass)
        two_pass = _SIM_ALIASES.get(selector.lower()) == "AwareSim"
        whatif = LoopWhatIf(system, backend=sim_backend, two_pass=two_pass)
        return SelectionService(selector, reward=reward, seed=seed,
                                simulator=whatif), whatif
    if is_learned_policy(selector):
        # learned lanes bind decision context through a LoopFeaturizer —
        # the same set_context surface as a what-if pricer, so the replay
        # drives both through the lane's ``whatif`` slot
        assert system is not None, "learned lanes need a machine model"
        fz = LoopFeaturizer(system)
        # the policy's phase feature must mean the same thing it meant in
        # the training logs (t / lane T), so the lane horizon rides along
        hkw = {} if horizon is None else {"horizon": horizon}
        return SelectionService(selector, reward=reward, seed=seed,
                                featurizer=fz, **hkw), fz
    return SelectionService(selector, reward=reward, seed=seed), None


def _lane_rng(app_name: str, system: SystemModel, selector: str,
              chunk_mode: str, reward: Optional[str],
              seed: int) -> np.random.Generator:
    """The lane's noise stream, folded from the historical crc32 label
    tuple (see ``_lane_digest`` for the reward term)."""
    return np.random.default_rng((seed, _digest(app_name), system.P,
                                  _lane_digest(selector, reward),
                                  _digest(chunk_mode)))


def run_selector_sequential(app_name: str, system_name: str, selector: str,
                            chunk_mode: str = "default",
                            reward: Optional[str] = None,
                            T: Optional[int] = None, seed: int = 0,
                            sweep: Optional[PortfolioSweep] = None,
                            backend=None, sim_backend=None,
                            perturb: Optional[PerturbationSpec] = None
                            ) -> SelectorRun:
    """Reference replay: one cell, one instance at a time.

    This is the historical ``run_selector`` loop, kept as the
    bit-exactness oracle for the lockstep engine (``tests/test_replay.py``)
    and as the baseline ``benchmarks/bench_replay.py`` measures against.
    ``run_selector`` itself now routes through :class:`ReplayBatch` and must
    reproduce this loop exactly on the Python backend."""
    bk = get_backend(backend)
    app = get_application(app_name)
    system = get_system(system_name)
    T = T or app.T

    if sim_backend is None:
        sim_backend = backend
    service, whatif = _lane_service(app, selector, reward, seed, sweep,
                                    system=system, sim_backend=sim_backend,
                                    horizon=T)
    rng = _lane_rng(app_name, system, selector, chunk_mode, reward, seed)
    total = 0.0
    for t in range(T):
        ip = None if perturb is None else perturb.instance_perturb(t,
                                                                   system.P)
        loops = app.loops(t) if perturb is None else perturb.loops(app, t)
        for li, profile in enumerate(loops):
            nm = app.loop_names[li]
            cp = chunk_param_for(chunk_mode, profile.N, system.P)
            if whatif is not None:      # bind the loop the decision is about
                whatif.set_context(profile, cp, perturb=ip)
            with service.instance(nm) as inst:
                # a policy may steer the chunk parameter; the campaign's
                # chunk mode fills the default
                d = inst.decision.with_instance_defaults(cp)
                res = bk.run_instance(profile, system, d.action,
                                      d.chunk_param, rng, perturb=ip)
                inst.report(loop_time=res.loop_time, lib=res.lib)
            total += res.loop_time
    # the service's per-region records ARE the selection traces
    history = {nm: list(service.history(nm)) for nm in app.loop_names}
    return SelectorRun(selector=selector, chunk_mode=chunk_mode,
                       reward=reward, total=total, history=history,
                       service=service)


# ---------------------------------------------------------------------------
# lockstep multi-cell replay (the batched Fig. 5 engine)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CellSpec:
    """One replay lane of the factorial campaign: which application on which
    system, driven by which selection method.  ``perturb`` makes the lane
    non-stationary (``repro.sim.perturb``); it is deliberately NOT part of
    the lane's rng identity, so a perturbed lane consumes the exact noise
    stream of its clean twin (paired comparisons by construction)."""

    app: str
    system: str
    selector: str
    chunk_mode: str = "default"
    reward: Optional[str] = None
    perturb: Optional[PerturbationSpec] = None

    @property
    def key(self) -> Tuple[str, str, Optional[str]]:
        """The (selector, chunk_mode, reward) key Fig. 5 tables use."""
        return (self.selector, self.chunk_mode, self.reward)


class _Lane:
    """Live state of one replay lane: its service (per-loop policies), its
    private noise stream, and the running total."""

    __slots__ = ("spec", "app", "system", "T", "service", "whatif", "rng",
                 "total", "_ip_cache")

    def __init__(self, spec: CellSpec, app: Application, system: SystemModel,
                 T: int, seed: int, sweep: Optional[PortfolioSweep],
                 sim_backend=None):
        self.spec = spec
        self.app = app
        self.system = system
        self.T = T
        self.service, self.whatif = _lane_service(
            app, spec.selector, spec.reward, seed, sweep, system=system,
            sim_backend=sim_backend, horizon=T)
        self.rng = _lane_rng(spec.app, system, spec.selector,
                             spec.chunk_mode, spec.reward, seed)
        self.total = 0.0
        self._ip_cache: Dict[int, Optional[InstancePerturb]] = {}

    def perturb_at(self, t: int) -> Optional[InstancePerturb]:
        """The lane's resolved execution-side perturbation at step ``t``
        (memoized — every loop of the step shares one resolution)."""
        if self.spec.perturb is None:
            return None
        ip = self._ip_cache.get(t, False)
        if ip is False:
            ip = self.spec.perturb.instance_perturb(t, self.system.P)
            self._ip_cache.clear()      # only the current step is ever hot
            self._ip_cache[t] = ip
        return ip

    def result(self) -> SelectorRun:
        history = {nm: list(self.service.history(nm))
                   for nm in self.app.loop_names}
        return SelectorRun(selector=self.spec.selector,
                           chunk_mode=self.spec.chunk_mode,
                           reward=self.spec.reward, total=self.total,
                           history=history, service=self.service)


class _StepGroup:
    """Per-system accumulator for one lockstep step: the shared profile
    list (lanes on the same application share rows) plus the request and
    pending-instance queues, in lane order."""

    def __init__(self, system: SystemModel):
        self.system = system
        self.profiles: List = []
        self._pids: Dict[Tuple, List[int]] = {}
        self.requests: List[LockstepRequest] = []
        self.pending: List = []          # (lane, RegionInstance) per request
        self.trans: List = []            # translog row index per request

    def register(self, key: Tuple, loops) -> List[int]:
        """Share profile rows between lanes with identical loop content —
        keyed on (app name, active drift), so a drifted lane never aliases
        its clean sibling's profiles."""
        pids = self._pids.get(key)
        if pids is None:
            pids = list(range(len(self.profiles),
                              len(self.profiles) + len(loops)))
            self.profiles.extend(loops)
            self._pids[key] = pids
        return pids


class ReplayBatch:
    """Lockstep multi-cell selector replay.

    Selector state is sequential across time steps, but loop execution is
    parallel across cells — so the replay is organized as a per-step
    decide / execute / learn cycle over many (app, system, selector,
    chunk-mode, reward) lanes:

    * **decide** — every lane's per-loop policy is consulted host-side
      (``SelectionService.instance``; RL agents, fuzzy ladders and Oracle
      overrides all run here);
    * **execute** — all lanes' loop instances for step *t* fan into ONE
      ``SimBackend.run_lockstep`` call per machine model (profiles of lanes
      sharing an application are deduplicated), instead of hundreds of
      sequential DES runs;
    * **learn** — the batched results scatter back through
      ``Observation.batch`` into each lane's policy feedback.

    Lanes are fully independent: each owns its service and its private rng
    stream (the historical crc32 label tuples), so on the Python backend a
    lockstep replay is bit-identical to running ``run_selector_sequential``
    per cell, and on the JAX backend it is identical to the sequential JAX
    replay while being batched across every lane.
    """

    def __init__(self, lanes: Sequence[CellSpec], T: Optional[int] = None,
                 seed: int = 0,
                 sweeps: Optional[Dict[Tuple[str, str],
                                       PortfolioSweep]] = None,
                 backend=None, sim_backend=None, translog=None):
        self.bk = get_backend(backend)
        #: optional :class:`~repro.sim.translog.TransitionLogger` — records
        #: every lane decision's context + full counterfactual prices for
        #: offline policy training; pricing draws from the what-if's fixed
        #: stateless seed, so a logged replay stays bit-identical
        self.translog = translog
        if sim_backend is None:
            # sim-assisted lanes price candidates on the replay backend by
            # default, so their argmin matches that engine's Oracle
            sim_backend = backend
        sweeps = sweeps or {}
        apps: Dict[str, Application] = {}
        self.lanes: List[_Lane] = []
        for spec in lanes:
            app = apps.get(spec.app)
            if app is None:
                app = apps[spec.app] = get_application(spec.app)
            self.lanes.append(_Lane(
                spec, app, get_system(spec.system), T or app.T, seed,
                sweeps.get((spec.app, spec.system)),
                sim_backend=sim_backend))
        self._apps = apps
        self.T_max = max((lane.T for lane in self.lanes), default=0)

    def _loops(self, cache: Dict[Tuple, List], app_name: str, t: int,
               drift: Optional[PerturbationSpec] = None) -> List:
        key = (app_name, drift)
        loops = cache.get(key)
        if loops is None:
            app = self._apps[app_name]
            loops = cache[key] = (app.loops(t) if drift is None
                                  else drift.loops(app, t))
        return loops

    def step(self, t: int) -> None:
        """One decide / execute / learn cycle over all active lanes."""
        loops_cache: Dict[Tuple, List] = {}
        groups: Dict[str, _StepGroup] = {}
        for lane in self.lanes:                               # decide
            if t >= lane.T:
                continue
            g = groups.get(lane.spec.system)
            if g is None:
                g = groups[lane.spec.system] = _StepGroup(lane.system)
            pz = lane.spec.perturb
            drift = pz if (pz is not None and pz.has_drift) else None
            ip = lane.perturb_at(t)
            loops = self._loops(loops_cache, lane.spec.app, t, drift)
            pids = g.register((lane.spec.app, drift), loops)
            for li, profile in enumerate(loops):
                cp = chunk_param_for(lane.spec.chunk_mode, profile.N,
                                     lane.system.P)
                if lane.whatif is not None:
                    lane.whatif.set_context(profile, cp, perturb=ip)
                inst = lane.service.instance(lane.app.loop_names[li])
                d = inst.decision.with_instance_defaults(cp)
                g.requests.append(LockstepRequest(
                    profile_id=pids[li], alg=d.action,
                    chunk_param=d.chunk_param, rng=lane.rng, perturb=ip))
                g.pending.append((lane, inst))
                if self.translog is not None:
                    g.trans.append(self.translog.log_decision(
                        lane, t, profile, cp, ip, d))
        for g in groups.values():                             # execute
            res = self.bk.run_lockstep(g.profiles, g.system, g.requests)
            obs = Observation.batch(res.loop_time, res.lib)
            for i, ((lane, inst), o) in enumerate(zip(g.pending,
                                                      obs)):  # learn
                inst.report(observation=o)
                inst.close()
                lane.total += o.loop_time
                if g.trans and g.trans[i] is not None:
                    self.translog.log_result(g.trans[i], o.loop_time)

    def run(self) -> List[SelectorRun]:
        """Replay every lane to completion; results in lane order."""
        for t in range(self.T_max):
            self.step(t)
        return [lane.result() for lane in self.lanes]


def run_selector(app_name: str, system_name: str, selector: str,
                 chunk_mode: str = "default", reward: Optional[str] = None,
                 T: Optional[int] = None, seed: int = 0,
                 sweep: Optional[PortfolioSweep] = None,
                 backend=None, sim_backend=None,
                 perturb: Optional[PerturbationSpec] = None,
                 translog=None) -> SelectorRun:
    """Execute one selection method over the full time-stepped application.

    Every modified loop gets an independent policy via ``SelectionService``
    (LB4OMP loop ids); ``selector`` is any ``make_policy`` name, including
    "Hybrid" (expert-seeded RL) and "Oracle" (per-loop overrides carrying
    the per-step best; ``sweep`` is required for it).  Runs as a one-lane
    :class:`ReplayBatch` — bit-identical to the sequential reference loop
    (``run_selector_sequential``); batch many cells through ``ReplayBatch``
    or ``run_campaign`` to amortize the backend calls across lanes."""
    spec = CellSpec(app=app_name, system=system_name, selector=selector,
                    chunk_mode=chunk_mode, reward=reward, perturb=perturb)
    sweeps = {(app_name, system_name): sweep} if sweep is not None else None
    return ReplayBatch([spec], T=T, seed=seed, sweeps=sweeps,
                       backend=backend, sim_backend=sim_backend,
                       translog=translog).run()[0]


# ---------------------------------------------------------------------------
# the full factorial campaign (Fig. 5)
# ---------------------------------------------------------------------------

SELECTOR_GRID: List[Tuple[str, Optional[str]]] = [
    ("RandomSel", None), ("ExhaustiveSel", None), ("ExpertSel", None),
    ("QLearn", "LT"), ("QLearn", "LIB"), ("SARSA", "LT"), ("SARSA", "LIB"),
]

#: the paper grid plus the §6 expert-seeded RL combination
EXTENDED_SELECTOR_GRID: List[Tuple[str, Optional[str]]] = \
    SELECTOR_GRID + [("Hybrid", "LT"), ("Hybrid", "LT+LIB")]

#: the extended grid plus the simulation-assisted methods (SimAS-style):
#: candidate pricing in simulation, zero live exploration for SimPolicy and
#: a sim-pruned RL window for SimHybrid
SIM_SELECTOR_GRID: List[Tuple[str, Optional[str]]] = \
    EXTENDED_SELECTOR_GRID + [("SimPolicy", "LT"), ("SimHybrid", "LT")]


@dataclass
class CampaignResult:
    app: str
    system: str
    sweep: PortfolioSweep
    oracle_total: float
    selector_runs: Dict[Tuple[str, str, Optional[str]], SelectorRun]

    def degradation(self) -> Dict[Tuple[str, str, Optional[str]], float]:
        """Fig. 5 cells: (T_method - T_oracle) / T_oracle * 100."""
        return {k: (r.total - self.oracle_total) / self.oracle_total * 100.0
                for k, r in self.selector_runs.items()}


def run_campaign(cells: Sequence[Tuple[str, str]],
                 T: Optional[int] = None, reps: int = 3, seed: int = 0,
                 selectors=SELECTOR_GRID,
                 chunk_modes=CHUNK_MODES,
                 backend=None,
                 selector_backend=None,
                 sim_backend=None,
                 translog=None
                 ) -> Dict[Tuple[str, str], CampaignResult]:
    """The full factorial campaign over many Fig. 5 cells at once.

    ``cells`` is a sequence of (application, system) name pairs.  Per cell
    the fixed-algorithm portfolio sweeps to the Oracle through one
    ``run_batch``; then EVERY cell's (selector x chunk-mode x reward) lanes
    replay in lockstep through one :class:`ReplayBatch` — per time step the
    campaign issues one batched backend call per machine model instead of
    ``len(cells) * len(selectors) * len(chunk_modes)`` sequential DES runs.

    ``backend`` drives the portfolio sweeps; ``selector_backend`` (default:
    same as ``backend``) drives the lockstep replays — pass
    ``selector_backend="python"`` when the adaptive algorithms must see
    exact per-chunk telemetry rather than the JAX surrogates.
    ``sim_backend`` (default: same as ``selector_backend``) prices the
    candidate sets of simulation-assisted lanes (``SIM_SELECTOR_GRID``).
    ``translog`` (a :class:`~repro.sim.translog.TransitionLogger`) records
    every lane decision with full counterfactual prices for offline policy
    training without touching lane rng streams."""
    if selector_backend is None:
        selector_backend = backend
    sweeps = {
        (app, sysname): sweep_portfolio(app, sysname, T=T, reps=reps,
                                        seed=seed, backend=backend)
        for app, sysname in cells}
    lanes = [CellSpec(app=app, system=sysname, selector=sel,
                      chunk_mode=mode, reward=reward)
             for app, sysname in cells
             for mode in chunk_modes
             for sel, reward in selectors]
    runs = ReplayBatch(lanes, T=T, seed=seed, sweeps=sweeps,
                       backend=selector_backend,
                       sim_backend=sim_backend, translog=translog).run()
    by_cell: Dict[Tuple[str, str], Dict] = {tuple(c): {} for c in cells}
    for spec, run in zip(lanes, runs):
        by_cell[(spec.app, spec.system)][spec.key] = run
    out = {}
    for app, sysname in cells:
        sweep = sweeps[(app, sysname)]
        T_eff = T or get_application(app).T
        out[(app, sysname)] = CampaignResult(
            app=app, system=sysname, sweep=sweep,
            oracle_total=float(sweep.oracle_times()[:T_eff].sum()),
            selector_runs=by_cell[(app, sysname)])
    return out


def run_campaign_cell(app_name: str, system_name: str,
                      T: Optional[int] = None, reps: int = 3,
                      seed: int = 0,
                      selectors=SELECTOR_GRID,
                      chunk_modes=CHUNK_MODES,
                      backend=None,
                      selector_backend="python",
                      sim_backend=None) -> CampaignResult:
    """One Fig. 5 cell (a ``run_campaign`` of a single (app, system) pair).

    ``backend`` picks the simulation engine for the heavy portfolio sweep
    (``"jax"`` batches it); the selector replays default to the reference
    engine for exact-telemetry adaptivity — pass
    ``selector_backend="jax"`` to batch them too."""
    return run_campaign([(app_name, system_name)], T=T, reps=reps, seed=seed,
                        selectors=selectors, chunk_modes=chunk_modes,
                        backend=backend,
                        selector_backend=selector_backend,
                        sim_backend=sim_backend)[(app_name, system_name)]
