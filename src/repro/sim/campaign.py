"""Factorial experiment campaign (paper §4.1, Table 2).

Drives the DES over {applications} x {systems} x {scheduling algorithms |
selection methods} x {chunk parameter: default | expChunk} x {RL reward: LT |
LIB}, computes the Oracle (per-loop, per-time-step best over all algorithm x
chunk combinations) and the performance-degradation table of Fig. 5, the
c.o.v. of Fig. 4, and the selection traces of Figs. 7-8.
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


def _digest(label: str) -> int:
    """Stable 16-bit label digest for rng seed tuples — ``hash()`` is salted
    per process for strings, which made campaign noise irreproducible."""
    return zlib.crc32(label.encode("utf-8")) & 0xFFFF

from ..core import (ALGORITHM_NAMES, N_ALGORITHMS, SelectionService,
                    coefficient_of_variation, exp_chunk)
from .backends import InstanceSpec, get_backend
from .systems import SYSTEMS, SystemModel, get_system
from .workloads import APPLICATIONS, Application, get_application

CHUNK_MODES = ("default", "expChunk")


def chunk_param_for(mode: str, N: int, P: int) -> int:
    if mode == "default":
        return 0
    if mode == "expChunk":
        return exp_chunk(N, P)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# fixed-algorithm runs (portfolio sweep → Oracle, c.o.v.)
# ---------------------------------------------------------------------------

@dataclass
class FixedRun:
    """Median per-time-step loop times for one (alg, chunk_mode)."""
    times: np.ndarray          # (T, n_loops) medians over reps
    libs: np.ndarray           # (T, n_loops)

    @property
    def total(self) -> float:
        return float(self.times.sum())


def _run_portfolio(app: Application, system: SystemModel,
                   pairs: List[Tuple[int, str]], T: int, reps: int,
                   seed: int, backend=None) -> Dict[Tuple[int, str],
                                                    "FixedRun"]:
    """Evaluate every (alg, chunk_mode) pair over the app's time-stepped
    loops through ONE backend batch (the campaign fan-out: alg x mode x
    time-step x loop x rep).  Seed tuples are the historical per-instance
    rng labels, so the Python backend reproduces ``run_fixed`` bit-exactly
    and the JAX backend folds the same tuples into its stateless streams."""
    bk = get_backend(backend)
    # time-invariant apps: simulate a window and tile (median statistics are
    # identical across steps; saves orders of magnitude of DES time)
    T_sim = min(T, 24) if app.time_invariant else T
    stack = app.profile_stack(T_sim)
    n_loops = stack.n_loops
    specs: List[InstanceSpec] = []
    for alg, mode in pairs:
        for t in range(T_sim):
            for li in range(n_loops):
                pid = stack.pid(t, li)
                cp = chunk_param_for(mode, stack.profiles[pid].N, system.P)
                for r in range(reps):
                    specs.append(InstanceSpec(
                        profile_id=pid, alg=alg, chunk_param=cp,
                        seed=(seed, _digest(app.name), system.P, alg,
                              _digest(mode), t, r)))
    res = bk.run_batch(stack.profiles, system, specs)
    lt = res.loop_time.reshape(len(pairs), T_sim, n_loops, reps)
    lb = res.lib.reshape(len(pairs), T_sim, n_loops, reps)
    out = {}
    for i, pair in enumerate(pairs):
        times = np.median(lt[i], axis=-1)
        libs = np.median(lb[i], axis=-1)
        if T_sim < T:
            reps_needed = -(-T // T_sim)
            times = np.tile(times, (reps_needed, 1))[:T]
            libs = np.tile(libs, (reps_needed, 1))[:T]
        out[pair] = FixedRun(times=times, libs=libs)
    return out


def run_fixed(app: Application, system: SystemModel, alg: int,
              chunk_mode: str, T: Optional[int] = None, reps: int = 3,
              seed: int = 0, backend=None) -> FixedRun:
    T = T or app.T
    return _run_portfolio(app, system, [(alg, chunk_mode)], T, reps, seed,
                          backend=backend)[(alg, chunk_mode)]


@dataclass
class PortfolioSweep:
    """All 12 algorithms x 2 chunk modes for one app-system pair."""
    app: str
    system: str
    runs: Dict[Tuple[int, str], FixedRun]

    def oracle_times(self) -> np.ndarray:
        """(T, n_loops) per-loop per-step best over the whole sweep (§3.3)."""
        stack = np.stack([r.times for r in self.runs.values()])
        return stack.min(axis=0)

    def oracle_total(self) -> float:
        return float(self.oracle_times().sum())

    def oracle_best_fn(self, loop_index: int = 0):
        """Per-step best algorithm index (default chunk-mode-agnostic)."""
        keys = list(self.runs.keys())
        stack = np.stack([self.runs[k].times[:, loop_index] for k in keys])
        arg = stack.argmin(axis=0)
        return lambda t: keys[arg[min(t, len(arg) - 1)]][0]

    def oracle_argmin(self) -> np.ndarray:
        """(T, n_loops) index into ``sorted run keys`` of the per-instance
        winner — the Oracle's selection trace (backend-equivalence tests
        compare these across engines)."""
        keys = sorted(self.runs.keys(), key=str)
        stack = np.stack([self.runs[k].times for k in keys])
        return stack.argmin(axis=0)

    def cov(self) -> float:
        """Fig. 4: c.o.v. of loop execution time over every algorithm and
        chunk parameter."""
        totals = np.array([r.total for r in self.runs.values()])
        return coefficient_of_variation(totals)


def sweep_portfolio(app_name: str, system_name: str, T: Optional[int] = None,
                    reps: int = 3, seed: int = 0,
                    backend=None) -> PortfolioSweep:
    """All 12 algorithms x 2 chunk modes, fanned into a single backend
    batch (with ``backend="jax"`` the whole sweep is a handful of jitted
    vmapped calls instead of tens of thousands of Python event loops)."""
    app = get_application(app_name)
    system = get_system(system_name)
    T_eff = T or app.T
    pairs = [(alg, mode) for alg in range(N_ALGORITHMS)
             for mode in CHUNK_MODES]
    runs = _run_portfolio(app, system, pairs, T_eff, reps, seed,
                          backend=backend)
    return PortfolioSweep(app=app_name, system=system_name, runs=runs)


# ---------------------------------------------------------------------------
# selector runs
# ---------------------------------------------------------------------------

@dataclass
class SelectorRun:
    selector: str
    chunk_mode: str
    reward: Optional[str]
    total: float
    #: per loop name: list of (chosen alg, loop_time, lib) per time-step
    history: Dict[str, List[Tuple[int, float, float]]]

    def selection_shares(self, loop: Optional[str] = None) -> Dict[str, float]:
        """Fig. 7/8 pie charts: fraction of instances per selected algorithm."""
        hists = ([self.history[loop]] if loop else list(self.history.values()))
        counts = np.zeros(N_ALGORITHMS)
        for h in hists:
            for a, _, _ in h:
                counts[a] += 1
        tot = counts.sum() or 1.0
        return {ALGORITHM_NAMES[i]: counts[i] / tot
                for i in range(N_ALGORITHMS) if counts[i] > 0}


def run_selector(app_name: str, system_name: str, selector: str,
                 chunk_mode: str = "default", reward: Optional[str] = None,
                 T: Optional[int] = None, seed: int = 0,
                 sweep: Optional[PortfolioSweep] = None,
                 backend=None) -> SelectorRun:
    """Execute one selection method over the full time-stepped application.

    Every modified loop gets an independent policy via ``SelectionService``
    (LB4OMP loop ids); ``selector`` is any ``make_policy`` name, including
    "Hybrid" (expert-seeded RL) and "Oracle" (per-loop overrides carrying
    the per-step best; ``sweep`` is required for it).  The selection loop is
    inherently sequential (each decision feeds on the previous instance's
    telemetry), so ``backend`` here steers per-instance evaluation only —
    the default Python engine is usually right."""
    bk = get_backend(backend)
    app = get_application(app_name)
    system = get_system(system_name)
    T = T or app.T

    if selector.lower() == "oracle":
        assert sweep is not None, "Oracle needs a portfolio sweep"
        service = SelectionService("Oracle", overrides={
            nm: {"best_fn": sweep.oracle_best_fn(li)}
            for li, nm in enumerate(app.loop_names)})
    else:
        service = SelectionService(selector, reward=reward, seed=seed)

    rng = np.random.default_rng((seed, _digest(app_name), system.P,
                                 _digest(selector), _digest(chunk_mode)))
    total = 0.0
    for t in range(T):
        for li, profile in enumerate(app.loops(t)):
            nm = app.loop_names[li]
            with service.instance(nm) as inst:
                # a policy may steer the chunk parameter; the campaign's
                # chunk mode fills the default
                d = inst.decision.with_instance_defaults(
                    chunk_param_for(chunk_mode, profile.N, system.P))
                res = bk.run_instance(profile, system, d.action,
                                      d.chunk_param, rng)
                inst.report(loop_time=res.loop_time, lib=res.lib)
            total += res.loop_time
    # the service's per-region records ARE the selection traces
    history = {nm: list(service.history(nm)) for nm in app.loop_names}
    return SelectorRun(selector=selector, chunk_mode=chunk_mode,
                       reward=reward, total=total, history=history)


# ---------------------------------------------------------------------------
# the full factorial campaign (Fig. 5)
# ---------------------------------------------------------------------------

SELECTOR_GRID: List[Tuple[str, Optional[str]]] = [
    ("RandomSel", None), ("ExhaustiveSel", None), ("ExpertSel", None),
    ("QLearn", "LT"), ("QLearn", "LIB"), ("SARSA", "LT"), ("SARSA", "LIB"),
]

#: the paper grid plus the §6 expert-seeded RL combination
EXTENDED_SELECTOR_GRID: List[Tuple[str, Optional[str]]] = \
    SELECTOR_GRID + [("Hybrid", "LT"), ("Hybrid", "LT+LIB")]


@dataclass
class CampaignResult:
    app: str
    system: str
    sweep: PortfolioSweep
    oracle_total: float
    selector_runs: Dict[Tuple[str, str, Optional[str]], SelectorRun]

    def degradation(self) -> Dict[Tuple[str, str, Optional[str]], float]:
        """Fig. 5 cells: (T_method - T_oracle) / T_oracle * 100."""
        return {k: (r.total - self.oracle_total) / self.oracle_total * 100.0
                for k, r in self.selector_runs.items()}


def run_campaign_cell(app_name: str, system_name: str,
                      T: Optional[int] = None, reps: int = 3,
                      seed: int = 0,
                      selectors=SELECTOR_GRID,
                      chunk_modes=CHUNK_MODES,
                      backend=None) -> CampaignResult:
    """One Fig. 5 cell.  ``backend`` picks the simulation engine for the
    heavy portfolio sweep (``"jax"`` batches it); the sequential selector
    replays stay on the reference engine for exact-telemetry adaptivity."""
    sweep = sweep_portfolio(app_name, system_name, T=T, reps=reps, seed=seed,
                            backend=backend)
    T_eff = T or get_application(app_name).T
    runs = {}
    for mode in chunk_modes:
        for sel, reward in selectors:
            # pinned to the reference engine (not the env default): the
            # adaptive algorithms need real per-chunk telemetry here
            runs[(sel, mode, reward)] = run_selector(
                app_name, system_name, sel, chunk_mode=mode, reward=reward,
                T=T_eff, seed=seed, sweep=sweep, backend="python")
    oracle_total = float(sweep.oracle_times()[:T_eff].sum())
    return CampaignResult(app=app_name, system=system_name, sweep=sweep,
                          oracle_total=oracle_total, selector_runs=runs)
