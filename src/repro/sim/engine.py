"""Compatibility shim — the reference engine now lives in
``repro.sim.backends.python`` (one of the pluggable simulation backends;
see ``repro.sim.backends``).  All public and test-visible names are
re-exported here unchanged.
"""

from __future__ import annotations

from .backends.base import EVENT_CAP
from .backends.python import (H_ATOMIC_ADAPTIVE, MUTEX_ADAPTIVE,
                              InstanceResult, PythonBackend, run_instance)

__all__ = [
    "EVENT_CAP", "H_ATOMIC_ADAPTIVE", "MUTEX_ADAPTIVE", "InstanceResult",
    "PythonBackend", "run_instance",
]
