"""Jitted DES variant: self-scheduled loop execution as a ``lax.while_loop``.

The Python engine (`repro.sim.engine`) is the reference; this variant runs
the same event loop fully inside ``jax.jit`` for the *non-adaptive* dynamic
algorithms (SS/GSS/AutoLLVM/TSS/mFAC2) — the form a JAX-native runtime would
embed (e.g. inside a jitted dispatcher).  Event ordering uses argmin over
the P thread-available times (P <= 128, cheap on-vector).

For whole-campaign batches use ``repro.sim.backends.jax_batched`` — this
module remains the minimal single-instance form.  ``MAX_EVENTS`` is the
shared ``EVENT_CAP`` from the backend protocol, so this engine and the
closed-form cutover agree on when SS/StaticSteal go analytic.

Cross-validated against the Python engine in ``tests/test_extensions.py``
(noise-free mode, chunk counts + makespan within tolerance).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..core.jaxsched import chunk_schedule
from .backends.base import EVENT_CAP

MAX_EVENTS = EVENT_CAP


@functools.partial(jax.jit, static_argnums=(0, 3, 5))
def simulate_loop(alg: int, prefix_grid, N, P, chunk_param,
                  max_events: int = MAX_EVENTS, h: float = 1e-7,
                  jitter=None):
    """Simulate one loop instance with algorithm ``alg`` (non-adaptive).

    prefix_grid: (G+1,) cumulative cost over [0, N] (uniform grids work via
    jnp.linspace).  Returns (makespan, finish_times (P,), n_chunks).
    """
    sizes, count = chunk_schedule(alg, N, P, chunk_param,
                                  max_chunks=max_events)
    G = prefix_grid.shape[0] - 1
    Nf = jnp.asarray(N, jnp.float32)

    def pref(x):
        pos = x.astype(jnp.float32) * (G / Nf)
        i = jnp.clip(pos.astype(jnp.int32), 0, G - 1)
        frac = pos - i
        return prefix_grid[i] + frac * (prefix_grid[i + 1] - prefix_grid[i])

    starts = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                              jnp.cumsum(sizes)[:-1]])
    costs = pref(starts + sizes) - pref(starts)

    t0 = jitter if jitter is not None else jnp.zeros((P,))

    def body(carry):
        i, avail = carry
        pe = jnp.argmin(avail)
        dt = jnp.where(i < count, h + costs[i], 0.0)
        avail = avail.at[pe].add(dt)
        return i + 1, avail

    def cond(carry):
        i, _ = carry
        return i < count

    _, finish = lax.while_loop(cond, body, (jnp.asarray(0, jnp.int32), t0))
    return finish.max(), finish, count
