"""Perturbation & drift injection (SimAS's actual subject).

The selection problem only matters because workloads and systems are
non-stationary: SimAS is selection *under perturbations*, and LB4OMP
motivates dynamic scheduling with PE-speed variability.  This module is the
declarative layer that makes the repro's cells non-stationary:

* :class:`PESlowdown` / :class:`PEFailure` — a subset of PEs runs slower
  (or effectively dies) inside a time-step window;
* :class:`NoiseBurst` — the machine's lognormal per-chunk noise sigma is
  inflated inside a window (bursty co-tenancy);
* :class:`WorkloadDrift` — the application itself drifts between time
  steps: iteration-count scaling (``kind="N"``), load-imbalance sharpening
  (``kind="cov"``: the per-iteration cost density is raised to a power and
  renormalized, preserving total work), or an app-phase shift
  (``kind="phase"``: the app's own ``loops(t)`` evolution is fast-forwarded);
* :class:`PerturbationSpec` — a frozen, hashable bundle of the above,
  attached to a campaign :class:`~repro.sim.campaign.CellSpec` (or passed to
  ``run_selector*``) and resolved per time step into the backends'
  :class:`~repro.sim.backends.base.InstancePerturb`;
* :class:`FleetPerturb` / :class:`GroupSlowdown` / :class:`ReplicaFailure` /
  :class:`ReplicaStraggler` — the serving-layer analogue: whole replica
  groups slow down, individual replicas drop out of or degrade within their
  group, all inside wall-clock windows (``FleetSimulator`` scales the
  group's cost model, masks dead replicas out of dispatch, and exposes the
  effective per-group capacity to routers and admission control; whole-group
  failures interrupt in-flight work, which the fleet's
  :class:`~repro.serving.fleet.recovery.RecoveryPolicy` retries/migrates).

Execution-side injection happens inside the backends' shared vectorized
precompute (per-PE speed multipliers and a sigma scale applied *before* the
sequential event cores), so the Pallas and while_loop cores stay
bit-identical, and a perturbation-off run is bit-equal to the clean goldens
by construction: neutral multipliers are exactly 1.0 and no rng draw is
added or reordered.

Windows are half-open in time steps: active for ``t0 <= t < t1``
(``t1=None`` means "until the end").
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .backends.base import InstancePerturb

__all__ = [
    "FAILED_PE_FACTOR", "PESlowdown", "PEFailure", "NoiseBurst",
    "WorkloadDrift", "PerturbationSpec", "GroupSlowdown", "ReplicaFailure",
    "ReplicaStraggler", "FleetPerturb", "InstancePerturb",
    "pe_slowdown_spec", "noise_burst_spec", "drift_spec",
]

#: execution-time multiplier modelling a *failed* PE: large enough that the
#: argmin event cores never assign it work after its first chunk, small
#: enough to stay far from float32 overflow inside the cores
FAILED_PE_FACTOR = 1.0e4


def _active(t0: int, t1: Optional[int], t: int) -> bool:
    return t >= t0 and (t1 is None or t < t1)


@dataclass(frozen=True)
class PESlowdown:
    """``pes`` run ``factor``x slower for time steps ``t0 <= t < t1``."""

    pes: Tuple[int, ...]
    factor: float
    t0: int = 0
    t1: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "pes", tuple(int(p) for p in self.pes))


@dataclass(frozen=True)
class PEFailure:
    """``pes`` are effectively dead for ``t0 <= t < t1`` (their execution
    time inflates by :data:`FAILED_PE_FACTOR`; dynamic algorithms route
    around them, STATIC does not — that asymmetry is the whole point)."""

    pes: Tuple[int, ...]
    t0: int = 0
    t1: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "pes", tuple(int(p) for p in self.pes))


@dataclass(frozen=True)
class NoiseBurst:
    """The machine's lognormal noise sigma is multiplied by ``factor``
    for ``t0 <= t < t1``."""

    factor: float
    t0: int = 0
    t1: Optional[int] = None


@dataclass(frozen=True)
class WorkloadDrift:
    """The application drifts at step ``t0`` (and stays drifted).

    ``kind="N"``     — every loop's iteration count scales by ``factor``;
    ``kind="cov"``   — per-iteration cost density is raised to ``factor``
                       and renormalized (total work preserved; > 1 sharpens
                       imbalance, < 1 flattens it);
    ``kind="phase"`` — the app's own time evolution jumps forward by
                       ``phase_shift`` steps (``loops(t + phase_shift)``).
    """

    kind: str
    t0: int = 0
    factor: float = 1.0
    phase_shift: int = 0

    def __post_init__(self):
        if self.kind not in ("N", "cov", "phase"):
            raise ValueError(f"unknown drift kind {self.kind!r}; "
                             f"expected 'N', 'cov' or 'phase'")


def _drift_profile(p, d: WorkloadDrift):
    if d.kind == "N":
        N2 = max(1, int(round(p.N * d.factor)))
        if p.prefix_grid is None:
            return dataclasses.replace(p, N=N2)
        # scaling the cumulative-cost grid by the same factor keeps the
        # density shape while total work tracks the new N
        return dataclasses.replace(p, N=N2,
                                   prefix_grid=p.prefix_grid * d.factor)
    if d.kind == "cov":
        if p.prefix_grid is None:
            return p            # uniform density: nothing to sharpen
        dens = np.maximum(np.diff(p.prefix_grid), 0.0)
        dens = dens ** d.factor
        total = float(p.prefix_grid[-1] - p.prefix_grid[0])
        s = float(dens.sum())
        if s <= 0.0:
            return p
        dens *= total / s       # preserve total work exactly (up to fp)
        grid = np.concatenate([[0.0], np.cumsum(dens)])
        return dataclasses.replace(p, prefix_grid=grid.astype(np.float64))
    return p                    # "phase" is handled at the app level


@dataclass(frozen=True)
class PerturbationSpec:
    """Declarative, hashable perturbation bundle for one campaign cell."""

    slowdowns: Tuple[PESlowdown, ...] = ()
    failures: Tuple[PEFailure, ...] = ()
    noise_bursts: Tuple[NoiseBurst, ...] = ()
    drifts: Tuple[WorkloadDrift, ...] = ()

    def __post_init__(self):
        for name in ("slowdowns", "failures", "noise_bursts", "drifts"):
            object.__setattr__(self, name, tuple(getattr(self, name)))

    @property
    def has_drift(self) -> bool:
        return bool(self.drifts)

    def instance_perturb(self, t: int, P: int) -> Optional[InstancePerturb]:
        """Resolve the execution-side perturbation active at step ``t`` for
        a P-PE machine; ``None`` when nothing is active (the common case —
        callers then take the untouched clean path)."""
        scale: Optional[np.ndarray] = None
        for ev in self.slowdowns:
            if _active(ev.t0, ev.t1, t):
                if scale is None:
                    scale = np.ones(P)
                for p in ev.pes:
                    scale[p % P] *= ev.factor
        for ev in self.failures:
            if _active(ev.t0, ev.t1, t):
                if scale is None:
                    scale = np.ones(P)
                for p in ev.pes:
                    scale[p % P] *= FAILED_PE_FACTOR
        ss = 1.0
        for ev in self.noise_bursts:
            if _active(ev.t0, ev.t1, t):
                ss *= ev.factor
        if scale is None and ss == 1.0:
            return None
        return InstancePerturb(
            pe_scale=None if scale is None else tuple(scale),
            sigma_scale=ss)

    def loops(self, app, t: int) -> List:
        """The app's loop profiles at step ``t`` under any active drift."""
        shift = sum(d.phase_shift for d in self.drifts
                    if d.kind == "phase" and t >= d.t0)
        loops = app.loops(t + shift)
        transforms = [d for d in self.drifts
                      if d.kind in ("N", "cov") and t >= d.t0]
        for d in transforms:
            loops = [_drift_profile(p, d) for p in loops]
        return loops


# ---------------------------------------------------------------------------
# convenience builders (the bench / CI scenarios)
# ---------------------------------------------------------------------------

def pe_slowdown_spec(P: int, frac: float = 0.2, factor: float = 8.0,
                     t0: int = 0, t1: Optional[int] = None
                     ) -> PerturbationSpec:
    """The canonical scenario: the last ``frac`` of the machine's PEs run
    ``factor``x slower from step ``t0`` on."""
    k = max(1, int(round(P * frac)))
    return PerturbationSpec(slowdowns=(
        PESlowdown(pes=tuple(range(P - k, P)), factor=factor, t0=t0, t1=t1),))


def noise_burst_spec(factor: float = 6.0, t0: int = 0,
                     t1: Optional[int] = None) -> PerturbationSpec:
    return PerturbationSpec(noise_bursts=(NoiseBurst(factor=factor, t0=t0,
                                                     t1=t1),))


def drift_spec(kind: str, t0: int = 0, factor: float = 1.0,
               phase_shift: int = 0) -> PerturbationSpec:
    return PerturbationSpec(drifts=(WorkloadDrift(kind=kind, t0=t0,
                                                  factor=factor,
                                                  phase_shift=phase_shift),))


# ---------------------------------------------------------------------------
# fleet-level perturbations (serving layer)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GroupSlowdown:
    """Replica group ``group`` serves ``factor``x slower for wall-clock
    ``t0 <= now < t1`` (seconds, half-open; ``t1=None`` = until the end)."""

    group: int
    factor: float
    t0: float = 0.0
    t1: Optional[float] = None


@dataclass(frozen=True)
class ReplicaFailure:
    """Replicas of ``group`` drop out for wall-clock ``t0 <= now < t1``
    (seconds, half-open; ``t1=None`` = never rejoin).  ``replicas=None``
    means the WHOLE group fails — the only failure shape that interrupts
    in-flight work (sub-shard attribution does not exist at wave
    granularity); a partial replica set is masked out of future dispatch
    and pricing from ``t0`` on."""

    group: int
    t0: float = 0.0
    t1: Optional[float] = None
    replicas: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.replicas is not None:
            object.__setattr__(self, "replicas",
                               tuple(int(r) for r in self.replicas))


@dataclass(frozen=True)
class ReplicaStraggler:
    """Replicas of ``group`` serve ``factor``x slower for wall-clock
    ``t0 <= now < t1`` (``replicas=None`` = every replica — then equivalent
    to :class:`GroupSlowdown`, but applied per replica inside the dispatch
    loop instead of through the group cost model)."""

    group: int
    factor: float
    t0: float = 0.0
    t1: Optional[float] = None
    replicas: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.replicas is not None:
            object.__setattr__(self, "replicas",
                               tuple(int(r) for r in self.replicas))


def _wall_active(ev, now: float) -> bool:
    return ev.t0 <= now and (ev.t1 is None or now < ev.t1)


@dataclass(frozen=True)
class FleetPerturb:
    """Time-windowed fleet perturbations for ``FleetSimulator``:
    group-level slowdowns (``events``), replica-level failures and
    stragglers."""

    events: Tuple[GroupSlowdown, ...] = ()
    failures: Tuple[ReplicaFailure, ...] = ()
    stragglers: Tuple[ReplicaStraggler, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        object.__setattr__(self, "failures", tuple(self.failures))
        object.__setattr__(self, "stragglers", tuple(self.stragglers))

    def slowdowns(self, now: float, G: int) -> np.ndarray:
        """(G,) multiplicative service-time slowdowns active at ``now``."""
        f = np.ones(G)
        for ev in self.events:
            if _wall_active(ev, now):
                f[ev.group % G] *= ev.factor
        return f

    @property
    def has_replica_events(self) -> bool:
        return bool(self.failures or self.stragglers)

    def replica_state(self, now: float, G: int, R: int
                      ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """``(alive, scale)`` — (G, R) dispatch-availability mask and
        service-time multipliers active at ``now``; ``None`` while no
        replica-level event is active (the clean path)."""
        alive: Optional[np.ndarray] = None
        scale: Optional[np.ndarray] = None
        for ev in self.failures:
            if _wall_active(ev, now):
                if alive is None:
                    alive = np.ones((G, R), dtype=bool)
                reps = range(R) if ev.replicas is None else ev.replicas
                for r in reps:
                    alive[ev.group % G, r % R] = False
        for ev in self.stragglers:
            if _wall_active(ev, now):
                if scale is None:
                    scale = np.ones((G, R))
                reps = range(R) if ev.replicas is None else ev.replicas
                for r in reps:
                    scale[ev.group % G, r % R] *= ev.factor
        if alive is None and scale is None:
            return None
        return (np.ones((G, R), dtype=bool) if alive is None else alive,
                np.ones((G, R)) if scale is None else scale)

    def failure_start(self, g: int, G: int, R: int, lo: float, hi: float
                      ) -> Optional[Tuple[float, float]]:
        """Earliest WHOLE-group failure on group ``g`` starting strictly
        inside ``(lo, hi)`` — the event that interrupts a shard dispatched
        at ``lo`` predicted to drain at ``hi``.  Returns ``(t0, t1)`` with
        ``t1 = inf`` for a permanent failure, or ``None``."""
        best: Optional[Tuple[float, float]] = None
        for ev in self.failures:
            if ev.group % G != g:
                continue
            if ev.replicas is not None and \
                    len({r % R for r in ev.replicas}) < R:
                continue
            if lo < ev.t0 < hi:
                t1 = np.inf if ev.t1 is None else float(ev.t1)
                if best is None or ev.t0 < best[0]:
                    best = (float(ev.t0), t1)
        return best

    def next_change(self, now: float) -> Optional[float]:
        """Earliest event boundary strictly after ``now`` — the instant the
        fleet's availability/capacity next changes.  The run loop advances
        here when every group is unroutable, so a fully-failed fleet waits
        out the window instead of livelocking."""
        bounds = []
        for ev in (*self.events, *self.failures, *self.stragglers):
            bounds.append(ev.t0)
            if ev.t1 is not None:
                bounds.append(ev.t1)
        future = [b for b in bounds if b > now]
        return min(future) if future else None
