"""Machine models for the paper's three systems (Table 2).

Constants are calibrated so the simulator reproduces the paper's *qualitative*
behavior (claims C1-C8 in DESIGN.md), not exact seconds:

* ``h`` — central work-queue dispatch overhead per chunk (mutex/atomic path).
* ``h_adaptive_mult`` — extra bookkeeping for the mutex-protected adaptive
  variants (AWF-B/D per LB4OMP's implementation notes; mFAC2 and AWF-C/E use
  the atomic path).
* ``boundary_cost`` — per-chunk stream/prefetch restart cost charged to
  *memory-bound* loops (the data-locality loss the paper attributes to small
  chunks; §4.2).
* ``dyn_locality`` — relative inflation of memory-bound work under *dynamic*
  assignment (iterations land on threads that did not first-touch the data).
* ``noise_sigma`` — lognormal multiplicative execution noise per chunk.
* ``jitter`` — thread arrival spread at loop start (the GSS motivation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class SystemModel:
    name: str
    P: int
    h: float                  # s per dispatch (atomic/mutex fast path)
    h_adaptive_mult: float    # multiplier on h for mutex-protected adaptive algs
    h_serial_frac: float      # fraction of h inside the serializing critical
                              # section (central-queue saturation model)
    boundary_cost: float      # s per chunk on fully memory-bound loops
    dyn_locality: float       # base inflation of dynamically-assigned work on
                              # locality-sensitive loops (first-touch loss)
    loc_amp: float            # hardware miss-penalty amplitude for tiny chunks
    c_loc: int                # (unused default reuse window; per-loop c_loc wins)
    noise_sigma: float
    jitter: float             # s, max arrival offset
    speed_spread: float       # persistent per-thread speed variation (fraction)
    pe_speeds: Optional[Tuple[float, ...]] = None
                              # per-PE execution-time multipliers for
                              # *persistently* heterogeneous machines
                              # (big.LITTLE-style); None = homogeneous.
                              # 1.0 nominal, > 1 slower.  Composed with any
                              # instance perturbation by the backends.

    def __post_init__(self):
        if self.pe_speeds is not None:
            speeds = tuple(float(x) for x in self.pe_speeds)
            if len(speeds) != self.P:
                raise ValueError(
                    f"pe_speeds has {len(speeds)} entries for P={self.P}")
            if any(x <= 0.0 for x in speeds):
                raise ValueError("pe_speeds must be positive multipliers")
            object.__setattr__(self, "pe_speeds", speeds)

    def chunk_inflation(self, locality_sens: float, c: float,
                        c_loc: float) -> float:
        """Execution-time inflation for dynamically assigned chunks of size c
        on a loop whose spatial-reuse window is ``c_loc`` iterations."""
        return 1.0 + locality_sens * (
            self.dyn_locality + self.loc_amp * c_loc / (c + c_loc))


BROADWELL = SystemModel(
    name="broadwell", P=20, h=0.10e-6, h_adaptive_mult=4.0,
    h_serial_frac=0.5, boundary_cost=3.0e-6, dyn_locality=0.08,
    loc_amp=4.0, c_loc=256, noise_sigma=0.015,
    jitter=25e-6, speed_spread=0.005)

CASCADE_LAKE = SystemModel(
    name="cascadelake", P=56, h=0.12e-6, h_adaptive_mult=6.0,
    h_serial_frac=0.5, boundary_cost=3.0e-6, dyn_locality=0.10,
    loc_amp=6.0, c_loc=256, noise_sigma=0.02,
    jitter=35e-6, speed_spread=0.008)

EPYC = SystemModel(
    name="epyc", P=128, h=0.20e-6, h_adaptive_mult=8.0,
    h_serial_frac=0.5, boundary_cost=2.0e-6, dyn_locality=0.12,
    loc_amp=8.0, c_loc=256, noise_sigma=0.025,
    jitter=45e-6, speed_spread=0.010)

SYSTEMS = {s.name: s for s in (BROADWELL, CASCADE_LAKE, EPYC)}


def hetero_system(base: SystemModel, name: str,
                  pe_speeds: Tuple[float, ...]) -> SystemModel:
    """A synthetic heterogeneous machine derived from one of the paper's
    systems: same overhead/noise constants, but per-PE execution-time
    multipliers (1.0 nominal, > 1 slower)."""
    return dataclasses.replace(base, name=name,
                               pe_speeds=tuple(pe_speeds))


def _big_little(base: SystemModel, name: str, frac_little: float,
                little_factor: float) -> SystemModel:
    k = max(1, int(round(base.P * frac_little)))
    speeds = (1.0,) * (base.P - k) + (float(little_factor),) * k
    return hetero_system(base, name, speeds)


#: Synthetic heterogeneous machines beyond the paper's three (kept out of
#: ``SYSTEMS`` so figure pipelines iterating the paper's machine set are
#: untouched).  "big.LITTLE" quarters: last 25% of PEs run slower.
HETERO_SYSTEMS = {
    s.name: s for s in (
        _big_little(BROADWELL, "broadwell_het", 0.25, 2.0),
        _big_little(CASCADE_LAKE, "cascadelake_het", 0.25, 3.0),
        _big_little(EPYC, "epyc_het", 0.25, 4.0),
    )
}


def get_system(name: str) -> SystemModel:
    try:
        return SYSTEMS[name]
    except KeyError:
        pass
    try:
        return HETERO_SYSTEMS[name]
    except KeyError:
        raise KeyError(
            f"unknown system {name!r}; known: "
            f"{sorted(SYSTEMS) + sorted(HETERO_SYSTEMS)}") from None
