"""Transition logging for offline policy training.

:class:`TransitionLogger` threads through :class:`~repro.sim.campaign.
ReplayBatch` (``translog=`` on ``ReplayBatch`` / ``run_campaign`` /
``run_selector``): at every lane decision it extracts the decision's
:data:`~repro.core.learned.FEATURE_NAMES` context row and prices **all 12
portfolio algorithms** for that exact (profile, chunk-param, perturbation)
context through the lane system's batched :class:`~repro.sim.whatif.
LoopWhatIf` — so every logged transition carries the full counterfactual
reward vector, not just the chosen arm's outcome.  That makes the dump a
*true contextual-bandit dataset*: ``repro.runtime.policy_trainer`` can
regress predicted cost per arm directly, with no off-policy importance
correction, regardless of which selector actually drove the lane.

Pricing uses the two-pass what-if (``two_pass=True``): clean steps get
deterministic noise-free costs, perturbed steps get costs under the active
:class:`~repro.sim.backends.base.InstancePerturb` — so drift cells teach
the net what slow PEs and noise bursts do to each algorithm.  Pricing draws
from the what-if's fixed stateless seed and never touches lane rng streams:
a logged replay stays bit-identical to an unlogged one (test-enforced).

Shards are compressed ``.npz`` written atomically (tmp + ``os.replace``,
the ``core.persistence`` discipline), versioned with the feature schema;
``load_shards`` concatenates and schema-checks a shard set.
``scripts/gen_translog.py`` mass-produces shards across the app x system
grid (including ``*_het`` systems and ``PerturbationSpec`` drift cells).
"""

from __future__ import annotations

import os
import tempfile
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import N_ALGORITHMS
from ..core.learned import FEATURE_NAMES, FEATURE_VERSION, LoopFeaturizer
from ..core.simpolicy import Candidate
from .whatif import LoopWhatIf
from .workloads import profile_digest

__all__ = ["TransitionLogger", "TRANSLOG_VERSION", "load_translog",
           "load_shards", "save_translog"]

#: bumped together with the feature schema; a shard's (version,
#: feature_names) pair must match the loader's before training sees it
TRANSLOG_VERSION = 1

_ARRAY_KEYS = ("features", "costs", "libs", "chosen", "measured",
               "cell", "step", "perturbed")


class TransitionLogger:
    """Collects one training transition per (deduplicated) lane decision.

    One logger serves a whole :class:`~repro.sim.campaign.ReplayBatch`; it
    lazily builds one :class:`~repro.core.learned.LoopFeaturizer` and one
    two-pass :class:`~repro.sim.whatif.LoopWhatIf` per machine model.  With
    ``dedupe`` (default), lanes that face the identical decision context —
    same system, loop content, chunk parameter, perturbation and step —
    share one logged row (their features and counterfactual costs are
    identical by construction; only the first lane's chosen arm and live
    outcome are recorded).  ``stride`` keeps every k-th step only.
    """

    def __init__(self, sim_backend=None, stride: int = 1,
                 dedupe: bool = True):
        self.sim_backend = sim_backend
        self.stride = max(1, int(stride))
        self.dedupe = bool(dedupe)
        self._featurizers: Dict[str, LoopFeaturizer] = {}
        self._whatifs: Dict[str, LoopWhatIf] = {}
        self._seen: Dict[tuple, int] = {}
        self._features: List[np.ndarray] = []
        self._costs: List[np.ndarray] = []
        self._libs: List[np.ndarray] = []
        self._chosen: List[int] = []
        self._measured: List[float] = []
        self._cell: List[int] = []
        self._step: List[int] = []
        self._perturbed: List[bool] = []
        self._cell_keys: List[str] = []
        self._cell_index: Dict[str, int] = {}

    def __len__(self) -> int:
        return len(self._features)

    def _lane_tools(self, lane):
        nm = lane.spec.system
        fz = self._featurizers.get(nm)
        if fz is None:
            fz = self._featurizers[nm] = LoopFeaturizer(lane.system,
                                                        horizon=lane.T)
            self._whatifs[nm] = LoopWhatIf(lane.system,
                                           backend=self.sim_backend,
                                           two_pass=True)
        return fz, self._whatifs[nm]

    def _cell_id(self, lane) -> int:
        key = f"{lane.spec.app}|{lane.spec.system}"
        ci = self._cell_index.get(key)
        if ci is None:
            ci = self._cell_index[key] = len(self._cell_keys)
            self._cell_keys.append(key)
        return ci

    # -- the ReplayBatch hooks ----------------------------------------------
    def log_decision(self, lane, t: int, profile, chunk_param: int,
                     perturb, decision) -> Optional[int]:
        """Record the decision context; returns the row index the lane's
        live outcome should be reported to (``log_result``), or None when
        the row is strided out or deduplicated away."""
        if t % self.stride:
            return None
        pkey = None if perturb is None else perturb.key()
        if self.dedupe:
            key = (lane.spec.system, profile.name, profile_digest(profile),
                   profile.unit, chunk_param, pkey, t, lane.T)
            if key in self._seen:
                return None
            self._seen[key] = len(self._features)
        fz, wi = self._lane_tools(lane)
        fz.set_context(profile, chunk_param, perturb=perturb)
        wi.set_context(profile, chunk_param, perturb=perturb)
        obs = wi.price([Candidate(a) for a in range(N_ALGORITHMS)])
        self._features.append(fz.features(phase=t / lane.T))
        self._costs.append(np.array([o.loop_time for o in obs], np.float32))
        self._libs.append(np.array([o.lib for o in obs], np.float32))
        self._chosen.append(int(decision.action))
        self._measured.append(-1.0)     # filled by log_result
        self._cell.append(self._cell_id(lane))
        self._step.append(int(t))
        self._perturbed.append(pkey is not None)
        return len(self._features) - 1

    def log_result(self, index: int, loop_time: float) -> None:
        """Attach the chosen arm's live outcome to a logged row."""
        self._measured[index] = float(loop_time)

    # -- export --------------------------------------------------------------
    def arrays(self) -> Dict[str, np.ndarray]:
        """The shard payload (see ``save_translog`` for the schema)."""
        n = len(self._features)
        return {
            "version": np.int64(TRANSLOG_VERSION),
            "feature_names": np.array(FEATURE_NAMES),
            "feature_version": np.int64(FEATURE_VERSION),
            "features": (np.stack(self._features) if n
                         else np.zeros((0, len(FEATURE_NAMES)), np.float32)),
            "costs": (np.stack(self._costs) if n
                      else np.zeros((0, N_ALGORITHMS), np.float32)),
            "libs": (np.stack(self._libs) if n
                     else np.zeros((0, N_ALGORITHMS), np.float32)),
            "chosen": np.asarray(self._chosen, np.int16),
            "measured": np.asarray(self._measured, np.float32),
            "cell": np.asarray(self._cell, np.int32),
            "step": np.asarray(self._step, np.int32),
            "perturbed": np.asarray(self._perturbed, np.bool_),
            "cell_keys": np.array(self._cell_keys or [""]),
        }

    def save(self, path: str) -> str:
        """Atomically write the collected transitions as one npz shard."""
        return save_translog(path, self.arrays())


def save_translog(path: str, arrays: Dict[str, np.ndarray]) -> str:
    """Atomic compressed-npz write: tmp file + ``os.replace``, so a killed
    ``gen_translog`` run never leaves a torn shard for training to read."""
    path = str(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def _check_schema(d: Dict[str, np.ndarray], path: str) -> None:
    ver = int(d.get("version", -1))
    if ver != TRANSLOG_VERSION:
        raise ValueError(f"{path}: translog version {ver}, expected "
                         f"{TRANSLOG_VERSION}")
    names = tuple(str(s) for s in d["feature_names"])
    if names != FEATURE_NAMES:
        raise ValueError(f"{path}: feature schema mismatch "
                         f"({names} != {FEATURE_NAMES})")


def load_translog(path: str) -> Dict[str, np.ndarray]:
    """Load one shard, schema-checked against this build's features."""
    with np.load(path, allow_pickle=False) as z:
        d = {k: z[k] for k in z.files}
    _check_schema(d, path)
    return d


def load_shards(paths: Sequence[str]) -> Dict[str, np.ndarray]:
    """Concatenate many shards into one training dict.  Per-shard ``cell``
    indices are rebased onto a merged ``cell_keys`` table, so the
    (app, system) held-out split works across shard boundaries."""
    if not paths:
        raise ValueError("no translog shards given")
    merged_keys: List[str] = []
    key_index: Dict[str, int] = {}
    parts: Dict[str, List[np.ndarray]] = {k: [] for k in _ARRAY_KEYS}
    for path in paths:
        d = load_translog(path)
        for k in d["cell_keys"]:
            key_index.setdefault(str(k), len(key_index))
        remap = np.array([key_index[str(k)] for k in d["cell_keys"]],
                         np.int32)
        for k in _ARRAY_KEYS:
            arr = d[k]
            if k == "cell" and len(arr):
                arr = remap[arr]
            parts[k].append(arr)
    merged_keys = [k for k, _ in sorted(key_index.items(),
                                        key=lambda kv: kv[1])]
    out = {k: np.concatenate(v) if v else np.zeros(0) for k, v in
           parts.items()}
    out["cell_keys"] = np.array(merged_keys)
    out["feature_names"] = np.array(FEATURE_NAMES)
    out["version"] = np.int64(TRANSLOG_VERSION)
    return out
