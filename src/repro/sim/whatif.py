"""Loop-instance candidate pricing for simulation-assisted selection.

:class:`LoopWhatIf` is the DES-side *candidate simulator* behind
``repro.core.simpolicy``: a replay lane binds the current loop profile with
``set_context`` before consulting its policy, and ``price`` evaluates every
candidate (algorithm x chunk-parameter variant) through ONE
``SimBackend.run_batch`` call on a noise-free copy of the machine model —
deterministic predictions whose argmin coincides with the Oracle's choice on
noise-free cells (test-enforced on both backends).

Pricing never touches the lane's live rng stream: candidate runs draw from a
fixed stateless seed, so wiring a ``SimPolicy`` lane into a lockstep replay
leaves every other lane — and the lane's own noise trajectory — bit-exact.

Perturbation awareness: ``set_context`` also accepts the step's resolved
:class:`~repro.sim.backends.base.InstancePerturb`.  The default pricer stays
deliberately BLIND to it — a surrogate is calibrated against the nominal
machine, and unannounced perturbations are exactly the drift the reactive
policies must detect from live feedback.  With ``two_pass=True`` the pricer
runs the two-pass adaptive-surrogate scheme instead: a clean pass first
(kept in :attr:`last_clean` — the AWF/mAF weight re-estimation baseline),
then a perturbed re-simulation whose prices are returned (the ``AwareSim``
lane wiring).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional, Sequence

from ..core import exp_chunk
from ..core.api import Observation
from ..core.simpolicy import Candidate, SimUnavailable
from .backends import InstancePerturb, InstanceSpec, get_backend
from .workloads import profile_digest

#: constant stateless seed for candidate pricing runs (the noise-free system
#: leaves almost nothing for it to draw; determinism is what matters)
_PRICE_SEED = (0x51A5,)

#: priced candidate sets kept per (profile, chunk-context) — sphynx-style
#: time-varying apps produce one entry per time step, so bound it
_CACHE_SIZE = 512


def noise_free(system):
    """The deterministic twin of a machine model: same dispatch overheads and
    locality costs, zero stochastic terms (persistent ``pe_speeds``
    heterogeneity is *kept* — it is structure, not noise)."""
    return dataclasses.replace(system, noise_sigma=0.0, jitter=0.0,
                               speed_spread=0.0)


class LoopWhatIf:
    """Prices ``SimPolicy`` candidates for DES loop instances.

    One instance serves a whole replay lane: the lane re-binds the current
    loop with ``set_context(profile, chunk_param, perturb)`` before each
    decision and every candidate is evaluated against that context.
    ``backend`` is any ``get_backend`` name/instance (the lane's
    ``sim_backend``); with the batched JAX engine the full candidate set is
    one vmapped call.
    """

    def __init__(self, system, backend=None, deterministic: bool = True,
                 two_pass: bool = False):
        self.bk = get_backend(backend)
        self.system = noise_free(system) if deterministic else system
        self.two_pass = bool(two_pass)
        self._profile = None
        self._chunk_param = 0
        self._perturb: Optional[InstancePerturb] = None
        #: clean-pass prices from the last two-pass ``price`` call (the
        #: adaptive-surrogate baseline); None outside two-pass operation
        self.last_clean: Optional[List[Observation]] = None
        self._cache: "OrderedDict[tuple, List[Observation]]" = OrderedDict()

    # -- context ------------------------------------------------------------
    def set_context(self, profile, chunk_param: int = 0,
                    perturb: Optional[InstancePerturb] = None) -> None:
        """Bind the loop instance the next ``price`` calls are about."""
        self._profile = profile
        self._chunk_param = int(chunk_param)
        self._perturb = None if (perturb is not None
                                 and perturb.neutral) else perturb

    # -- the candidate-simulator protocol -----------------------------------
    def candidates(self) -> List[Candidate]:
        """All 12 algorithms under the context's default chunk parameter,
        plus their expChunk variants when that differs — LB4OMP's full
        selection portfolio."""
        if self._profile is None:
            raise SimUnavailable("LoopWhatIf has no loop context bound")
        from ..core import N_ALGORITHMS
        out = [Candidate(a) for a in range(N_ALGORITHMS)]
        ec = exp_chunk(self._profile.N, self.system.P)
        if ec != self._chunk_param:
            out += [Candidate(a, ec) for a in range(N_ALGORITHMS)]
        return out

    def _priced(self, p, resolved, perturb: Optional[InstancePerturb]
                ) -> List[Observation]:
        # profile_digest covers the prefix-grid *content* — mean-normalized
        # patterns share N*unit totals across time steps, so cheap fields
        # alone would alias genuinely different load distributions.  The
        # perturbation key keeps perturbed prices from aliasing clean ones.
        key = (p.name, profile_digest(p), p.unit, p.memory_bound,
               p.locality_sens, p.c_loc, resolved,
               None if perturb is None else perturb.key())
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            return hit
        specs = [InstanceSpec(profile_id=0, alg=a, chunk_param=cp,
                              seed=_PRICE_SEED + (a, cp), perturb=perturb)
                 for a, cp in resolved]
        res = self.bk.run_batch([p], self.system, specs)
        out = [Observation(loop_time=float(t), lib=float(b))
               for t, b in zip(res.loop_time, res.lib)]
        self._cache[key] = out
        if len(self._cache) > _CACHE_SIZE:
            self._cache.popitem(last=False)
        return out

    def price(self, cands: Sequence[Candidate]) -> List[Observation]:
        """Predicted (loop_time, lib) per candidate via one batched
        noise-free ``run_batch`` on the configured backend (two when
        ``two_pass`` is on under an active perturbation)."""
        if self._profile is None:
            raise SimUnavailable("LoopWhatIf has no loop context bound")
        p = self._profile
        resolved = tuple(
            (c.alg, self._chunk_param if c.chunk_param is None
             else int(c.chunk_param)) for c in cands)
        if self.two_pass and self._perturb is not None:
            # two-pass adaptive surrogate: simulate clean, let the backend
            # re-estimate the adaptive algorithms' per-PE weights from the
            # perturbed speeds, re-simulate perturbed; the clean pass is the
            # re-estimation baseline callers can diff against
            self.last_clean = self._priced(p, resolved, None)
            return self._priced(p, resolved, self._perturb)
        # default pricer: BLIND to execution-side perturbations (a surrogate
        # is calibrated against the nominal machine; unannounced slowdowns
        # are exactly what the reactive policies must detect live)
        self.last_clean = None
        return self._priced(p, resolved, None)
