"""Workload models for the paper's six applications (Table 2, §4.1).

Each application exposes ``loops(t)`` — the per-time-step list of
``LoopProfile``s for its modified OpenMP loops.  Iteration costs are carried
as a *prefix-sum grid* (G buckets, linear interpolation) so that chunk costs
over arbitrary ranges are O(1) regardless of N (STREAM has N = 2e9).

The cost *patterns* implement the imbalance characters stated in Table 2:

    Mandelbrot  L0 constant / L1 increasing / L2 decreasing imbalance
    STREAM      uniform, fully memory-bound
    TC          power-law head (sorted Kronecker degrees) — severe imbalance
    HACCKernels uniform, compute-bound
    LULESH      4 loops, mild imbalance, mixed memory/compute
    SPHYNX      evolving imbalance across time-steps (gravity loop)
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

GRID = 16384  # prefix-grid resolution


def profile_digest(p) -> tuple:
    """Content key of one profile, memoized on the profile — shared by every
    per-profile cache (the JAX backend's device-grid uploads, the what-if
    candidate pricer).

    Profiles are treated as immutable (the repo's ``Application`` classes
    rebuild ``LoopProfile`` objects rather than mutating them) — the
    expensive blake2b over a 64 KB grid runs once per object.  The cheap
    fields (``N``, ``total``, the grid tail) ride along in the key as a
    partial guard, but mutating ``prefix_grid`` in place after first use
    is unsupported: rebuild the profile instead.
    """
    if p.prefix_grid is None:
        return (p.N, p.total)
    memo = getattr(p, "_grid_blake", None)
    if memo is None or memo[0] is not p.prefix_grid:     # rebound array
        memo = (p.prefix_grid, hashlib.blake2b(
            np.ascontiguousarray(p.prefix_grid).tobytes(),
            digest_size=16).digest())
        try:
            p._grid_blake = memo
        except Exception:   # pragma: no cover - exotic read-only profiles
            pass
    # N/total/tail read live so they guard the cheap mutations too
    return (p.N, p.total, float(p.prefix_grid[-1]), memo[1])


def stack_prefix_grids(profiles) -> np.ndarray:
    """(S, GRID+1) float32 stacked cumulative-cost grids — the device-ready
    form the batched backend gathers from.  Uniform profiles synthesize a
    linear ramp at the shared resolution so one interpolation serves all."""
    rows = np.zeros((len(profiles), GRID + 1), np.float32)
    for i, p in enumerate(profiles):
        if p.prefix_grid is None:
            rows[i] = np.linspace(0.0, p.total, GRID + 1, dtype=np.float32)
        else:
            assert len(p.prefix_grid) == GRID + 1, "mixed grid resolutions"
            rows[i] = p.prefix_grid
    return rows


@dataclass
class LoopProfile:
    """Cost model of one parallel loop at one time-step."""

    name: str
    N: int
    memory_bound: float                 # 0 = compute-bound .. 1 = STREAM
    locality_sens: float = 0.0          # spatial-reuse sensitivity (small-chunk
                                        # locality loss; 0 = random access)
    c_loc: int = 64                     # reuse window in iterations
    unit: float = 0.0                   # mean per-iteration cost (s)
    prefix_grid: Optional[np.ndarray] = None   # (GRID+1,) cumulative cost, or None = uniform
    total: float = 0.0

    def __post_init__(self):
        if self.prefix_grid is None:
            self.total = self.N * self.unit
        else:
            self.total = float(self.prefix_grid[-1])

    def prefix(self, x):
        """Cumulative cost of iterations [0, x). Vectorized; x in [0, N]."""
        if self.prefix_grid is None:
            return np.asarray(x, dtype=np.float64) * self.unit
        pos = np.asarray(x, dtype=np.float64) * (GRID / self.N)
        return np.interp(pos, np.arange(GRID + 1), self.prefix_grid)

    def range_cost(self, a, b):
        return self.prefix(b) - self.prefix(a)

    @property
    def uniform(self) -> bool:
        return self.prefix_grid is None


def _grid_from_pattern(pattern: np.ndarray, N: int, unit: float) -> np.ndarray:
    """pattern: (GRID,) relative per-bucket cost density, mean-normalized."""
    density = pattern / pattern.mean()
    bucket_cost = density * (N / GRID) * unit
    return np.concatenate([[0.0], np.cumsum(bucket_cost)])


@dataclass
class ProfileStack:
    """Device-ready view of an application's loops over a window of
    time-steps: the flattened profile list plus the stacked prefix grids
    the batched backend gathers from (one row per (t, loop), uniform
    profiles synthesized as linear ramps at the shared resolution).

    ``pid(t, li)`` maps a (time-step, loop-index) pair to its row.
    """

    profiles: List[LoopProfile]
    n_loops: int

    def pid(self, t: int, li: int) -> int:
        return t * self.n_loops + li

    def grids(self) -> np.ndarray:
        """(S, GRID+1) float32 stacked cumulative-cost grids."""
        return stack_prefix_grids(self.profiles)


class Application:
    name: str = "app"
    T: int = 500
    loop_names: List[str] = []
    time_invariant: bool = False  # loops(t) identical for all t

    def loops(self, t: int) -> List[LoopProfile]:  # pragma: no cover
        raise NotImplementedError

    def profile_stack(self, T: Optional[int] = None) -> ProfileStack:
        """Flatten ``loops(t)`` for t in [0, T) into a ``ProfileStack``."""
        T = T or self.T
        profiles: List[LoopProfile] = []
        for t in range(T):
            profiles.extend(self.loops(t))
        return ProfileStack(profiles=profiles, n_loops=len(self.loop_names))


class Mandelbrot(Application):
    """Compute-bound, N = 262'144, 3 loops: constant / increasing / decreasing
    workload imbalance (the loops 'zoom' into different set regions)."""

    name = "mandelbrot"
    N = 262_144
    T = 500
    loop_names = ["L0", "L1", "L2"]
    UNIT = 2.0e-6

    def __init__(self):
        x = np.linspace(0.0, 1.0, GRID)
        # escape-iteration-like bumps at different set regions
        self._bump0 = np.exp(-((x - 0.35) / 0.08) ** 2)
        self._bump1 = np.exp(-((x - 0.62) / 0.05) ** 2)
        self._bump2 = np.exp(-((x - 0.18) / 0.06) ** 2)

    def loops(self, t: int) -> List[LoopProfile]:
        frac = t / max(1, self.T - 1)
        amps = (6.0,                  # L0: constant imbalance
                0.5 + 11.0 * frac,    # L1: increasing
                11.5 - 11.0 * frac)   # L2: decreasing
        bumps = (self._bump0, self._bump1, self._bump2)
        out = []
        for nm, a, b in zip(self.loop_names, amps, bumps):
            pattern = 1.0 + a * b
            out.append(LoopProfile(
                name=nm, N=self.N, memory_bound=0.0, locality_sens=0.0,
                unit=self.UNIT,
                prefix_grid=_grid_from_pattern(pattern, self.N, self.UNIT)))
        return out


class StreamTriad(Application):
    """Memory-bound, N = 2e9, perfectly regular."""

    name = "stream"
    N = 2_000_000_000
    T = 500
    loop_names = ["L0"]
    UNIT = 2.0e-9   # ~24 B/iter over per-thread effective bandwidth
    time_invariant = True

    def loops(self, t: int) -> List[LoopProfile]:
        return [LoopProfile(name="L0", N=self.N, memory_bound=1.0,
                            locality_sens=0.3, c_loc=512, unit=self.UNIT)]


class TriangleCounting(Application):
    """Graph kernel, N = 2^20, severe power-law imbalance (degree-sorted
    Kronecker graph: the heavy vertices form a contiguous head)."""

    name = "tc"
    N = 1_048_576
    T = 500
    loop_names = ["L0"]
    UNIT = 5.0e-6
    time_invariant = True

    def __init__(self):
        i = np.arange(GRID, dtype=np.float64)
        # cost ~ d_u^2 for degree-sorted Kronecker: heavy head spread over the
        # first few percent of vertices (interleaving CAN balance it)
        pattern = 1.0 + 120.0 * (i + 1.0) ** -0.7
        self._grid = _grid_from_pattern(pattern, self.N, self.UNIT)

    def loops(self, t: int) -> List[LoopProfile]:
        # graph traversal: access pattern is random regardless of chunking
        return [LoopProfile(name="L0", N=self.N, memory_bound=0.2,
                            locality_sens=0.0, unit=self.UNIT,
                            prefix_grid=self._grid)]


class HACCKernels(Application):
    """Compute-bound short-range force kernel, N = 600'000, no imbalance."""

    name = "hacc"
    N = 600_000
    T = 500
    loop_names = ["L0"]
    UNIT = 2.0e-5   # short-range force kernel: ~20us per particle-pair set
    time_invariant = True

    def loops(self, t: int) -> List[LoopProfile]:
        return [LoopProfile(name="L0", N=self.N, memory_bound=0.0,
                            locality_sens=0.05, c_loc=64, unit=self.UNIT)]


class Lulesh(Application):
    """Hydrodynamics mini-app: 4 loops over 5'488'000 elements each (Table 2's
    21'952'000 total across the modified loops), mild imbalance, mixed
    memory/compute behavior."""

    name = "lulesh"
    N = 5_488_000
    T = 500
    loop_names = ["CalcFBHourglass", "CalcHourglassCtl", "CalcKinematics",
                  "IntegrateStress"]
    UNIT = 4.0e-8

    def __init__(self):
        rng = np.random.default_rng(1234)
        self._patterns = [1.0 + 0.12 * rng.random(GRID) for _ in range(4)]
        self._mb = [0.7, 0.6, 0.5, 0.65]

    def loops(self, t: int) -> List[LoopProfile]:
        return [LoopProfile(name=nm, N=self.N, memory_bound=mb,
                            locality_sens=0.7, c_loc=64, unit=self.UNIT,
                            prefix_grid=_grid_from_pattern(p, self.N, self.UNIT))
                for nm, p, mb in zip(self.loop_names, self._patterns, self._mb)]


class Sphynx(Application):
    """SPH Evrard collapse: gravity loop over 1e6 particles, variable and
    *evolving* load imbalance across time-steps (particle clustering)."""

    name = "sphynx"
    N = 1_000_000
    T = 500
    loop_names = ["gravity"]
    UNIT = 2.0e-5

    def __init__(self):
        x = np.linspace(0.0, 1.0, GRID)
        self._x = x

    def loops(self, t: int) -> List[LoopProfile]:
        frac = t / max(1, self.T - 1)
        # clusters drift and sharpen as the collapse evolves
        c1 = 0.3 + 0.25 * frac
        c2 = 0.75 - 0.15 * math.sin(2.0 * math.pi * frac)
        w = 0.18 - 0.10 * frac
        amp = 3.0 + 5.0 * frac + 1.5 * math.sin(6.0 * math.pi * frac)
        pattern = (0.4 + amp * np.exp(-((self._x - c1) / max(w, 0.03)) ** 2)
                   + 0.7 * amp * np.exp(-((self._x - c2) / 0.12) ** 2))
        # neighbor-list reuse window is short (~2 dozen particles)
        return [LoopProfile(name="gravity", N=self.N, memory_bound=0.15,
                            locality_sens=0.8, c_loc=24, unit=self.UNIT,
                            prefix_grid=_grid_from_pattern(pattern, self.N,
                                                           self.UNIT))]


APPLICATIONS: Dict[str, type] = {
    "mandelbrot": Mandelbrot,
    "stream": StreamTriad,
    "tc": TriangleCounting,
    "hacc": HACCKernels,
    "lulesh": Lulesh,
    "sphynx": Sphynx,
}


def get_application(name: str) -> Application:
    return APPLICATIONS[name]()
