"""Deterministic stand-in for the tiny slice of hypothesis the suite uses.

``hypothesis`` is an optional dev extra (``pip install -e .[dev]``).  When
it is absent, property tests degrade to an exhaustive sweep over a small
deterministic grid drawn from each strategy's bounds — weaker than random
property testing, but the invariants still get exercised and the tier-1
suite stays runnable on a bare ``numpy + jax`` image.

Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""

from __future__ import annotations

import itertools


class _Strategy:
    def __init__(self, values):
        self.values = list(values)


class _St:
    @staticmethod
    def integers(min_value, max_value):
        lo, hi = min_value, max_value
        mid = (lo + hi) // 2
        return _Strategy(sorted({lo, min(lo + 1, hi), mid,
                                 max(hi - 1, lo), hi}))

    @staticmethod
    def floats(min_value, max_value, **_ignored):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(sorted({lo, (lo + hi) / 2, hi}))

    @staticmethod
    def lists(elems, min_size=0, max_size=8):
        """A deterministic spread of lists over the element strategy's
        values: cycled, reversed-cycle, and constant-extreme fills at the
        size bounds."""
        vals = list(elems.values)
        out = []
        for size in sorted({min_size, (min_size + max_size) // 2, max_size}):
            cyc = [vals[i % len(vals)] for i in range(size)]
            out.extend([cyc, cyc[::-1],
                        [vals[0]] * size, [vals[-1]] * size])
        # dedupe while preserving order
        seen, uniq = set(), []
        for lst in out:
            key = tuple(lst)
            if key not in seen:
                seen.add(key)
                uniq.append(lst)
        return _Strategy(uniq)

    @staticmethod
    def sampled_from(seq):
        return _Strategy(seq)

    @staticmethod
    def booleans():
        return _Strategy([False, True])


st = _St()


def given(**params):
    def deco(fn):
        def run():
            keys = list(params)
            for combo in itertools.product(*(params[k].values for k in keys)):
                fn(**dict(zip(keys, combo)))
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco


def settings(**kw):
    return lambda fn: fn
