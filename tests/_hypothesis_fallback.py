"""Deterministic stand-in for the tiny slice of hypothesis the suite uses.

``hypothesis`` is an optional dev extra (``pip install -e .[dev]``).  When
it is absent, property tests degrade to an exhaustive sweep over a small
deterministic grid drawn from each strategy's bounds — weaker than random
property testing, but the invariants still get exercised and the tier-1
suite stays runnable on a bare ``numpy + jax`` image.

Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""

from __future__ import annotations

import itertools


class _Strategy:
    def __init__(self, values):
        self.values = list(values)


class _St:
    @staticmethod
    def integers(lo, hi):
        mid = (lo + hi) // 2
        return _Strategy(sorted({lo, min(lo + 1, hi), mid,
                                 max(hi - 1, lo), hi}))

    @staticmethod
    def sampled_from(seq):
        return _Strategy(seq)

    @staticmethod
    def booleans():
        return _Strategy([False, True])


st = _St()


def given(**params):
    def deco(fn):
        def run():
            keys = list(params)
            for combo in itertools.product(*(params[k].values for k in keys)):
                fn(**dict(zip(keys, combo)))
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco


def settings(**kw):
    return lambda fn: fn
