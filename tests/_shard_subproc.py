"""Sharded-vs-single-device bit-equality checks, run in a subprocess.

``tests/test_shard.py`` (and the ``bench_shard`` smoke lane) execute this
script under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the
script also forces the flag itself when unset, so it can only run as a
fresh process (jax reads XLA_FLAGS once at import).  Everything here must
be *bit*-equal — lanes are embarrassingly parallel, so putting them under
``shard_map`` (including padding to non-divisible mesh extents) must not
change a single ulp of any campaign statistic.

Prints ``SHARD-OK`` and exits 0 on success.
"""

from __future__ import annotations

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402


def _policy_states(run):
    out = {}
    for nm in run.history:
        policy = run.service.policy(nm)
        state = policy.state_dict()
        if state is None:
            expert = getattr(policy, "_expert", policy)
            state = {"current": getattr(expert, "current", None)}
        out[nm] = state
    return out


def check_run_batch(backends) -> None:
    """Portfolio sweep (run_batch fan-out) across mesh extents."""
    from repro.sim import sweep_portfolio

    ref = None
    for label, bk in backends:
        sweep = sweep_portfolio("sphynx", "epyc", T=3, reps=2, backend=bk)
        if ref is None:
            ref = sweep
            continue
        for key in ref.runs:
            assert (sweep.runs[key].times == ref.runs[key].times).all() \
                and (sweep.runs[key].libs == ref.runs[key].libs).all(), \
                (label, key)
    print("run_batch: bit-equal across", [l for l, _ in backends])


def check_lockstep(backends) -> None:
    """Lockstep selector replays: totals, selection traces AND per-loop
    policy state (Q-tables) must be identical across mesh extents."""
    from repro.sim import CellSpec, ReplayBatch

    lanes = [CellSpec("tc", "epyc", sel, mode, reward)
             for mode in ("default", "expChunk")
             for sel, reward in (("RandomSel", None), ("ExhaustiveSel", None),
                                 ("ExpertSel", None), ("QLearn", "LT"),
                                 ("QLearn", "LIB"), ("SARSA", "LIB"),
                                 ("Hybrid", "LT"))]
    ref = None
    for label, bk in backends:
        runs = ReplayBatch(lanes, T=4, seed=0, backend=bk).run()
        if ref is None:
            ref = runs
            continue
        for run, rf, spec in zip(runs, ref, lanes):
            assert run.total == rf.total, (label, spec)
            assert run.history == rf.history, (label, spec)
            assert _policy_states(run) == _policy_states(rf), (label, spec)
    print("lockstep replay: Q-tables/traces bit-equal across",
          [l for l, _ in backends], f"({len(lanes)} lanes)")


def check_what_if(backends) -> None:
    """Serving what-if pricing: wave and fleet-route candidate rows,
    including candidate counts that do NOT divide the mesh extent."""
    rng = np.random.default_rng(7)
    prefixes = [np.concatenate([[0.0], np.cumsum(rng.random(96 + 31 * i)
                                                 * 1e-3)])
                for i in range(3)]
    avails = [rng.random(8) * 1e-3 for _ in range(3)]
    # 3 slots x 4 algs - 1 = 11 rows: indivisible by 8, 4 and 3 alike
    cands = [(s, a, cp) for s in range(3) for a, cp in
             ((0, 0), (2, 0), (4, 8), (6, 0))][:-1]
    ref_r = ref_w = None
    for label, bk in backends:
        routes = bk.what_if_routes(prefixes, 8, avails, 2e-4, 1e-3, cands)
        wave = bk.what_if_wave(prefixes[0], 8, avails[0], 2e-4, 1e-3,
                               list(range(12)))
        if ref_r is None:
            ref_r, ref_w = routes, wave
            continue
        assert (routes == ref_r).all(), (label, "routes")
        assert (wave == ref_w).all(), (label, "wave")
    print(f"what_if_routes/wave: {len(cands)}-candidate prices bit-equal "
          "across", [l for l, _ in backends])


def main() -> None:
    import jax

    from repro.sim.backends.jax_batched import JaxBatchedBackend

    n = jax.device_count()
    assert n >= 2, f"need multiple devices, got {n} (XLA_FLAGS not applied?)"
    # d=1: the unsharded reference; d=n: every virtual device; d=3 (when it
    # does not divide the padded pow2 lane buckets) exercises the padding /
    # masking edge; async off re-checks the synchronous drain path
    backends = [
        ("d1", JaxBatchedBackend(data_parallel=1)),
        (f"d{n}", JaxBatchedBackend(data_parallel=n)),
        ("d3", JaxBatchedBackend(data_parallel=3)),
        (f"d{n}-sync", JaxBatchedBackend(data_parallel=n,
                                         async_dispatch=False)),
    ]
    assert backends[1][1].mesh is not None, "mesh did not form"
    check_run_batch(backends)
    check_lockstep(backends)
    check_what_if(backends)
    print("SHARD-OK")


if __name__ == "__main__":
    main()
