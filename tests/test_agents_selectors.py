"""RL agents (Q-Learn / SARSA), Eq. 11 rewards, explore-first policy, and
the expert-based selectors."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (ExhaustiveSel, QLearnAgent, RandomSel, RewardTracker,
                        SarsaAgent, SelectionService, explore_first_sequence,
                        make_selector, REWARD_POSITIVE, REWARD_NEUTRAL,
                        REWARD_NEGATIVE)


# ---------------------------------------------------------------------------
# explore-first
# ---------------------------------------------------------------------------

def test_explore_first_covers_all_144_pairs():
    seq = explore_first_sequence(12, start=0)
    assert len(seq) == 144                     # paper: 144 learning instances
    pairs = set()
    s = 0
    for a in seq:
        pairs.add((s, a))
        s = a
    assert len(pairs) == 144                   # every (state, action) once


@given(n=st.integers(2, 16), start=st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_explore_first_eulerian_any_n(n, start):
    start = start % n
    seq = explore_first_sequence(n, start=start)
    assert len(seq) == n * n
    s, pairs = start, set()
    for a in seq:
        pairs.add((s, a))
        s = a
    assert len(pairs) == n * n


# ---------------------------------------------------------------------------
# Eq. 11 reward
# ---------------------------------------------------------------------------

def test_reward_eq11():
    rt = RewardTracker()
    assert rt.reward(10.0) == REWARD_POSITIVE      # first observation
    assert rt.reward(5.0) == REWARD_POSITIVE       # new min
    assert rt.reward(7.0) == REWARD_NEUTRAL        # between extrema
    assert rt.reward(10.0) == REWARD_NEGATIVE      # >= max
    assert rt.reward(5.0) == REWARD_POSITIVE       # == min -> positive
    assert rt.reward(100.0) == REWARD_NEGATIVE


def test_reward_values_match_paper():
    assert REWARD_POSITIVE == 0.01   # distinguishable from 0-initialized Q
    assert REWARD_NEUTRAL == -2.0
    assert REWARD_NEGATIVE == -4.0


# ---------------------------------------------------------------------------
# agents
# ---------------------------------------------------------------------------

def run_bandit(agent_cls, best=3, T=400, noise=0.0, seed=0, spread=1.0):
    sel = agent_cls()
    rng = np.random.default_rng(seed)
    for _ in range(T):
        a = sel.select()
        x = 1.0 + spread * abs(a - best) + rng.normal(0, noise)
        sel.observe(a, x)
    return sel


def test_qlearn_defaults_match_paper():
    a = QLearnAgent()
    assert a.alpha == 0.5 and a.gamma == 0.5 and a.alpha_decay == 0.05
    assert a.q.shape == (12, 12)
    assert (a.q == 0).all()
    assert a.learning_steps == 144


def test_qlearn_learning_phase_is_144():
    a = QLearnAgent()
    for t in range(144):
        assert a.learning
        act = a.select()
        a.observe(act, 1.0)
    assert not a.learning


def test_qlearn_finds_strong_optimum():
    """With order-of-magnitude gaps (the paper's STREAM case), Q-Learn
    selects the best algorithm after the learning phase (claim C1)."""
    sel = run_bandit(QLearnAgent, best=5, T=300, noise=0.0, spread=50.0)
    assert sel.select() == 5


def test_sarsa_update_rule():
    a = SarsaAgent(n_actions=3)
    # force deterministic single update
    a._explore = [1, 2]
    a.state = 0
    act = a.select()
    assert act == 1
    a.observe(1, 100.0)   # first obs -> r+ = 0.01; bootstrap Q(1, 2) = 0
    assert a.q[0, 1] == pytest.approx(0.5 * 0.01)


def test_qlearn_update_rule():
    a = QLearnAgent(n_actions=3)
    a._explore = [1, 2]
    a.state = 0
    a.q[1, 0] = 7.0   # max bootstrap source
    a.observe(1, 50.0)
    assert a.q[0, 1] == pytest.approx(0.5 * (0.01 + 0.5 * 7.0))


def test_alpha_decay_after_learning():
    a = QLearnAgent(n_actions=2)   # learning = 4 steps
    for _ in range(4):
        a.observe(a.select(), 1.0)
    assert a.alpha == 0.5
    a.observe(a.select(), 1.0)
    assert a.alpha == pytest.approx(0.45)


# ---------------------------------------------------------------------------
# expert selectors
# ---------------------------------------------------------------------------

def test_exhaustive_selects_argmin_and_retriggers():
    sel = ExhaustiveSel()
    for t in range(12):
        a = sel.select()
        assert a == t                      # portfolio order
        sel.observe(a, 1.0 + 0.1 * abs(a - 4), lib=3.0)
    assert sel.select() == 4
    # stable LIB: stays
    for _ in range(5):
        sel.observe(sel.select(), 1.0, lib=10.0)
    assert sel.select() == 4
    # big LIB drift: re-triggers the search
    sel.observe(sel.select(), 1.0, lib=50.0)
    assert sel.select() == 0


def test_randomsel_jump_probability():
    sel = RandomSel(seed=0)
    sel.observe(0, 1.0, lib=0.0)       # P_j = 0 -> never jump
    picks = {sel.select() for _ in range(20)}
    assert len(picks) == 1
    sel.observe(0, 1.0, lib=50.0)      # P_j = 5 > 1 -> always jump
    picks = [sel.select() for _ in range(50)]
    assert len(set(picks)) > 3


def test_expertsel_moves_toward_adaptive_under_imbalance():
    sel = make_selector("expert")
    assert sel.select() == 0           # DLS_0 = STATIC first
    sel.observe(0, 1.0, lib=80.0)      # severe imbalance
    assert sel.select() >= 7           # jumps to the adaptive end


def test_selection_service_isolates_loops():
    svc = SelectionService("qlearn", reward_type="LT")
    a0 = svc.begin("L0")
    svc.end("L0", a0, 1.0, 0.0)
    svc.begin("L1")
    assert len(svc.history("L0")) == 1
    assert len(svc.history("L1")) == 0
    assert set(svc.regions) == {"L0", "L1"}


def test_selector_generalizes_to_plan_portfolios():
    sel = make_selector("exhaustive", n_actions=5)
    for t in range(5):
        a = sel.select()
        sel.observe(a, 1.0 + abs(a - 2), 0.0)
    assert sel.select() == 2
