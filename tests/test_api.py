"""Structured selection API: reward registry dispatch, Hybrid expert+RL,
SelectionService v2 (instance context manager, overrides, stable seeds),
and the paper-§5 warm-start roundtrip through the service."""

import numpy as np
import pytest

from repro.core import (Decision, HybridPolicy, Observation, QLearnPolicy,
                        SelectionService, get_reward, make_policy,
                        register_reward, reward_names, system_fingerprint)


# ---------------------------------------------------------------------------
# synthetic imbalanced workload: adaptive algorithms (>= 7) fix a severe
# imbalance; cost valley at algorithm 9 (the paper's STREAM-like regime)
# ---------------------------------------------------------------------------

BEST = 9


def synthetic_obs(action: int, t: int, noise: float = 0.0,
                  rng=None) -> Observation:
    cost = 1.0 + 0.3 * abs(action - BEST)
    if noise and rng is not None:
        cost += rng.normal(0.0, noise)
    lib = 5.0 if action >= 7 else 60.0
    return Observation(loop_time=cost, lib=lib, instance=t)


def drive(policy, T=400, noise=0.0, seed=0):
    rng = np.random.default_rng(seed)
    explored = 0
    for t in range(T):
        d = policy.decide()
        if d.phase in ("expert", "explore"):
            explored += 1
        policy.feedback(d, synthetic_obs(d.action, t, noise, rng))
    return policy.decide(), explored


# ---------------------------------------------------------------------------
# reward registry
# ---------------------------------------------------------------------------

def test_builtin_rewards_registered():
    for name in ("LT", "LIB", "p95", "throughput", "LT+LIB"):
        assert name.lower() in reward_names()


def test_reward_dispatch_extracts_the_right_signal():
    obs = Observation(loop_time=2.0, lib=40.0, throughput=100.0,
                      tail_latency=3.5)
    assert get_reward("LT")(obs) == 2.0
    assert get_reward("LIB")(obs) == 40.0
    assert get_reward("p95")(obs) == 3.5
    assert get_reward("throughput")(obs) == -100.0
    assert get_reward("LT+LIB")(obs) == pytest.approx(2.0 * 1.4)


def test_reward_fallbacks_without_rich_signals():
    obs = Observation(loop_time=2.0, lib=10.0)
    assert get_reward("p95")(obs) == 2.0          # falls back to loop time
    assert get_reward("throughput")(obs) == 2.0
    pe = Observation(loop_time=4.0, pe_times=(1.0, 2.0, 4.0))
    assert get_reward("p95")(pe) == pytest.approx(
        np.percentile([1.0, 2.0, 4.0], 95))


def test_register_custom_reward_and_use_by_name():
    @register_reward("test-geo")
    def geo(obs):
        return obs.loop_time * (1.0 + obs.lib / 50.0)

    assert get_reward("TEST-GEO") is geo          # case-insensitive
    policy = make_policy("qlearn", reward="test-geo", n_actions=3)
    d = policy.decide()
    policy.feedback(d, Observation(loop_time=1.0, lib=25.0))
    assert policy.agent.reward.count == 1         # signal reached Eq. 11


def test_unknown_reward_raises():
    with pytest.raises(ValueError, match="unknown reward"):
        make_policy("qlearn", reward="nope")


def test_decision_chunk_param_defaults():
    d = Decision(action=3)
    assert d.with_instance_defaults(64).chunk_param == 64
    steered = Decision(action=3, chunk_param=8)
    assert steered.with_instance_defaults(64).chunk_param == 8


def test_observation_from_pe_times():
    obs = Observation.from_pe_times([1.0, 2.0, 3.0], instance=7)
    assert obs.loop_time == 3.0
    assert obs.lib == pytest.approx((1.0 - 2.0 / 3.0) * 100.0)
    assert obs.instance == 7


# ---------------------------------------------------------------------------
# policies through the structured protocol
# ---------------------------------------------------------------------------

def test_every_policy_name_builds_and_decides():
    for name in ("Fixed", "RandomSel", "ExhaustiveSel", "ExpertSel",
                 "QLearn", "SARSA", "Hybrid"):
        kw = {"algorithm": 2} if name == "Fixed" else {"seed": 3}
        p = make_policy(name, **kw)
        d = p.decide()
        assert isinstance(d, Decision)
        assert 0 <= d.action < 12
        p.feedback(d, synthetic_obs(d.action, 0))


def test_decision_phases_progress_explore_to_exploit():
    p = QLearnPolicy(n_actions=3)
    phases = []
    for t in range(12):
        d = p.decide()
        phases.append(d.phase)
        p.feedback(d, synthetic_obs(d.action, t))
    assert phases[:9] == ["explore"] * 9          # 3*3 explore-first
    assert set(phases[9:]) == {"exploit"}


# ---------------------------------------------------------------------------
# HybridPolicy: the paper-§6 combination
# ---------------------------------------------------------------------------

def test_hybrid_explores_less_than_qlearn_and_matches_selection():
    q_final, q_explored = drive(QLearnPolicy(), T=400)
    h = HybridPolicy()
    h_final, h_explored = drive(h, T=400)
    assert h.learning_steps < 144                 # bounded exploration
    assert h_explored < q_explored                # fewer explore instances
    # equal-or-better final selection on the imbalanced workload
    cost = lambda a: 1.0 + 0.3 * abs(a - BEST)
    assert cost(h_final.action) <= cost(q_final.action)
    assert h_final.action == BEST


def test_hybrid_expert_phase_bounds_rl_to_adaptive_window():
    h = HybridPolicy(expert_steps=4, window=5)
    drive(h, T=60)
    # severe imbalance: the fuzzy ladder must have pushed the RL window
    # into the adaptive end of the portfolio
    assert all(a >= 5 for a in h.actions)
    assert BEST in h.actions
    assert h.learning_steps == 4 + 25


def test_hybrid_robust_to_noise():
    h = HybridPolicy()
    final, _ = drive(h, T=400, noise=0.05, seed=1)
    assert abs(final.action - BEST) <= 1


def test_hybrid_window_clamps_to_portfolio():
    h = HybridPolicy(window=50, n_actions=4, expert_steps=2)
    drive(h, T=30)
    assert h.actions == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# SelectionService v2
# ---------------------------------------------------------------------------

def test_instance_context_manager_records_feedback():
    svc = SelectionService("QLearn", reward="LT")
    with svc.instance("L0") as inst:
        assert isinstance(inst.decision, Decision)
        inst.report(loop_time=1.5, lib=10.0, throughput=64.0)
    assert len(svc.history("L0")) == 1
    assert svc.history("L0")[0][1] == 1.5
    obs = svc._regions["L0"].observations[0]
    assert obs.throughput == 64.0 and obs.instance == 0


def test_instance_without_report_is_a_peek():
    svc = SelectionService("QLearn")
    with svc.instance("L0"):
        pass                                      # decided, never executed
    assert len(svc.history("L0")) == 0
    assert not svc.policy("L0").agent._t          # agent did not advance


def test_instance_report_accepts_pe_times_only():
    svc = SelectionService("SARSA", reward="p95")
    with svc.instance("L0") as inst:
        inst.report(pe_times=[1.0, 2.0, 4.0])
    (_, lt, lib), = svc.history("L0")
    assert lt == 4.0 and lib > 0


def test_history_is_readonly_introspection():
    """history() must not instantiate a region policy as a side effect
    (an Oracle service would crash on a typo'd region otherwise)."""
    svc = SelectionService("Oracle")          # regions come via overrides
    assert svc.history("typo") == []
    assert svc.regions == []
    svc.set_policy("typo", "ExpertSel")       # still free: nothing was built


def test_randomsel_shim_matches_seed_repo_stream():
    """Seeded RandomSel trajectories must be bit-identical to the
    pre-redesign implementation (select rolls, observe only updates LIB)."""
    def reference(seed, libs):
        rng = np.random.default_rng(seed)
        current, lib, out = 0, 100.0, []
        for l in libs:
            if lib / 10.0 > rng.random():
                current = int(rng.integers(0, 12))
            out.append(current)
            lib = l
        return out

    from repro.core import RandomSel
    libs = [0.0, 50.0, 20.0, 5.0, 80.0, 0.0, 30.0]
    sel = RandomSel(seed=7)
    got = []
    for l in libs:
        a = sel.select()
        got.append(a)
        sel.observe(a, 1.0, l)
    assert got == reference(7, libs)


def test_per_region_policy_overrides():
    svc = SelectionService("QLearn", reward="LT",
                           overrides={"io": {"method": "ExhaustiveSel"}})
    svc.set_policy("ladder", "ExpertSel")
    assert svc.policy("io").name == "ExhaustiveSel"
    assert svc.policy("ladder").name == "ExpertSel"
    assert svc.policy("compute").name == "QLearn"
    with pytest.raises(ValueError, match="live policy"):
        svc.set_policy("io", "SARSA")


def test_region_seeds_are_stable_across_services():
    """The old hash((seed, region)) varied per process under salted string
    hashing; the CRC-32 digest must give identical RandomSel streams for
    identical construction."""
    def stream(svc):
        out = []
        for t in range(30):
            with svc.instance("waves") as inst:
                out.append(inst.action)
                inst.report(loop_time=1.0, lib=30.0)
        return out

    a = stream(SelectionService("RandomSel", seed=42))
    b = stream(SelectionService("RandomSel", seed=42))
    c = stream(SelectionService("RandomSel", seed=43))
    assert a == b
    assert a != c


def test_hybrid_by_name_through_service():
    svc = SelectionService("Hybrid", reward="LT", expert_steps=2, window=3)
    for t in range(20):
        with svc.instance("L0") as inst:
            inst.report(observation=synthetic_obs(inst.action, t))
    assert svc.policy("L0").name == "Hybrid"
    assert not svc.policy("L0").learning          # 2 + 9 = 11 < 20


# ---------------------------------------------------------------------------
# warm start through the service (paper §5 end-to-end)
# ---------------------------------------------------------------------------

def train_service(store, region="gravity", T=300):
    svc = SelectionService("QLearn", reward="LT", store_dir=str(store))
    for t in range(T):
        with svc.instance(region) as inst:
            inst.report(observation=synthetic_obs(inst.action, t))
    return svc


def test_service_save_warmstart_roundtrip(tmp_path):
    svc = train_service(tmp_path)
    trained = svc.policy("gravity")
    assert not trained.learning
    paths = svc.save()
    assert len(paths) == 1

    fresh = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path))
    policy = fresh.policy("gravity")
    assert fresh.warm_started("gravity")
    assert not policy.learning                    # 144-instance phase skipped
    d = policy.decide()
    assert d.phase == "exploit"
    assert d.action == trained.decide().action == BEST
    np.testing.assert_allclose(policy.agent.q, trained.agent.q)


def test_service_context_manager_autosaves(tmp_path):
    with train_service(tmp_path) as svc:
        pass                                      # __exit__ persists
    fresh = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path))
    assert fresh.warm_started("gravity")
    # svc.save() was never called explicitly
    assert svc.policy("gravity").decide().action == BEST


def test_warmstart_keyed_by_region_and_system(tmp_path):
    svc = train_service(tmp_path)
    svc.save()
    other_region = SelectionService("QLearn", store_dir=str(tmp_path))
    assert not other_region.warm_started("pressure")
    other_system = SelectionService("QLearn", store_dir=str(tmp_path),
                                    system="deadbeef")
    assert not other_system.warm_started("gravity")
    assert len(system_fingerprint()) == 8


def test_warmstart_ignores_reward_mismatch(tmp_path):
    """A Q-table trained for LT must not warm-start a LIB-objective run."""
    svc = train_service(tmp_path)
    svc.save()
    lib_run = SelectionService("QLearn", reward="LIB",
                               store_dir=str(tmp_path))
    assert not lib_run.warm_started("gravity")
    assert lib_run.policy("gravity").learning


def test_warmstart_shape_mismatch_starts_cold(tmp_path):
    """Growing the portfolio after a snapshot is a cache miss, not a crash
    (and never a silently mis-shaped table)."""
    svc = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path),
                           n_actions=5)
    for t in range(40):
        with svc.instance("plans") as inst:
            inst.report(observation=synthetic_obs(inst.action, t))
    svc.save()
    grown = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path),
                             n_actions=6)
    assert not grown.warm_started("plans")
    assert grown.policy("plans").agent.q.shape == (6, 6)
    assert grown.policy("plans").learning


def test_midlearning_snapshot_resumes_not_freezes(tmp_path):
    """A snapshot saved 5 instances into the 144-step explore phase must
    resume exploration, not freeze a near-empty Q-table into greedy
    exploitation forever."""
    with SelectionService("QLearn", reward="LT",
                          store_dir=str(tmp_path)) as svc:
        for t in range(5):
            with svc.instance("gravity") as inst:
                inst.report(observation=synthetic_obs(inst.action, t))
    resumed = SelectionService("QLearn", reward="LT",
                               store_dir=str(tmp_path))
    policy = resumed.policy("gravity")
    assert policy.learning                        # still exploring
    assert policy.agent._t == 5                   # ...from where it stopped
    assert not resumed.warm_started("gravity")    # learning was NOT skipped
    for t in range(200):
        with resumed.instance("gravity") as inst:
            inst.report(observation=synthetic_obs(inst.action, t))
    assert resumed.policy("gravity").decide().action == BEST


def test_hybrid_corrupt_agent_snapshot_leaves_policy_untouched(tmp_path):
    """A snapshot with a valid window but inconsistent agent record must not
    half-restore (a stale non-None agent would disable the expert-driven
    window rebuild)."""
    h = HybridPolicy()
    bad = {"actions": [0, 1, 2, 3, 4],
           "agent": {"q": [[0.0] * 3] * 3, "state": 0, "alpha": 0.5}}
    with pytest.raises(ValueError):
        h.load_state_dict(bad)
    assert h.agent is None and h.actions == []    # untouched: expert phase
    drive(h, T=60)                                # ...still builds the window
    assert BEST in h.actions


def test_report_explicit_signals_win_over_pe_derivation():
    svc = SelectionService("QLearn", reward="p95")
    with svc.instance("L0") as inst:
        obs = inst.report(pe_times=[1.0, 2.0, 4.0], lib=12.5,
                          tail_latency=9.0)
    assert obs.loop_time == 4.0                   # derived makespan
    assert obs.lib == 12.5                        # caller's LIB wins
    assert obs.tail_latency == 9.0                # caller's p95 wins


def test_warmstart_corrupt_snapshot_starts_cold(tmp_path):
    svc = train_service(tmp_path)
    path, = svc.save()
    with open(path, "w") as f:
        f.write("{not json")
    fresh = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path))
    assert not fresh.warm_started("gravity")
    assert fresh.policy("gravity").learning


def test_midlearning_restore_resumes_same_explore_circuit():
    """The Eulerian explore-first circuit depends on the start node; a
    mid-learning snapshot must resume on the circuit it was saved on."""
    from repro.core import QLearnAgent
    src = QLearnAgent(n_actions=3, initial_state=1)
    for _ in range(4):
        src.observe(src.select(), 1.0)
    snap = src.state_dict()
    expected = [src.select() for _ in range(1)]   # next explore action
    dst = QLearnAgent(n_actions=3)                # default initial_state=0
    dst.load_state_dict(snap)
    assert dst.initial_state == 1
    assert dst._explore == src._explore
    assert dst.select() == expected[0]


def test_report_derives_lib_from_pe_times_alongside_loop_time():
    """Supplying loop_time must not suppress LIB/p95 derivation from
    pe_times — an LIB-reward policy would otherwise learn from 0.0."""
    svc = SelectionService("QLearn", reward="LIB")
    with svc.instance("L0") as inst:
        obs = inst.report(loop_time=2.0, pe_times=[1.0, 2.0, 0.5])
    assert obs.loop_time == 2.0                   # explicit wins
    assert obs.lib > 0.0                          # derived from pe_times
    assert obs.tail_latency is not None


def test_wrongtyped_snapshot_field_starts_cold(tmp_path):
    import json
    svc = train_service(tmp_path)
    path, = svc.save()
    rec = json.load(open(path))
    rec["state"]["agent"]["q"] = {"bad": 1}
    json.dump(rec, open(path, "w"))
    fresh = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path))
    assert not fresh.warm_started("gravity")      # cache miss, no TypeError
    assert fresh.policy("gravity").learning


def test_truncated_agent_snapshot_leaves_agent_untouched(tmp_path):
    """A record missing a later field (hand-edited/truncated JSON) must not
    half-restore the Q-table before failing."""
    import json
    svc = train_service(tmp_path)
    path, = svc.save()
    rec = json.load(open(path))
    del rec["state"]["agent"]["alpha"]
    json.dump(rec, open(path, "w"))
    fresh = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path))
    assert not fresh.warm_started("gravity")
    agent = fresh.policy("gravity").agent
    assert (agent.q == 0).all() and agent._t == 0  # a true cold start


def test_hybrid_snapshot_rejected_on_grown_portfolio(tmp_path):
    svc = SelectionService("Hybrid", reward="LT", store_dir=str(tmp_path),
                           n_actions=12)
    for t in range(60):
        with svc.instance("plans") as inst:
            inst.report(observation=synthetic_obs(inst.action, t))
    svc.save()
    grown = SelectionService("Hybrid", reward="LT", store_dir=str(tmp_path),
                             n_actions=20)
    assert not grown.warm_started("plans")        # stale window: cache miss
    assert grown.policy("plans").learning


def test_warmstart_reward_match_is_case_insensitive(tmp_path):
    svc = SelectionService("QLearn", reward="lt", store_dir=str(tmp_path))
    for t in range(200):
        with svc.instance("gravity") as inst:
            inst.report(observation=synthetic_obs(inst.action, t))
    svc.save()
    fresh = SelectionService("QLearn", reward="LT", store_dir=str(tmp_path))
    assert fresh.warm_started("gravity")


def test_warmstart_ignores_method_mismatch(tmp_path):
    svc = train_service(tmp_path)
    svc.save()
    sarsa = SelectionService("SARSA", reward="LT", store_dir=str(tmp_path))
    assert not sarsa.warm_started("gravity")
    assert sarsa.policy("gravity").learning       # starts cold, correctly


def test_hybrid_warmstart_roundtrip(tmp_path):
    svc = SelectionService("Hybrid", reward="LT", store_dir=str(tmp_path))
    for t in range(80):
        with svc.instance("L0") as inst:
            inst.report(observation=synthetic_obs(inst.action, t))
    assert not svc.policy("L0").learning
    svc.save()
    fresh = SelectionService("Hybrid", reward="LT", store_dir=str(tmp_path))
    policy = fresh.policy("L0")
    assert fresh.warm_started("L0")
    assert not policy.learning                    # expert + explore skipped
    assert policy.decide().action == BEST


# ---------------------------------------------------------------------------
# deprecated scalar shims stay alive
# ---------------------------------------------------------------------------

def test_decide_is_a_pure_peek_for_every_policy():
    """Repeated decide() without feedback must not change the selection or
    advance any RNG (callers like StepAutoTuner.selected_plan peek)."""
    for name in ("RandomSel", "ExhaustiveSel", "ExpertSel", "QLearn",
                 "SARSA", "Hybrid"):
        p = make_policy(name, seed=11)
        first = p.decide().action
        assert all(p.decide().action == first for _ in range(10)), name


def test_make_selector_rl_shims_expose_agent():
    """Pre-redesign scripts rely on sel.agent (e.g. for save_agent)."""
    from repro.core import make_selector
    with pytest.warns(DeprecationWarning):
        q = make_selector("QLearn", reward_type="LIB", seed=0)
        s = make_selector("sarsa")
    assert q.agent.q.shape == (12, 12) and q.reward_type == "LIB"
    assert s.agent.learning_steps == 144


def test_make_selector_shim_warns_and_works():
    from repro.core import make_selector
    with pytest.warns(DeprecationWarning):
        sel = make_selector("qlearn", reward_type="LT")
    assert sel.learning_steps == 144
    for t in range(150):
        a = sel.select()
        sel.observe(a, 1.0 + 0.3 * abs(a - BEST),
                    5.0 if a >= 7 else 60.0)
    assert sel.select() == BEST


def test_begin_end_shims_feed_the_policy():
    svc = SelectionService("ExhaustiveSel")
    for t in range(12):
        a = svc.begin("L0")
        assert a == t
        svc.end("L0", a, 1.0 + 0.1 * abs(a - 4), 3.0)
    assert svc.begin("L0") == 4
