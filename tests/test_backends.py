"""Backend equivalence: the batched vmapped JAX engine must agree with the
reference Python event loop — exactly where exactness is possible (noise-free
chunk sequences, shared closed forms), within tolerance elsewhere."""

import dataclasses

import numpy as np
import pytest

from repro.core.jaxsched import chunk_schedule, staticsteal_schedule
from repro.sim import (InstanceSpec, LoopProfile, backend_names, get_backend,
                       get_system, sweep_portfolio)

# P a power of two and unit an exact binary fraction keep the adaptive
# algorithms' telemetry bit-exact (variance exactly 0, weights exactly 1),
# so even the surrogate recurrences must match the host classes chunk-for-
# chunk.  locality_sens = 0: chunk-size-dependent locality inflation is real
# telemetry the surrogates cannot see.
QUIET = dataclasses.replace(get_system("broadwell"), P=8, noise_sigma=0.0,
                            jitter=0.0, speed_spread=0.0)
UNIFORM = LoopProfile(name="u", N=4096, memory_bound=0.0, locality_sens=0.0,
                      c_loc=64, unit=2**-20)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_names():
    assert {"python", "jax"} <= set(backend_names())
    assert get_backend("python").name == "python"
    assert get_backend("jax").name == "jax"
    assert get_backend(get_backend("python")).name == "python"
    with pytest.raises(ValueError, match="unknown simulation backend"):
        get_backend("fortran")


def test_registry_env_default(monkeypatch):
    monkeypatch.delenv("REPRO_SIM_BACKEND", raising=False)
    assert get_backend(None).name == "python"
    monkeypatch.setenv("REPRO_SIM_BACKEND", "jax")
    assert get_backend(None).name == "jax"


def test_event_cap_is_shared():
    from repro.sim import engine, engine_jax
    from repro.sim.backends import base

    assert engine.EVENT_CAP == base.EVENT_CAP == engine_jax.MAX_EVENTS
    assert get_backend("python").event_cap == get_backend("jax").event_cap


# ---------------------------------------------------------------------------
# noise-free exact equivalence, every portfolio algorithm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg", range(12))
@pytest.mark.parametrize("cp", [0, 37])
def test_noise_free_chunk_sequences_and_makespan(alg, cp):
    py = get_backend("python")
    jx = get_backend("jax")
    rp = py.run_instance(UNIFORM, QUIET, alg, cp, np.random.default_rng(0),
                         record_chunks=True)
    rj = jx.run_instance(UNIFORM, QUIET, alg, cp, np.random.default_rng(0),
                         record_chunks=True)
    assert rp.n_chunks == rj.n_chunks
    assert rp.chunk_sizes == rj.chunk_sizes, f"alg {alg} cp {cp}"
    np.testing.assert_allclose(rj.loop_time, rp.loop_time, rtol=1e-4)
    np.testing.assert_allclose(rj.lib, rp.lib, atol=0.05)


def test_noise_free_nonadaptive_on_nonuniform_profile():
    """Non-adaptive schedules don't depend on telemetry, so they stay exact
    on imbalanced (gridded) loops too."""
    from repro.sim import get_application

    profile = get_application("mandelbrot").loops(0)[0]
    py = get_backend("python")
    jx = get_backend("jax")
    for alg in (1, 2, 3, 4, 6):
        rp = py.run_instance(profile, QUIET, alg, 64,
                             np.random.default_rng(0), record_chunks=True)
        rj = jx.run_instance(profile, QUIET, alg, 64,
                             np.random.default_rng(0), record_chunks=True)
        assert rp.chunk_sizes == rj.chunk_sizes, alg
        np.testing.assert_allclose(rj.loop_time, rp.loop_time, rtol=2e-3)


def test_closed_forms_are_bit_identical():
    """STATIC and over-cap SS/StaticSteal share the reference closed forms
    AND the numpy rng streams — identical even with noise on."""
    system = get_system("cascadelake")
    from repro.sim import get_application

    profile = get_application("stream").loops(0)[0]
    py = get_backend("python")
    jx = get_backend("jax")
    for alg in (0, 1, 5):       # N/c_floor = 2e9 >> EVENT_CAP
        seed = (0, 7, system.P, alg)
        rp = py.run_batch([profile], system,
                          [InstanceSpec(0, alg, 0, seed)])
        rj = jx.run_batch([profile], system,
                          [InstanceSpec(0, alg, 0, seed)])
        assert rp.loop_time[0] == rj.loop_time[0]
        assert rp.lib[0] == rj.lib[0]


# ---------------------------------------------------------------------------
# the acceptance cell: same Oracle selections on a T=4 campaign cell
# ---------------------------------------------------------------------------

def test_campaign_cell_oracle_agreement():
    """T=4 cell with a 40 % winner margin (TC on EPYC: StaticSteal-default
    dominates) — both engines must elect the same Oracle even under their
    different noise realizations."""
    sp = sweep_portfolio("tc", "epyc", T=4, reps=1, backend="python")
    sj = sweep_portfolio("tc", "epyc", T=4, reps=1, backend="jax")
    assert (sp.oracle_argmin() == sj.oracle_argmin()).all()
    # the winner goes through the shared closed form -> bit-identical
    np.testing.assert_allclose(sj.oracle_total(), sp.oracle_total(),
                               rtol=1e-12)
    # the c.o.v. regime (Fig. 4) must match across engines (adaptive
    # surrogates shift TC's portfolio spread by ~10 %)
    np.testing.assert_allclose(sj.cov(), sp.cov(), rtol=0.25)


def test_batch_matches_per_instance_python():
    """The batched campaign path reproduces run_fixed's historical rng
    tuples bit-for-bit on the Python backend."""
    from repro.sim import get_application
    from repro.sim.campaign import _digest
    from repro.sim.engine import run_instance

    app = get_application("sphynx")
    system = get_system("broadwell")
    profile = app.loops(0)[0]
    seed = (0, _digest("sphynx"), system.P, 6, _digest("default"), 0, 0)
    direct = run_instance(profile, system, 6, 0,
                          np.random.default_rng(seed))
    res = get_backend("python").run_batch(
        [profile], system, [InstanceSpec(0, 6, 0, seed)])
    assert res.loop_time[0] == direct.loop_time


# ---------------------------------------------------------------------------
# jaxsched: surrogates, StaticSteal replay, int32 overflow regression
# ---------------------------------------------------------------------------

def _drain_constant_telemetry(alg_idx, N, P, chunk_param):
    from repro.core import make_algorithm

    alg = make_algorithm(alg_idx)
    alg.reset(N, P, chunk_param)
    sizes = []
    pe = 0
    while True:
        c = alg.next_chunk(pe % P)
        if c == 0:
            break
        alg.report(pe % P, c, c * 1.0, c * 1.0)   # exactly 1.0 s/iteration
        sizes.append(c)
        pe += 1
        assert len(sizes) <= N + P
    return sizes


@pytest.mark.parametrize("alg", [4, 7, 8, 9, 10, 11])
def test_surrogate_schedules_match_host_classes(alg):
    for (N, P, cp) in [(1000, 4, 0), (4096, 8, 0), (4096, 8, 64),
                       (5000, 7, 8), (20000, 32, 0), (16, 1, 0)]:
        sizes, count = chunk_schedule(alg, N, P, cp, max_chunks=4096)
        got = list(np.asarray(sizes[: int(count)]))
        assert got == _drain_constant_telemetry(alg, N, P, cp), (N, P, cp)


def test_chunk_schedule_int32_overflow_regression():
    """TSS on STREAM (N = 2e9, x64 off): the old fixed-point state
    ``f0 * 1024`` wrapped int32 and degenerated into unit chunks."""
    N = 2_000_000_000
    sizes, count = chunk_schedule(4, N, 20, 0, max_chunks=4096)
    s = np.asarray(sizes[: int(count)], dtype=np.int64)
    assert s[0] == 50_000_000          # ceil(N / 2P)
    assert s.min() >= 1
    assert s.sum() == N
    assert int(count) < 4096


def test_chunk_schedule_rejects_beyond_int32():
    import jax

    if jax.config.read("jax_enable_x64"):
        pytest.skip("x64 enabled: large N is legal")
    with pytest.raises(ValueError, match="int32"):
        chunk_schedule(2, 2**31, 8, 0)
    with pytest.raises(ValueError, match="int32"):
        chunk_schedule(2, np.int64(2**31 + 5), 8, 0)   # np scalars too


def test_staticsteal_schedule_covers_and_marks_ownership():
    starts, sizes, pes, own, count = staticsteal_schedule(
        4096, 8, 0, max_chunks=8192, unit=2**-20)
    count = int(count)
    sizes = np.asarray(sizes[:count], dtype=np.int64)
    own = np.asarray(own[:count])
    assert sizes.sum() == 4096
    assert own[: 8].all()              # first rounds serve own ranges
    # every iteration delivered exactly once
    starts = np.asarray(starts[:count], dtype=np.int64)
    covered = np.zeros(4096, bool)
    for a, c in zip(starts, sizes):
        assert not covered[a: a + c].any()
        covered[a: a + c] = True
    assert covered.all()


# ---------------------------------------------------------------------------
# serving what-if
# ---------------------------------------------------------------------------

def test_what_if_wave_backends_agree():
    from repro.data.pipeline import Request
    from repro.serving.engine import DispatchSimulator

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt_len=int(rng.integers(10, 400)),
                    gen_len=int(rng.integers(10, 200)), arrival=0.0)
            for i in range(192)]
    sim_py = DispatchSimulator(n_replicas=8, selector="Fixed",
                               selector_kw={"algorithm": 2})
    sim_jx = DispatchSimulator(n_replicas=8, selector="Fixed",
                               selector_kw={"algorithm": 2}, backend="jax")
    wp = sim_py.what_if(reqs)
    wj = sim_jx.what_if(reqs)
    assert wp.shape == wj.shape == (12,)
    # non-adaptive candidates are exact; adaptive/StaticSteal surrogates
    # within 5 %
    np.testing.assert_allclose(wj[[0, 1, 2, 3, 4, 6]], wp[[0, 1, 2, 3, 4, 6]],
                               rtol=1e-5)
    np.testing.assert_allclose(wj, wp, rtol=0.05)
    assert wp.argmin() == wj.argmin()
    # candidate-subset form (before run_wave mutates the busy-state)
    sub = sim_py.what_if(reqs, algs=[1, 2])
    np.testing.assert_allclose(sub, wp[[1, 2]])
    # the prediction for the committed wave matches the actual dispatch
    st = sim_py.run_wave(reqs)
    np.testing.assert_allclose(st.makespan, wp[2], rtol=1e-9)


def test_what_if_wave_float64_prefix_precision():
    """Regression for the float32 downcast of the request-cost prefix: the
    JAX backend now gathers per-chunk costs from the float64 prefix host-side
    (exact integer indexing), so large request totals stay within float32
    rounding of the float64 reference loop.  The old device-side f32-prefix
    subtraction lost ~3e-6 relative on this 16k-request wave — two orders of
    magnitude outside this tolerance."""
    rng = np.random.default_rng(0)
    prefix = np.concatenate([[0.0], np.cumsum(rng.random(16384) * 1e-2)])
    avail = rng.random(16) * 1e-3
    algs = [1, 2, 3, 6]                  # exact (non-adaptive) candidates
    wp = get_backend("python").what_if_wave(prefix, 16, avail, 2e-4, 1e-3,
                                            algs, chunk_param=4)
    wj = get_backend("jax").what_if_wave(prefix, 16, avail, 2e-4, 1e-3,
                                         algs, chunk_param=4)
    np.testing.assert_allclose(wj, wp, rtol=5e-7)


def test_schedule_caches_are_lru_bounded():
    """Long campaign processes must not grow the schedule caches without
    bound — the LRU evicts the least-recently-used entry."""
    from repro.sim.backends.jax_batched import JaxBatchedBackend, _LRU

    lru = _LRU(maxsize=2)
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1             # refreshes "a"
    lru.put("c", 3)                      # evicts "b"
    assert lru.get("b") is None
    assert lru.get("a") == 1 and lru.get("c") == 3

    bk = JaxBatchedBackend()
    bk._sched_cache = _LRU(maxsize=4)
    for n in range(1000, 1006):
        bk._central_schedule(2, n, 8, 0)
    assert len(bk._sched_cache) <= 4
    assert get_backend("jax")._sched_cache.maxsize > 0
    assert get_backend("jax")._steal_cache.maxsize > 0


def test_grids_device_upload_cached_per_profile_stack():
    """Equal-content profile stacks (even rebuilt objects, as lockstep
    replays do every time step) hit the same device-resident upload."""
    from repro.sim import get_application

    bk = get_backend("jax")
    mk = lambda: LoopProfile(name="u", N=1024, memory_bound=0.0,
                             locality_sens=0.0, c_loc=64, unit=2**-20)
    assert bk._grids_dev([mk()]) is bk._grids_dev([mk()])
    # gridded profiles are rebuilt per loops(t) call yet digest equal
    app = get_application("mandelbrot")
    d1 = bk._grids_dev(app.loops(0))
    d2 = bk._grids_dev(app.loops(0))
    assert d1 is d2
    # different content -> different upload
    assert bk._grids_dev([mk()]) is not d1


def test_continuous_batcher_queue_is_deque():
    from collections import deque

    from repro.data.pipeline import Request
    from repro.serving.engine import ContinuousBatcher

    b = ContinuousBatcher(serve_step=None, init_cache_fn=None, batch_slots=2)
    assert isinstance(b.queue, deque)
    reqs = [Request(rid=i, prompt_len=4, gen_len=2, arrival=0.0)
            for i in range(4)]
    b.submit(reqs)
    b._refill()
    # FIFO: the first two submitted occupy the slots, rest stay queued
    assert [r.rid for r in b.active] == [0, 1]
    assert [r.rid for r in b.queue] == [2, 3]
