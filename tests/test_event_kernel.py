"""Pallas event-loop kernel: bit-equivalence against the ``lax.while_loop``
reference core (interpret mode), across every portfolio algorithm, forced-PE
StaticSteal rows, over-bucket schedule lengths, and random ragged batches.

The contract under test (``repro.kernels.event_loop``): with identical
inputs — the random draws live in the shared data-parallel precompute — the
fused on-chip kernel must reproduce the reference core *bit for bit*, so
switching ``REPRO_EVENT_CORE`` can never change a campaign statistic.
"""

import dataclasses
import os
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.sim import LoopProfile, get_application, get_backend, get_system
from repro.sim.backends import InstanceSpec
from repro.sim.backends.jax_batched import (EVENT_CORES, JaxBatchedBackend,
                                            _core_while, resolve_event_core)

#: explicit kernel= constructions so the equivalence suite never degrades
#: to pallas-vs-pallas when REPRO_EVENT_CORE is set in the environment
#: (the jitted cores are module-level, so compile caches are still shared)
WHILE = JaxBatchedBackend(kernel="while_loop")
PALLAS = JaxBatchedBackend(kernel="pallas")

NOISY = dataclasses.replace(get_system("broadwell"), P=8)
QUIET = dataclasses.replace(NOISY, noise_sigma=0.0, jitter=0.0,
                            speed_spread=0.0)
UNIFORM = LoopProfile(name="u", N=4096, memory_bound=0.2, locality_sens=0.4,
                      c_loc=64, unit=2**-20)


# ---------------------------------------------------------------------------
# core selection plumbing
# ---------------------------------------------------------------------------

def test_resolve_event_core(monkeypatch):
    monkeypatch.delenv("REPRO_EVENT_CORE", raising=False)
    assert resolve_event_core() == "while_loop"
    assert resolve_event_core("pallas") == "pallas"
    monkeypatch.setenv("REPRO_EVENT_CORE", "pallas")
    assert resolve_event_core() == "pallas"
    assert resolve_event_core("while_loop") == "while_loop"   # arg wins
    with pytest.raises(ValueError, match="unknown event core"):
        resolve_event_core("triton")
    assert set(EVENT_CORES) == {"while_loop", "pallas"}


def test_registry_exposes_pallas_backend():
    # explicit kernel= always wins over the environment
    assert WHILE.event_core == "while_loop"
    assert PALLAS.event_core == "pallas"
    assert PALLAS.name == "jax-pallas"
    # the registry name constructs with kernel="pallas" (env-proof)
    pk = get_backend("jax-pallas")
    assert isinstance(pk, JaxBatchedBackend)
    assert pk.event_core == "pallas"
    assert pk is not get_backend("jax")


# ---------------------------------------------------------------------------
# bit-equivalence across every portfolio algorithm (noise-free AND noisy)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg", range(12))
@pytest.mark.parametrize("system", [QUIET, NOISY], ids=["quiet", "noisy"])
def test_all_algorithms_bit_identical(alg, system):
    """Same fold seed => same noise realization => identical finish times,
    makespans and LIBs on both cores (STATIC delegates to the shared
    closed form on both, trivially equal)."""
    a = WHILE.run_instance(UNIFORM, system, alg, 0, np.random.default_rng(7))
    b = PALLAS.run_instance(UNIFORM, system, alg, 0, np.random.default_rng(7))
    assert a.loop_time == b.loop_time, alg
    assert a.lib == b.lib, alg
    assert a.n_chunks == b.n_chunks, alg
    np.testing.assert_array_equal(np.asarray(a.finish), np.asarray(b.finish))


def test_staticsteal_forced_rows_bit_identical():
    """StaticSteal rows carry forced-PE assignments (own ranges + steals);
    the kernel's forced branch must track the reference exactly, including
    on an imbalanced (gridded) profile."""
    profile = get_application("mandelbrot").loops(0)[0]
    for cp in (0, 16):
        a = WHILE.run_instance(profile, NOISY, 5, cp,
                               np.random.default_rng(11))
        b = PALLAS.run_instance(profile, NOISY, 5, cp,
                                np.random.default_rng(11))
        assert (a.loop_time, a.lib) == (b.loop_time, b.lib), cp


def test_over_bucket_schedule_bit_identical():
    """SS with a unit chunk floor on N=4096 fills the 4096 bucket — the
    kernel streams 8 segments through the sequential grid axis with the
    finish state resident in scratch; a 586-chunk schedule exercises the
    partial tail segment of the 1024 bucket."""
    for cp, chunks in ((1, 4096), (7, 586)):
        a = WHILE.run_instance(UNIFORM, NOISY, 1, cp,
                               np.random.default_rng(5))
        b = PALLAS.run_instance(UNIFORM, NOISY, 1, cp,
                                np.random.default_rng(5))
        assert a.n_chunks == b.n_chunks == chunks
        assert (a.loop_time, a.lib) == (b.loop_time, b.lib), cp


def test_mixed_batch_bit_identical():
    """One run_batch mixing bucket sizes, algorithms, and closed-form
    delegates — spec order and results must be identical across cores."""
    profiles = [UNIFORM, get_application("mandelbrot").loops(0)[0]]
    specs = [InstanceSpec(i % 2, alg, cp, (alg, cp, i))
             for i, (alg, cp) in enumerate(
                 [(1, 1), (2, 0), (5, 0), (6, 37), (0, 0), (9, 0), (1, 7)])]
    ra = WHILE.run_batch(profiles, NOISY, specs)
    rb = PALLAS.run_batch(profiles, NOISY, specs)
    np.testing.assert_array_equal(ra.loop_time, rb.loop_time)
    np.testing.assert_array_equal(ra.lib, rb.lib)
    np.testing.assert_array_equal(ra.n_chunks, rb.n_chunks)


def test_what_if_wave_cores_bit_identical():
    """The serving what-if routes through the same sequential core."""
    rng = np.random.default_rng(0)
    prefix = np.concatenate([[0.0], np.cumsum(rng.random(512) * 1e-3)])
    avail = rng.random(8) * 1e-3
    wa = WHILE.what_if_wave(prefix, 8, avail, 2e-4, 1e-3, list(range(12)))
    wb = PALLAS.what_if_wave(prefix, 8, avail, 2e-4, 1e-3, list(range(12)))
    np.testing.assert_array_equal(wa, wb)


# ---------------------------------------------------------------------------
# property test: random ragged schedules straight into the cores
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), P=st.integers(1, 12),
       seg=st.sampled_from([64, 256]))
def test_random_ragged_schedules_property(seed, P, seg):
    """Random effective costs, speeds, jitters, forced rows and ragged
    counts (including empty lanes): kernel == while-loop reference, bit for
    bit, for any segment length that divides the bucket."""
    from repro.kernels.event_loop import event_finish

    rng = np.random.default_rng(seed)
    B, K = int(rng.integers(1, 6)), 256
    eff = jnp.asarray(rng.random((B, K)), jnp.float32)
    speed = jnp.asarray(1.0 + 0.2 * rng.standard_normal((B, P)), jnp.float32)
    jitter = jnp.asarray(rng.random((B, P)) * 1e-2, jnp.float32)
    h_eff = jnp.asarray(rng.random(B) * 1e-3, jnp.float32)
    bcost = jnp.asarray(rng.random(B) * 1e-3, jnp.float32)
    forced = np.full((B, K), -1, np.int32)
    nf = int(rng.integers(0, K))
    lane = int(rng.integers(0, B))
    forced[lane, :nf] = rng.integers(0, P, nf)
    cnt = rng.integers(0, K + 1, B).astype(np.int32)
    kernel = event_finish(eff, speed, jitter, h_eff, bcost,
                          jnp.asarray(forced), jnp.asarray(cnt),
                          seg=seg, interpret=True)
    ref = _core_while(eff, speed, jitter, h_eff, bcost,
                      jnp.asarray(forced), jnp.asarray(cnt))
    np.testing.assert_array_equal(np.asarray(kernel), np.asarray(ref))


# ---------------------------------------------------------------------------
# campaign scale (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_campaign_sweep_pallas_bit_identical():
    from repro.sim import sweep_portfolio

    sw = sweep_portfolio("tc", "epyc", T=4, reps=1, backend=WHILE)
    sp = sweep_portfolio("tc", "epyc", T=4, reps=1, backend=PALLAS)
    assert sw.oracle_total() == sp.oracle_total()
    assert (sw.oracle_argmin() == sp.oracle_argmin()).all()
    for key, run in sw.runs.items():
        np.testing.assert_array_equal(run.times, sp.runs[key].times)
        np.testing.assert_array_equal(run.libs, sp.runs[key].libs)


@pytest.mark.slow
def test_lockstep_replay_pallas_bit_identical():
    """Selector replays consume lane rngs host-side; with bit-equal cores
    the full decide/execute/learn trajectory is identical."""
    from repro.sim import CellSpec, ReplayBatch

    lanes = [CellSpec("mandelbrot", "broadwell", "QLearn", reward="LT"),
             CellSpec("tc", "epyc", "ExhaustiveSel")]
    rw = ReplayBatch(lanes, T=4, seed=0, backend=WHILE).run()
    rp = ReplayBatch(lanes, T=4, seed=0, backend=PALLAS).run()
    for a, b in zip(rw, rp):
        assert a.history == b.history
        assert a.total == b.total


@pytest.mark.slow
def test_stream_scale_lane_bit_identical():
    """K = 65536 (the STREAM-scale SS lane the kernel targets): 128
    sequential segments through the grid axis, still bit-exact."""
    sysm = dataclasses.replace(get_system("cascadelake"), P=20)
    prof = LoopProfile(name="u", N=4_194_304, memory_bound=0.3,
                       locality_sens=0.2, c_loc=64, unit=1e-8)
    specs = [InstanceSpec(0, 1, 64, (i,)) for i in range(4)]
    ma, la, fa, ca = WHILE._run_events([prof], sysm, specs)
    mb, lb, fb, cb = PALLAS._run_events([prof], sysm, specs)
    assert (ca == cb).all() and ca[0] == 65536
    np.testing.assert_array_equal(ma, mb)
    np.testing.assert_array_equal(la, lb)
    np.testing.assert_array_equal(fa, fb)
