"""Beyond-paper extensions: Q-table warm starting (the paper's suggested
'eliminate the learning phase' path) and the jitted DES variant."""

import os

import numpy as np
import pytest

from repro.core import QLearnAgent
from repro.core.persistence import (AgentStatsLogger, load_agent,
                                    load_policy_state, save_agent,
                                    save_policy_state, warm_start)


# ---------------------------------------------------------------------------
# Q-table persistence / warm start
# ---------------------------------------------------------------------------

def _train_agent(best=5, T=300, spread=50.0):
    a = QLearnAgent()
    for _ in range(T):
        act = a.select()
        a.observe(act, 1.0 + spread * abs(act - best))
    return a


def test_save_load_roundtrip(tmp_path):
    a = _train_agent()
    save_agent(a, str(tmp_path), "gravity", system="cascadelake")
    rec = load_agent(str(tmp_path), "gravity", system="cascadelake")
    assert rec["kind"] == "QLearnAgent"
    np.testing.assert_allclose(np.asarray(rec["q"]), a.q)
    assert load_agent(str(tmp_path), "gravity", system="epyc") is None


def test_save_is_atomic_and_load_tolerates_corruption(tmp_path):
    """A snapshot save must never leave a torn file (temp + os.replace),
    and a corrupt snapshot must be a warned cache miss (None), not a
    crash — a damaged warm-start store degrades to a cold start."""
    rec = {"method": "QLearn", "state": {"q": [1.0, 2.0]}}
    path = save_policy_state(rec, str(tmp_path), "L0", system="sys")
    assert load_policy_state(str(tmp_path), "L0", system="sys") == rec
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]

    with open(path, "w") as f:
        f.write('{"method": "QLe')        # torn write
    with pytest.warns(UserWarning, match="corrupt policy"):
        assert load_policy_state(str(tmp_path), "L0", system="sys") is None

    a = _train_agent()
    apath = save_agent(a, str(tmp_path), "L1")
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    with open(apath, "w") as f:
        f.write("not json at all")
    with pytest.warns(UserWarning, match="corrupt agent"):
        assert load_agent(str(tmp_path), "L1") is None


def test_warm_start_skips_learning_phase(tmp_path):
    trained = _train_agent(best=5)
    save_agent(trained, str(tmp_path), "L0")
    fresh = QLearnAgent()
    assert fresh.learning                       # would pay 144 instances
    rec = load_agent(str(tmp_path), "L0")
    warm_start(fresh, rec)
    assert not fresh.learning                   # paper's 28.8 % cost -> 0
    assert fresh.select() == 5                  # immediately exploits


def test_warm_start_keeps_reward_extrema(tmp_path):
    trained = _train_agent()
    save_agent(trained, str(tmp_path), "L0")
    fresh = QLearnAgent()
    warm_start(fresh, load_agent(str(tmp_path), "L0"))
    lo, hi = fresh.reward.extrema
    assert np.isfinite(lo) and np.isfinite(hi) and lo < hi


def test_stats_logger(tmp_path):
    a = QLearnAgent(n_actions=3)
    log = AgentStatsLogger(str(tmp_path))
    for t in range(4):
        act = a.select()
        a.observe(act, 1.0)
        log.log("L0", t, a)
    lines = open(tmp_path / "L0.jsonl").read().strip().splitlines()
    assert len(lines) == 4
    import json
    rec = json.loads(lines[-1])
    assert np.asarray(rec["q"]).shape == (3, 3)


# ---------------------------------------------------------------------------
# jitted DES cross-validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg", [1, 2, 3, 4, 6])
def test_engine_jax_matches_python(alg):
    import dataclasses

    import jax.numpy as jnp

    from repro.sim import get_application, get_system, run_instance
    from repro.sim.engine_jax import simulate_loop

    app = get_application("mandelbrot")
    system = get_system("broadwell")
    profile = app.loops(0)[0]

    # noise-free python reference: zero jitter/noise/overheads except h
    quiet = dataclasses.replace(system, noise_sigma=0.0, jitter=0.0,
                                speed_spread=0.0, boundary_cost=0.0,
                                dyn_locality=0.0, loc_amp=0.0)
    rng = np.random.default_rng(0)
    ref = run_instance(profile, quiet, alg, 64, rng)

    mk, finish, count = simulate_loop(
        alg, jnp.asarray(profile.prefix_grid, jnp.float32),
        profile.N, quiet.P, 64, h=quiet.h)
    assert int(count) == ref.n_chunks
    # same scheduling decisions -> same makespan (float32 tolerance)
    np.testing.assert_allclose(float(mk), ref.loop_time, rtol=2e-3)
