"""Fleet-scale serving: RNG substreams, arrival traces, routers, batched
route pricing, admission control, and the FleetSimulator end-to-end."""

import numpy as np
import pytest

from repro.data import field_rng, request_lengths, synthetic_requests
from repro.serving import (AdmissionControl, FleetSimulator, FleetView,
                           ReplicaCostModel, make_router, make_trace)
from repro.sim.backends import get_backend

BURSTY = dict(base_rate=2000.0, burst_factor=6.0, p_enter=0.015, p_exit=0.05)


# ---------------------------------------------------------------------------
# synthetic_requests substreams (satellite: RNG stream decoupling)
# ---------------------------------------------------------------------------

def test_synthetic_requests_golden():
    """Pin the per-field substreams: any change to how ``field_rng`` folds
    seeds, or to the draw order inside ``request_lengths``, breaks every
    replayable trace — fail loudly here, not in a benchmark diff."""
    got = [(r.prompt_len, r.gen_len, r.arrival)
           for r in synthetic_requests(6, seed=0)]
    expect = [(241, 63, 0.0704658129), (268, 76, 0.0842305105),
              (265, 676, 0.1200068781), (228, 68, 0.1393173676),
              (225, 202, 0.1495883785), (3476, 99, 0.1517558460)]
    for (p, g, a), (ep, eg, ea) in zip(got, expect):
        assert (p, g) == (ep, eg)
        assert a == pytest.approx(ea, abs=1e-9)
    got7 = [(r.prompt_len, r.gen_len) for r in
            synthetic_requests(3, seed=7, mean_prompt=300)]
    assert got7 == [(400, 102), (829, 557), (1477, 138)]


def test_field_substreams_are_decoupled():
    base = synthetic_requests(64, seed=0)
    # re-parameterizing gen lengths leaves prompts AND arrivals untouched
    regen = synthetic_requests(64, seed=0, mean_gen=64)
    assert [r.prompt_len for r in regen] == [r.prompt_len for r in base]
    assert [r.arrival for r in regen] == [r.arrival for r in base]
    assert [r.gen_len for r in regen] != [r.gen_len for r in base]
    # the arrival process is an exact exponential-scale family per seed
    fast = synthetic_requests(64, seed=0, arrival_rate=128.0)
    assert np.allclose([r.arrival * 2.0 for r in fast],
                       [r.arrival for r in base])
    assert [r.prompt_len for r in fast] == [r.prompt_len for r in base]


def test_request_lengths_prefix_property():
    p8, g8 = request_lengths(8, 0, 512, 128, 1.3)
    p20, g20 = request_lengths(20, 0, 512, 128, 1.3)
    assert np.array_equal(p20[:8], p8) and np.array_equal(g20[:8], g8)
    a8 = [r.arrival for r in synthetic_requests(8, seed=0)]
    a20 = [r.arrival for r in synthetic_requests(20, seed=0)]
    assert np.allclose(a20[:8], a8)


def test_synthetic_requests_arrival_injection():
    arr = np.linspace(0.5, 2.0, 16)
    reqs = synthetic_requests(16, seed=0, arrivals=arr)
    assert np.allclose([r.arrival for r in reqs], arr)
    with pytest.raises(ValueError):
        synthetic_requests(8, seed=0, arrivals=arr)


def test_field_rng_named_streams_differ():
    a = field_rng(0, "prompt").random(4)
    b = field_rng(0, "gen").random(4)
    c = field_rng(1, "prompt").random(4)
    assert not np.allclose(a, b) and not np.allclose(a, c)
    assert np.allclose(a, field_rng(0, "prompt").random(4))


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

def test_traces_replay_bit_identical():
    for kind in ("poisson", "bursty", "diurnal"):
        t1 = make_trace(kind, 512, seed=3)
        t2 = make_trace(kind, 512, seed=3)
        assert [r.arrival for r in t1.requests] \
            == [r.arrival for r in t2.requests]
        assert [r.prompt_len for r in t1.requests] \
            == [r.prompt_len for r in t2.requests]
        assert t1.kind == kind and len(t1) == 512
        arr = np.array([r.arrival for r in t1.requests])
        assert np.all(np.diff(arr) >= 0.0)


def test_poisson_trace_rate():
    t = make_trace("poisson", 8000, seed=0, rate=500.0)
    assert t.mean_rate == pytest.approx(500.0, rel=0.1)


def test_bursty_trace_is_overdispersed():
    pois = make_trace("poisson", 8000, seed=0, rate=256.0)
    # equal dwell mix: half the arrivals at 8x rate -> gap cv ~1.5
    burst = make_trace("bursty", 8000, seed=0, base_rate=256.0,
                       burst_factor=8.0, p_enter=0.05, p_exit=0.05)
    def cv(t):
        gaps = np.diff([r.arrival for r in t.requests])
        return gaps.std() / gaps.mean()
    # Poisson gaps have cv ~1; MMPP mixing pushes it well above
    assert cv(pois) == pytest.approx(1.0, abs=0.15)
    assert cv(burst) > 1.25
    # mean rate sits strictly between background and burst rates
    assert 256.0 < burst.mean_rate < 8.0 * 256.0


def test_diurnal_trace_oscillates():
    t = make_trace("diurnal", 12000, seed=0, base_rate=256.0,
                   amplitude=0.8, period=10.0)
    arr = np.array([r.arrival for r in t.requests])
    # rate in the peak half-period vs the trough half-period
    phase = (arr % 10.0) / 10.0
    peak = np.sum((phase > 0.05) & (phase < 0.45))
    trough = np.sum((phase > 0.55) & (phase < 0.95))
    assert peak > 2.0 * trough
    with pytest.raises(ValueError):
        make_trace("diurnal", 10, amplitude=1.5)


def test_make_trace_unknown_kind():
    with pytest.raises(ValueError, match="unknown trace kind"):
        make_trace("fractal", 10)


# ---------------------------------------------------------------------------
# routers
# ---------------------------------------------------------------------------

def _view(busy, R=4, backend=None):
    return FleetView(now=0.0, busy=[np.asarray(b, dtype=float) for b in busy],
                     n_replicas=R, cost=ReplicaCostModel(), h=0.2e-3,
                     backend=get_backend(backend))


def test_round_robin_carries_cursor_across_waves():
    r = make_router("rr")
    view = _view([np.zeros(4)] * 3)
    reqs = synthetic_requests(8, seed=0)
    s1 = r.route(reqs[:4], view)
    s2 = r.route(reqs[4:], view)
    assert [[q.rid for q in s] for s in s1] == [[0, 3], [1], [2]]
    # wave 2 starts where wave 1 left off (cursor = 4 % 3 = 1)
    assert [[q.rid for q in s] for s in s2] == [[6], [4, 7], [5]]


def test_least_outstanding_prefers_idle_groups():
    r = make_router("least_outstanding")
    view = _view([np.full(4, 10.0), np.zeros(4), np.full(4, 10.0)])
    reqs = synthetic_requests(6, seed=0)
    shards = r.route(reqs, view)
    assert [len(s) for s in shards] == [0, 6, 0]


def test_whatif_router_partitions_the_batch():
    r = make_router("whatif")
    reqs = synthetic_requests(40, seed=2)
    view = _view([np.zeros(4), np.linspace(0, 0.4, 4), np.zeros(4)])
    shards = r.route(reqs, view)
    assert len(shards) == 3
    assert sorted(q.rid for s in shards for q in s) \
        == [q.rid for q in reqs]
    assert set(r.last_prices) == {"stripe", "lpt", "waterfill", "focus"}
    assert r.choices[-1] == min(r.last_prices, key=r.last_prices.get)


def test_whatif_router_routes_around_a_hot_group():
    r = make_router("whatif")
    hot = np.full(4, 50.0)  # group 0 is way behind
    view = _view([hot, np.zeros(4), np.zeros(4)])
    shards = r.route(synthetic_requests(30, seed=1), view)
    assert len(shards[0]) == 0


def test_make_router_rejects_unknown():
    with pytest.raises(ValueError, match="unknown router"):
        make_router("hash_ring")


# ---------------------------------------------------------------------------
# batched route pricing (what_if_routes) across backends
# ---------------------------------------------------------------------------

def test_what_if_routes_python_jax_agree():
    jax_be = get_backend("jax")
    py_be = get_backend("python")
    rng = np.random.default_rng(0)
    R = 4
    prefixes, avails = [], []
    for n in (12, 30, 7):
        costs = rng.uniform(1e-3, 8e-3, n)
        prefixes.append(np.concatenate([[0.0], np.cumsum(costs)]))
        avails.append(rng.uniform(0.0, 0.05, R))
    cands = [(s, a, cp) for s in range(3) for a in (0, 1, 2, 4, 6)
             for cp in (0, 3)]
    mk_py = py_be.what_if_routes(prefixes, R, avails, 0.2e-3, 2e-3, cands)
    mk_jax = jax_be.what_if_routes(prefixes, R, avails, 0.2e-3, 2e-3, cands)
    assert mk_py.shape == mk_jax.shape == (len(cands),)
    assert np.allclose(mk_py, mk_jax, rtol=1e-5, atol=1e-6)
    # pricing respects the carried busy-state: idle groups finish sooner
    idle = py_be.what_if_routes(prefixes, R, [np.zeros(R)] * 3, 0.2e-3,
                                2e-3, cands)
    assert np.all(mk_py >= idle - 1e-12)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_quota_and_floor():
    view = _view([np.zeros(4)] * 2)
    reqs = synthetic_requests(600, seed=0)
    ac = AdmissionControl(wave_quota=128)
    assert ac.admit(reqs, now=1.0, view=view) == 256  # quota * G
    assert ac.admit(reqs[:10], now=1.0, view=view) == 10
    assert ac.admit([], now=1.0, view=view) == 0


def test_admission_queue_depth_backpressure():
    reqs = synthetic_requests(600, seed=0)
    deep = _view([np.full(4, 5.0)] * 2)     # 40s outstanding
    ac = AdmissionControl(wave_quota=128, queue_depth=0.1, min_admit=8)
    # budget exhausted with work still outstanding -> the wave must be held
    # at 0 (re-admitting min_admit here would defeat backpressure: the
    # fleet drains, the k=0 wave reopens at the next replica-free instant)
    assert ac.admit(reqs, now=1.0, view=deep) == 0
    idle = _view([np.zeros(4)] * 2)
    assert ac.admit(reqs, now=1.0, view=idle) > 8


def test_admission_idle_floor_survives_zero_budget():
    # nothing outstanding: even a zero queue-depth budget must admit the
    # min_admit floor, or an idle fleet would never start draining
    reqs = synthetic_requests(600, seed=0)
    idle = _view([np.zeros(4)] * 2)
    ac = AdmissionControl(wave_quota=128, queue_depth=1e-12, min_admit=8)
    assert ac.admit(reqs, now=1.0, view=idle) == 8
    assert ac.admit(reqs[:3], now=1.0, view=idle) == 3


def test_admission_p95_weights_by_group_capacity():
    reqs = synthetic_requests(600, seed=0, arrival_rate=1e6)
    even = _view([np.zeros(4)] * 2)
    ac = AdmissionControl(wave_quota=256, p95_slo=0.1, min_admit=8)
    k_even = ac.admit(reqs, 0.01, even)
    # same fleet, but one group at 10% capacity: the aggregate drain rate
    # shrinks, so the predicted horizon forces a smaller wave
    skew = _view([np.zeros(4)] * 2)
    skew.capacity = np.array([1.0, 0.1])
    k_skew = ac.admit(reqs, 0.01, skew)
    assert k_skew < k_even
    # explicit uniform capacity is bit-identical to None
    unif = _view([np.zeros(4)] * 2)
    unif.capacity = np.ones(2)
    assert ac.admit(reqs, 0.01, unif) == k_even


def test_fleet_zero_admit_run_completes():
    # a queue_depth tight enough to zero out admissions mid-run must not
    # livelock: the run loop advances to the next replica-free instant
    trace = make_trace("poisson", 300, seed=3, rate=2000.0)
    fleet = FleetSimulator(n_groups=2, replicas_per_group=4, router="rr",
                           selector="ExpertSel",
                           admission=AdmissionControl(
                               wave_quota=64, queue_depth=0.02, min_admit=8))
    rep = fleet.run(trace)
    assert rep.n_requests == 300
    assert sum(g["requests"] for g in rep.per_group) == 300


def test_fleet_perturbed_group_shifts_routing():
    from repro.sim.perturb import FleetPerturb, GroupSlowdown
    trace = make_trace("poisson", 240, seed=5, rate=600.0)
    pz = FleetPerturb(events=(GroupSlowdown(group=0, factor=6.0),))
    work = {}
    for router in ("rr", "whatif"):
        fleet = FleetSimulator(n_groups=2, replicas_per_group=4,
                               router=router, selector="ExpertSel",
                               perturb=pz)
        rep = fleet.run(trace)
        assert rep.n_requests == 240
        # nominal (pre-slowdown) work landed on the slow group
        work[router] = rep.per_group[0]["busy_s"] / 6.0
    # the capacity-aware what-if router moves load off the slowed group;
    # round-robin splits blindly
    assert work["whatif"] < work["rr"]


def test_admission_p95_slo_halves_waves():
    reqs = synthetic_requests(600, seed=0, arrival_rate=1e6)
    view = _view([np.zeros(4)] * 2)
    open_k = AdmissionControl(wave_quota=256).admit(reqs, 0.01, view)
    tight = AdmissionControl(wave_quota=256, p95_slo=0.02, min_admit=8)
    k = tight.admit(reqs, 0.01, view)
    assert 8 <= k < open_k


# ---------------------------------------------------------------------------
# FleetSimulator end-to-end
# ---------------------------------------------------------------------------

def test_fleet_end_to_end_accounting():
    trace = make_trace("poisson", 2000, seed=0, rate=1500.0)
    fleet = FleetSimulator(n_groups=2, replicas_per_group=4,
                           router="whatif", selector="SimPolicy",
                           backend="jax",
                           admission=AdmissionControl(wave_quota=256))
    rep = fleet.run(trace, keep_latencies=True)
    assert rep.n_requests == 2000
    assert sum(g["requests"] for g in rep.per_group) == 2000
    assert rep.makespan > 0 and rep.throughput > 0
    assert rep.p50 <= rep.p95 <= rep.p99
    assert len(rep.latencies) == 2000 and np.all(rep.latencies > 0)
    # each fleet wave dispatches on 1..G groups
    group_waves = [len(sim.stats) for sim in fleet.groups]
    assert max(group_waves) <= rep.waves <= sum(group_waves)
    s = rep.summary()
    assert "per_group" not in s and s["n_requests"] == 2000


def test_fleet_whatif_beats_round_robin_on_bursty():
    """The PR's headline claim at unit-test scale: same bursty regime as
    bench_fleet, what-if-priced routing wins both makespan and p95."""
    trace = make_trace("bursty", 30000, seed=0, **BURSTY)
    out = {}
    for router in ("round_robin", "whatif"):
        fleet = FleetSimulator(n_groups=4, replicas_per_group=8,
                               router=router, selector="SimPolicy",
                               backend="jax",
                               admission=AdmissionControl(wave_quota=1024))
        out[router] = fleet.run(trace)
    assert out["whatif"].makespan < out["round_robin"].makespan
    assert out["whatif"].p95 < out["round_robin"].p95


def test_fleet_warm_start_round_trip(tmp_path):
    # Hybrid with a 2-wide window exits its explore phase after
    # expert_steps + window**2 = 6 instances; warm_started() is True only
    # for snapshots taken past that phase (mid-explore ones resume cold)
    kw = dict(n_groups=2, replicas_per_group=4, router="rr",
              selector="Hybrid", seed=3,
              selector_kw=dict(expert_steps=2, window=2),
              admission=AdmissionControl(wave_quota=16),
              store_dir=str(tmp_path / "fleet_store"))
    trace = make_trace("poisson", 600, seed=0, rate=800.0)
    fleet = FleetSimulator(**kw)
    assert fleet.warm_started() == [False, False]
    fleet.run(trace)
    assert all(len(sim.stats) > 6 for sim in fleet.groups)
    paths = fleet.save_state()
    assert len(paths) == 2          # one snapshot per region
    fresh = FleetSimulator(**kw)
    assert fresh.warm_started() == [True, True]
    # regions are keyed independently: a wider fleet only warm-starts the
    # regions it has snapshots for
    wider = FleetSimulator(**{**kw, "n_groups": 3})
    assert wider.warm_started() == [True, True, False]
