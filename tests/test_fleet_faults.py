"""Fault-tolerant fleet serving: failure injection, recovery policy,
accounting invariants, and crash-safe journal resume."""

import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.serving import (AdmissionControl, DispatchSimulator,
                           FleetSimulator, RecoveryPolicy, RunJournal,
                           make_trace)
from repro.serving.fleet.recovery import BASELINE_RECOVERY, RecoveryLedger
from repro.sim.perturb import (FleetPerturb, GroupSlowdown, ReplicaFailure,
                               ReplicaStraggler)

BURSTY = dict(base_rate=2000.0, burst_factor=6.0, p_enter=0.015, p_exit=0.05)


def _fleet(n_groups=3, replicas=4, router="whatif", **kw):
    kw.setdefault("selector", "SimPolicy")
    return FleetSimulator(n_groups=n_groups, replicas_per_group=replicas,
                          router=router, seed=0, **kw)


def _trace(n=1200, seed=7, **params):
    params = {**BURSTY, **params}
    return make_trace("bursty", n, seed=seed, **params)


# ---------------------------------------------------------------------------
# perturb layer: replica-level events
# ---------------------------------------------------------------------------

def test_replica_state_masks_and_scales():
    p = FleetPerturb(
        failures=(ReplicaFailure(group=1, t0=1.0, t1=2.0, replicas=(0, 2)),),
        stragglers=(ReplicaStraggler(group=0, factor=3.0, t0=0.5, t1=1.5),))
    assert p.has_replica_events
    assert p.replica_state(0.0, 2, 4) is None      # nothing active yet
    alive, scale = p.replica_state(1.2, 2, 4)
    assert alive.shape == (2, 4) and scale.shape == (2, 4)
    assert not alive[1, 0] and not alive[1, 2]
    assert alive[1, 1] and alive[0, :].all()
    assert np.allclose(scale[0], 3.0) and np.allclose(scale[1], 1.0)
    # half-open window: inactive exactly at t1 -> back on the clean path
    assert p.replica_state(2.0, 2, 4) is None


def test_failure_start_whole_group_only():
    p = FleetPerturb(failures=(
        ReplicaFailure(group=0, t0=1.0, t1=2.0),                 # whole
        ReplicaFailure(group=1, t0=1.0, t1=2.0, replicas=(0,)),  # partial
        ReplicaFailure(group=0, t0=5.0),                         # permanent
    ))
    assert p.failure_start(0, 3, 4, 0.5, 1.5) == (1.0, 2.0)
    # a partial failure never interrupts in-flight work
    assert p.failure_start(1, 3, 4, 0.5, 1.5) is None
    # strictly-inside window semantics
    assert p.failure_start(0, 3, 4, 1.0, 1.5) is None
    assert p.failure_start(0, 3, 4, 4.0, 9.0) == (5.0, np.inf)
    # a partial set covering every replica IS a whole-group failure
    q = FleetPerturb(failures=(
        ReplicaFailure(group=0, t0=1.0, replicas=(0, 1, 2, 3)),))
    assert q.failure_start(0, 3, 4, 0.0, 2.0) == (1.0, np.inf)


def test_next_change_boundaries():
    p = FleetPerturb(events=(GroupSlowdown(group=0, factor=2.0, t0=3.0),),
                     failures=(ReplicaFailure(group=1, t0=1.0, t1=2.0),))
    assert p.next_change(0.0) == 1.0
    assert p.next_change(1.0) == 2.0
    assert p.next_change(2.5) == 3.0
    assert p.next_change(3.0) is None


# ---------------------------------------------------------------------------
# engine layer: masked / straggling replicas in one wave
# ---------------------------------------------------------------------------

def test_run_wave_active_mask():
    reqs = _trace(64).requests
    a = DispatchSimulator(4, selector="SimPolicy", seed=0)
    b = DispatchSimulator(4, selector="SimPolicy", seed=0)
    a.run_wave(list(reqs))
    # all-true mask is normalized to the exact unmasked path
    b.run_wave(list(reqs), active=np.ones(4, dtype=bool))
    assert np.array_equal(a.busy, b.busy)

    c = DispatchSimulator(4, selector="SimPolicy", seed=0)
    mask = np.array([True, False, True, True])
    stat = c.run_wave(list(reqs), active=mask)
    assert c.busy[1] == 0.0            # dead replica got no work
    assert (c.busy[mask] > 0).all()
    assert stat.n_requests == len(reqs)

    d = DispatchSimulator(4, selector="SimPolicy", seed=0)
    with pytest.raises(ValueError):
        d.run_wave(list(reqs), active=np.zeros(4, dtype=bool))


def test_run_wave_replica_scale_slows():
    reqs = _trace(64).requests
    a = DispatchSimulator(4, selector="SimPolicy", seed=0)
    a.run_wave(list(reqs))
    b = DispatchSimulator(4, selector="SimPolicy", seed=0)
    b.run_wave(list(reqs), replica_scale=np.array([8.0, 1.0, 1.0, 1.0]))
    assert b.busy.max() > a.busy.max()
    # all-ones scale is normalized to the exact unscaled path
    c = DispatchSimulator(4, selector="SimPolicy", seed=0)
    c.run_wave(list(reqs), replica_scale=np.ones(4))
    assert np.array_equal(a.busy, c.busy)


# ---------------------------------------------------------------------------
# routers under a failure-aware view
# ---------------------------------------------------------------------------

def _dead_group_view(G=3, R=2, dead=1):
    from repro.serving import FleetView, ReplicaCostModel
    from repro.sim.backends import get_backend

    routable = np.ones(G, dtype=bool)
    routable[dead] = False
    return FleetView(now=0.0, busy=[np.zeros(R) for _ in range(G)],
                     n_replicas=R, cost=ReplicaCostModel(), h=0.2e-3,
                     backend=get_backend(None), routable=routable)


@pytest.mark.parametrize("router", ["round_robin", "least_outstanding",
                                    "whatif"])
def test_routers_avoid_dead_groups(router):
    from repro.serving import make_router

    reqs = _trace(40).requests
    view = _dead_group_view(dead=1)
    shards = make_router(router).route(list(reqs), view)
    assert len(shards) == 3
    assert shards[1] == []
    assert sum(len(s) for s in shards) == len(reqs)


def test_round_robin_cursor_state_roundtrip():
    from repro.serving import make_router

    reqs = _trace(10).requests
    r1 = make_router("round_robin")
    r1.route(list(reqs), _dead_group_view(dead=1))
    state = r1.state_dict()
    r2 = make_router("round_robin")
    r2.load_state_dict(state)
    v = _dead_group_view(dead=1)
    assert [[q.rid for q in s] for s in r1.route(list(reqs), v)] == \
        [[q.rid for q in s] for s in r2.route(list(reqs), v)]


# ---------------------------------------------------------------------------
# recovery policy mechanics
# ---------------------------------------------------------------------------

def test_backoff_deterministic_capped():
    rp = RecoveryPolicy(backoff_base=0.01, backoff_factor=2.0,
                        backoff_cap=0.05, jitter=0.3)
    seq = [rp.backoff(42, a, seed=3) for a in range(1, 6)]
    assert seq == [rp.backoff(42, a, seed=3) for a in range(1, 6)]
    assert all(b <= 0.05 * 1.3 + 1e-12 for b in seq)
    assert rp.backoff(42, 1, seed=3) != rp.backoff(43, 1, seed=3)
    assert BASELINE_RECOVERY.backoff(42, 1) == 0.0
    assert not BASELINE_RECOVERY.exhausted(10 ** 6)
    assert RecoveryPolicy(max_retries=2).exhausted(3)
    assert not RecoveryPolicy(max_retries=2).exhausted(2)


def test_ledger_accounting_check():
    led = RecoveryLedger()
    led.record_retry(1)
    led.dead_letter(2, "max_retries")
    with pytest.raises(AssertionError):
        led.check(10, 8)               # 8 + 1 dead != 10
    led.check(9, 8)


def _outage_perturb(duration, group=1, frac=(0.25, 0.6)):
    return FleetPerturb(failures=(
        ReplicaFailure(group=group, t0=duration * frac[0],
                       t1=duration * frac[1]),))


@pytest.mark.parametrize("router", ["whatif", "least_outstanding"])
def test_interrupted_work_retried_and_accounted(router):
    trace = _trace(1500)
    sim = _fleet(router=router,
                 perturb=_outage_perturb(trace.duration),
                 recovery=RecoveryPolicy(max_retries=6))
    rep = sim.run(trace, keep_latencies=True)
    r = rep.recovery
    assert r is not None
    assert r["completed"] + r["dead_lettered"] == len(trace)
    assert r["interrupted"] > 0 and r["retries"] >= r["interrupted"]
    assert r["dead_lettered"] == 0     # transient outage: nothing lost
    assert len(rep.latencies) == r["completed"]


def test_recovery_beats_blind_baseline():
    trace = _trace(3000)
    pert = _outage_perturb(trace.duration)
    on = _fleet(perturb=pert, recovery=RecoveryPolicy(max_retries=6)) \
        .run(trace)
    off = _fleet(perturb=pert, recovery=None).run(trace)
    assert off.recovery["completed"] == len(trace)   # baseline loses nothing
    assert on.makespan < off.makespan
    assert on.p95 < off.p95


def test_permanent_failure_dead_letters_with_budget():
    trace = _trace(800)
    # group 1 dies at 25% of the trace and never rejoins; retries are
    # PINNED to it, so its interrupted work must exhaust the budget
    pert = FleetPerturb(failures=(
        ReplicaFailure(group=1, t0=trace.duration * 0.25),))
    sim = _fleet(perturb=pert,
                 recovery=RecoveryPolicy(max_retries=1, migrate=False,
                                         backoff_base=0.05,
                                         backoff_cap=0.05))
    rep = sim.run(trace)
    r = rep.recovery
    assert r["dead_lettered"] > 0
    assert r["dead_by_reason"] == {"max_retries": r["dead_lettered"]}
    assert r["completed"] + r["dead_lettered"] == len(trace)


def test_permanent_failure_unbounded_baseline_raises():
    trace = _trace(600)
    pert = FleetPerturb(failures=(
        ReplicaFailure(group=0, t0=trace.duration * 0.2),))
    # blind unbounded baseline keeps feeding a group that never rejoins
    sim = _fleet(router="round_robin", perturb=pert, recovery=None)
    with pytest.raises(RuntimeError, match="permanently"):
        sim.run(trace)


def test_permanent_failure_visible_migration_completes():
    trace = _trace(800)
    pert = FleetPerturb(failures=(
        ReplicaFailure(group=0, t0=trace.duration * 0.2),))
    rep = _fleet(perturb=pert, recovery=RecoveryPolicy(max_retries=6)) \
        .run(trace)
    assert rep.recovery["completed"] == len(trace)
    assert rep.per_group[0]["busy_s"] < rep.per_group[1]["busy_s"]


def test_all_groups_down_waits_out_the_window():
    trace = _trace(500)
    d = trace.duration
    pert = FleetPerturb(failures=tuple(
        ReplicaFailure(group=g, t0=d * 0.3, t1=d * 0.6) for g in range(3)))
    rep = _fleet(perturb=pert, recovery=RecoveryPolicy(max_retries=8)) \
        .run(trace)
    assert rep.recovery["completed"] + rep.recovery["dead_lettered"] \
        == len(trace)
    assert rep.recovery["dead_lettered"] == 0


def test_shed_wait_degrades_deterministically():
    trace = _trace(2000)
    d = trace.duration
    pert = FleetPerturb(failures=tuple(       # deep outage: 2 of 3 groups
        ReplicaFailure(group=g, t0=d * 0.2, t1=d * 0.9) for g in (0, 1)))

    def run():
        # queue-depth backpressure makes the degraded fleet hold work in
        # the pending queue — that wait is what shed_wait bounds
        return _fleet(perturb=pert,
                      admission=AdmissionControl(wave_quota=64,
                                                 queue_depth=0.1),
                      recovery=RecoveryPolicy(max_retries=6,
                                              shed_wait=0.2)).run(trace)

    rep = run()
    r = rep.recovery
    assert r["shed"] > 0
    assert r["dead_by_reason"].get("shed") == r["shed"]
    assert r["completed"] + r["dead_lettered"] == len(trace)
    assert run().summary() == rep.summary()   # shedding is deterministic


def test_hedge_first_finish_wins_and_accounts():
    trace = _trace(1500)
    pert = _outage_perturb(trace.duration, frac=(0.2, 0.7))
    rep = _fleet(perturb=pert,
                 recovery=RecoveryPolicy(max_retries=6, hedge=True)) \
        .run(trace)
    r = rep.recovery
    assert r["hedges"] > 0
    assert 0 <= r["hedge_wins"] <= r["hedges"]
    assert r["completed"] + r["dead_lettered"] == len(trace)


def test_timeout_cancels_and_retries():
    trace = _trace(1200)
    pert = _outage_perturb(trace.duration)
    rep = _fleet(perturb=pert,
                 recovery=RecoveryPolicy(timeout=0.02, max_retries=8)) \
        .run(trace, keep_latencies=True)
    r = rep.recovery
    assert r["timeouts"] > 0
    assert r["completed"] + r["dead_lettered"] == len(trace)
    assert len(rep.latencies) == r["completed"]


# ---------------------------------------------------------------------------
# clean-path neutrality + re-entrancy
# ---------------------------------------------------------------------------

def test_armed_recovery_without_events_is_bit_neutral():
    trace = _trace(1000)
    clean = _fleet().run(trace, keep_latencies=True)
    armed = _fleet(recovery=RecoveryPolicy()) \
        .run(trace, keep_latencies=True)
    s_clean = clean.summary()
    s_armed = {k: v for k, v in armed.summary().items() if k != "recovery"}
    assert s_clean == s_armed
    assert np.array_equal(clean.latencies, armed.latencies)
    assert armed.recovery["retries"] == 0
    assert armed.recovery["completed"] == len(trace)


def test_run_is_single_shot():
    trace = _trace(200)
    sim = _fleet()
    sim.run(trace)
    with pytest.raises(RuntimeError, match="single-shot"):
        sim.run(trace)


# ---------------------------------------------------------------------------
# journal: atomicity, retention, corruption tolerance
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_retention(tmp_path):
    j = RunJournal(str(tmp_path), keep=2)
    for w in (3, 6, 9):
        j.save(w, {"now": float(w)}, {"x": np.arange(w)})
    assert j.waves() == [6, 9]         # keep=2 retention
    snap = j.load(9)
    assert snap["meta"]["now"] == 9.0 and snap["meta"]["wave"] == 9
    assert np.array_equal(snap["x"], np.arange(9))
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    j.clear()
    assert j.waves() == [] and j.latest() is None


def test_journal_latest_skips_corrupt(tmp_path):
    j = RunJournal(str(tmp_path), keep=0)
    j.save(1, {"now": 1.0}, {"x": np.ones(2)})
    j.save(2, {"now": 2.0}, {"x": np.ones(2)})
    with open(os.path.join(str(tmp_path), "wave_000000002.npz"), "wb") as f:
        f.write(b"torn write")
    with pytest.warns(UserWarning, match="unreadable journal"):
        snap = j.latest()
    assert snap["meta"]["wave"] == 1   # fell back to the older snapshot


def test_journal_version_guard(tmp_path):
    j = RunJournal(str(tmp_path))
    path = j.save(1, {"now": 1.0}, {"x": np.ones(2)})
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"].tobytes()).decode())
        arrays = {k: z[k] for k in z.files if k != "meta"}
    meta["version"] = 99
    payload = dict(arrays)
    payload["meta"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    with open(path, "wb") as f:
        np.savez(f, **payload)
    with pytest.raises(ValueError, match="version"):
        j.load(1)


# ---------------------------------------------------------------------------
# kill-at-arbitrary-wave resume: bit-identical reports
# ---------------------------------------------------------------------------

def _resume_from(tmp_path, tag, wave, build, trace):
    """Copy snapshot ``wave`` into a fresh journal dir and resume there."""
    import shutil

    d = os.path.join(str(tmp_path), f"resume_{tag}_{wave}")
    os.makedirs(d)
    shutil.copy(os.path.join(str(tmp_path), "full",
                             f"wave_{wave:09d}.npz"), d)
    return build().run(trace, keep_latencies=True,
                       journal=RunJournal(d, every=3, keep=0), resume=True)


@pytest.mark.parametrize("faulty", [False, True])
def test_resume_bit_identical_from_any_wave(tmp_path, faulty):
    trace = _trace(1200)
    pert = _outage_perturb(trace.duration) if faulty else None
    rec = RecoveryPolicy(max_retries=6) if faulty else None

    def build():
        return _fleet(n_groups=3, replicas=3, perturb=pert, recovery=rec)

    full_dir = os.path.join(str(tmp_path), "full")
    ref = build().run(trace, keep_latencies=True,
                      journal=RunJournal(full_dir, every=3, keep=0))
    waves = RunJournal(full_dir, every=3, keep=0).waves()
    assert len(waves) >= 3
    # resume from an early, a middle, and the final snapshot — every one
    # must reproduce the uninterrupted report bit-for-bit
    for wave in (waves[0], waves[len(waves) // 2], waves[-1]):
        res = _resume_from(tmp_path, "f" if faulty else "c", wave,
                           build, trace)
        assert res.summary() == ref.summary(), f"diverged from wave {wave}"
        assert np.array_equal(res.latencies, ref.latencies)


def test_resume_guards(tmp_path):
    trace = _trace(400)
    j = RunJournal(str(tmp_path), every=2, keep=0)
    _fleet(n_groups=2, replicas=2).run(trace, journal=j)
    # wrong trace
    with pytest.raises(ValueError, match="cannot resume"):
        _fleet(n_groups=2, replicas=2).run(_trace(400, seed=8),
                                           journal=j, resume=True)
    # wrong fleet shape
    with pytest.raises(ValueError, match="shape"):
        _fleet(n_groups=3, replicas=2).run(trace, journal=j, resume=True)
    # wrong router family
    with pytest.raises(ValueError, match="router"):
        _fleet(n_groups=2, replicas=2, router="round_robin") \
            .run(trace, journal=j, resume=True)
    # resume without a snapshot
    with pytest.raises(ValueError, match="no journal"):
        _fleet(n_groups=2, replicas=2).run(
            trace, journal=RunJournal(os.path.join(str(tmp_path), "empty")),
            resume=True)


# ---------------------------------------------------------------------------
# property: the accounting invariant across scenario space
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(router=st.sampled_from(["whatif", "least_outstanding"]),
       scenario=st.sampled_from(["outage", "permanent", "straggle", "none"]),
       hedge=st.booleans(), migrate=st.booleans())
def test_every_request_completed_once_or_dead_lettered(router, scenario,
                                                       hedge, migrate):
    trace = _trace(500, seed=11)
    d = trace.duration
    pert = {
        "outage": FleetPerturb(failures=(
            ReplicaFailure(group=1, t0=d * 0.2, t1=d * 0.7),)),
        "permanent": FleetPerturb(failures=(
            ReplicaFailure(group=2, t0=d * 0.3),)),
        "straggle": FleetPerturb(stragglers=(
            ReplicaStraggler(group=0, factor=4.0, t0=d * 0.1, t1=d * 0.8,
                             replicas=(0, 1)),)),
        "none": None,
    }[scenario]
    rec = RecoveryPolicy(max_retries=2, hedge=hedge, migrate=migrate,
                         backoff_base=0.05, backoff_cap=0.1)
    rep = _fleet(router=router, perturb=pert, recovery=rec) \
        .run(trace, keep_latencies=True)
    r = rep.recovery
    # the invariant: completed exactly once + dead-lettered == admitted
    assert r["completed"] + r["dead_lettered"] == len(trace)
    assert len(rep.latencies) == r["completed"]
    assert r["hedge_wins"] <= r["hedges"]
    if scenario == "none":
        assert r["completed"] == len(trace) and r["retries"] == 0
