"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (3, 17, 64), (2, 5, 9, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, shape, dtype)
    w = jax.random.normal(k2, shape[-1:], dtype)
    out = ops.rmsnorm(x, w, block_rows=16)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,K,hd", [
    (1, 128, 128, 4, 4, 64),     # MHA square
    (2, 96, 160, 8, 2, 32),      # GQA, ragged lengths, padding path
    (1, 257, 129, 6, 3, 64),     # non-multiple-of-block sizes
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, T, H, K, hd, causal, dtype):
    if causal and S > T:
        pytest.skip("causal with S>T undefined in this harness")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, K, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_matches_model_chunked_attention():
    from repro.models.layers import chunked_attention
    ks = jax.random.split(KEY, 3)
    B, S, H, K, hd = 2, 256, 8, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    b = chunked_attention(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4,
                               atol=5e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,S,nh,hp,st,chunk,hb", [
    (1, 64, 4, 32, 16, 16, 4),
    (2, 128, 8, 32, 16, 32, 4),
    (1, 96, 6, 16, 8, 32, 2),    # S not multiple of 64; nh=6 hb=2
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, S, nh, hp, st, chunk, hb, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (b, S, nh, hp)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, S, st)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[4], (b, S, st)) * 0.5).astype(dtype)
    y, h = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, head_block=hb)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 5e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 5e-4)


def test_ssd_kernel_matches_model_path():
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    b, S, nh, hp, st = 2, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (b, S, nh, hp)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, st)) * 0.5
    C = jax.random.normal(ks[4], (b, S, st)) * 0.5
    y1, h1 = ops.ssd_scan(x, dt, A, B, C, chunk=32, head_block=4)
    y2, h2 = ssd_chunked(x, dt, A, B, C, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-4,
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=5e-4,
                               atol=5e-4)
