"""Learned selection: featurizer contract, registry + warm-start
persistence, the counterfactual transition logger, the policy trainer's
checkpoint/restart discipline (bit-identical resume, SIGTERM final save,
failure-injection equivalence), LearnedHybrid's net-pruned RL window, and
ladder distillation."""

import json
import os
import signal

import numpy as np
import pytest

from repro.core import (FEATURE_NAMES, N_ALGORITHMS, N_FEATURES,
                        LearnedHybrid, LearnedPolicy, LoopFeaturizer,
                        SelectionService, SimUnavailable, distill_ladder,
                        is_learned_policy, make_learned_state, make_policy,
                        set_default_state)
from repro.core.learned import (LEARNED_STATE_ENV, mlp_forward,
                                params_from_state)
from repro.sim import (CellSpec, ReplayBatch, TransitionLogger,
                       get_application, get_system, load_shards,
                       load_translog, pe_slowdown_spec, run_selector)
from repro.runtime.policy_trainer import (PolicyTrainer, PolicyTrainerConfig,
                                          TransitionDataset)

HIDDEN = 2


def const_state(scores, reward="LT"):
    """A learned state whose net outputs the constant ``scores`` vector for
    every input (all-zero weights, biases only) — exact, training-free
    ranking control for policy tests."""
    scores = np.asarray(scores, np.float32)
    params = {
        "w0": np.zeros((N_FEATURES, HIDDEN), np.float32),
        "b0": np.zeros((HIDDEN,), np.float32),
        "w1": np.zeros((HIDDEN, HIDDEN), np.float32),
        "b1": np.zeros((HIDDEN,), np.float32),
        "w2": np.zeros((HIDDEN, len(scores)), np.float32),
        "b2": scores,
    }
    return make_learned_state(params, reward=reward)


def synth_arrays(n=192, seed=0, n_actions=N_ALGORITHMS):
    """Synthetic translog: the best algorithm flips on the sign of feature
    0 (a learnable threshold rule with a known ladder form)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, N_FEATURES)).astype(np.float32)
    costs = rng.uniform(1.0, 2.0, size=(n, n_actions))
    best = np.where(X[:, 0] > 0.0, 3, 7)
    costs[np.arange(n), best] = 0.5
    return {
        "features": X, "costs": costs.astype(np.float32),
        "libs": np.zeros((n, n_actions), np.float32),
        "chosen": np.zeros(n, np.int16),
        "measured": np.zeros(n, np.float32),
        "cell": (np.arange(n) % 2).astype(np.int32),
        "step": np.zeros(n, np.int32),
        "perturbed": np.zeros(n, np.bool_),
        "cell_keys": np.array(["a|x", "b|y"]),
    }


# ---------------------------------------------------------------------------
# featurizer
# ---------------------------------------------------------------------------

def test_featurizer_contract():
    fz = LoopFeaturizer(get_system("broadwell"))
    with pytest.raises(SimUnavailable):
        fz.features()
    profile = get_application("tc").loops(0)[0]
    fz.set_context(profile, 0)
    x = fz.features(phase=0.5)
    assert x.shape == (N_FEATURES,) and x.dtype == np.float32
    assert np.isfinite(x).all()
    assert x[FEATURE_NAMES.index("phase")] == 0.5
    # cov feature reflects tc's power-law imbalance
    assert x[FEATURE_NAMES.index("cov")] > 0.5
    # chunk_norm responds to the chunk parameter
    fz.set_context(profile, 64)
    assert x[FEATURE_NAMES.index("chunk_norm")] != \
        fz.features(phase=0.5)[FEATURE_NAMES.index("chunk_norm")]


def test_featurizer_perturbation_telemetry():
    system = get_system("epyc")
    spec = pe_slowdown_spec(system.P, frac=0.25, factor=6.0, t0=0)
    ip = spec.instance_perturb(0, system.P)
    profile = get_application("hacc").loops(0)[0]
    fz = LoopFeaturizer(system)
    fz.set_context(profile, 0)
    clean = fz.features()
    fz.set_context(profile, 0, perturb=ip)
    hot = fz.features()
    i_cov = FEATURE_NAMES.index("pe_cov")
    i_ratio = FEATURE_NAMES.index("pe_max_ratio")
    assert clean[i_cov] == 0.0 and clean[i_ratio] == 0.0
    assert hot[i_cov] > 0.0 and hot[i_ratio] > 0.0
    # heterogeneous pe_speeds show up without any perturbation
    fz_het = LoopFeaturizer(get_system("epyc_het"))
    fz_het.set_context(profile, 0)
    assert fz_het.features()[i_cov] > 0.0


# ---------------------------------------------------------------------------
# policy + registry + persistence
# ---------------------------------------------------------------------------

def test_learned_cold_falls_back_to_expert():
    p = make_policy("Learned")
    assert isinstance(p, LearnedPolicy) and not p.trained
    assert p.decide().phase == "expert"
    assert p.learning_steps > 0          # the expert fallback still learns


def test_learned_policy_scores_and_confidence():
    fz = LoopFeaturizer(get_system("broadwell"))
    fz.set_context(get_application("tc").loops(0)[0], 0)
    scores = np.arange(N_ALGORITHMS)[::-1].astype(float)   # best = last
    p = make_policy("Learned", featurizer=fz,
                    state=const_state(scores))
    assert p.trained and p.learning_steps == 0
    d = p.decide()
    assert d.action == N_ALGORITHMS - 1 and d.phase == "exploit"
    assert 0.0 < d.confidence <= 1.0


def test_learned_state_roundtrip_and_validation():
    p = LearnedPolicy(state=const_state(np.arange(N_ALGORITHMS)))
    state = p.state_dict()
    q = LearnedPolicy()
    assert q.load_state_dict(state) is True
    assert q.state_dict()["params"] == state["params"]
    bad = dict(state, feature_version=-7)
    with pytest.raises(ValueError):
        LearnedPolicy().load_state_dict(bad)
    with pytest.raises(ValueError):
        LearnedPolicy(n_actions=5).load_state_dict(state)


def test_learned_env_default_state(tmp_path, monkeypatch):
    path = tmp_path / "weights.json"
    path.write_text(json.dumps(const_state(np.arange(N_ALGORITHMS))))
    monkeypatch.setenv(LEARNED_STATE_ENV, str(path))
    assert make_policy("Learned").trained
    # a corrupt file degrades to a cold policy instead of raising
    path.write_text("{not json")
    with pytest.warns(UserWarning):
        assert not make_policy("Learned").trained


def test_learned_registry_and_aliases():
    assert is_learned_policy("learned") and is_learned_policy("LearnedHybrid")
    assert not is_learned_policy("QLearn") and not is_learned_policy(None)
    assert isinstance(make_policy("mlp"), LearnedPolicy)
    assert isinstance(make_policy("LearnedHybrid"), LearnedHybrid)


def test_learned_service_warm_start(tmp_path):
    state = const_state(np.arange(N_ALGORITHMS))
    svc = SelectionService("Learned", store_dir=str(tmp_path), seed=0,
                          state=state)
    with svc.instance("loop0") as inst:
        assert inst.decision.action == 0
    svc.save()
    # a fresh service restores the trained net from the store
    svc2 = SelectionService("Learned", store_dir=str(tmp_path), seed=0)
    rec = svc2._record("loop0")
    assert rec.warm_started and rec.policy.trained


# ---------------------------------------------------------------------------
# transition logger
# ---------------------------------------------------------------------------

def test_translog_counterfactual_rows(tmp_path):
    tl = TransitionLogger()
    run_selector("tc", "broadwell", "ExpertSel", T=5, seed=0, translog=tl)
    assert len(tl) == 5
    arr = tl.arrays()
    assert arr["features"].shape == (5, N_FEATURES)
    assert arr["costs"].shape == (5, N_ALGORITHMS)
    assert (arr["costs"] > 0).all()
    assert (arr["measured"] >= 0).all()       # live outcomes were attached
    path = tl.save(str(tmp_path / "shard.npz"))
    back = load_translog(path)
    np.testing.assert_array_equal(back["costs"], arr["costs"])
    assert [str(k) for k in back["cell_keys"]] == ["tc|broadwell"]


def test_translog_replay_bit_identical():
    """Logging must not perturb the replay: pricing draws from the what-if's
    fixed stateless seed, never the lane rng."""
    plain = run_selector("hacc", "epyc", "QLearn", reward="LT", T=6, seed=0)
    logged = run_selector("hacc", "epyc", "QLearn", reward="LT", T=6, seed=0,
                          translog=TransitionLogger())
    assert plain.total == logged.total
    assert plain.history == logged.history


def test_translog_dedupe_and_shard_merge(tmp_path):
    tl = TransitionLogger()
    # two lanes, identical decision context -> rows are logged once
    ReplayBatch([CellSpec(app="tc", system="broadwell", selector="ExpertSel"),
                 CellSpec(app="tc", system="broadwell", selector="RandomSel")],
                T=4, seed=0, translog=tl).run()
    assert len(tl) == 4
    p1 = tl.save(str(tmp_path / "a.npz"))
    tl2 = TransitionLogger()
    ReplayBatch([CellSpec(app="hacc", system="epyc",
                          selector="ExpertSel")],
                T=3, seed=0, translog=tl2).run()
    p2 = tl2.save(str(tmp_path / "b.npz"))
    merged = load_shards([p1, p2])
    assert len(merged["features"]) == 7
    keys = [str(k) for k in merged["cell_keys"]]
    assert keys == ["tc|broadwell", "hacc|epyc"]
    assert [keys[c] for c in merged["cell"]] == \
        ["tc|broadwell"] * 4 + ["hacc|epyc"] * 3


# ---------------------------------------------------------------------------
# policy trainer: the Trainer checkpoint/restart discipline
# ---------------------------------------------------------------------------

def _trainer(tmp, arrays, n_steps=40, **kw):
    ds = TransitionDataset(arrays)
    cfg = PolicyTrainerConfig(ckpt_dir=str(tmp), hidden=8, n_steps=n_steps,
                              batch_size=32, ckpt_every=10, async_ckpt=False,
                              **kw)
    return PolicyTrainer(ds, cfg)


def _params_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


def test_policy_trainer_resume_bit_identical(tmp_path):
    """An interrupted run restored from its checkpoint replays to EXACTLY
    the uninterrupted result — batches are pure in (seed, step)."""
    arrays = synth_arrays()
    clean = _trainer(tmp_path / "clean", arrays).train()
    tr = _trainer(tmp_path / "cut", arrays)
    tr.train(20)                           # "the process died at step 20"
    resumed = _trainer(tmp_path / "cut", arrays).train()
    assert resumed["final_step"] == clean["final_step"] == 40
    assert _params_equal(clean["params"], resumed["params"])
    assert _params_equal(dict(clean["opt"].m), dict(resumed["opt"].m))


def test_policy_trainer_failure_restart_equivalence(tmp_path):
    """Injected node failures (restore latest + replay) reach the same
    final parameters as a clean run, like runtime.Trainer."""
    arrays = synth_arrays()
    clean = _trainer(tmp_path / "clean", arrays).train()
    tr = _trainer(tmp_path / "faulty", arrays, failure_rate=0.1,
                  failure_seed=7)
    faulty = tr.train()
    assert faulty["restarts"] > 0, "failure injection never fired"
    assert _params_equal(clean["params"], faulty["params"])
    assert faulty["final_step"] == 40


def test_policy_trainer_sigterm_final_save(tmp_path):
    """SIGTERM mid-run: the loop finishes the current step, takes a final
    synchronous checkpoint at that step, and a relaunch resumes to the
    uninterrupted result."""
    arrays = synth_arrays()
    tr = _trainer(tmp_path / "pre", arrays)
    old = signal.getsignal(signal.SIGTERM)
    try:
        tr.install_preemption_handler()
        orig = tr.ds.batch_at
        calls = {"n": 0}

        def batch_at(step, batch_size):
            calls["n"] += 1
            if calls["n"] == 14:
                os.kill(os.getpid(), signal.SIGTERM)
            return orig(step, batch_size)

        tr.ds.batch_at = batch_at
        out = tr.train()
    finally:
        signal.signal(signal.SIGTERM, old)
    assert out["preempted"] and out["final_step"] == 14
    assert tr.ckpt.latest_step() == 14     # the final save, not step 10
    tr.ds.batch_at = orig
    resumed = _trainer(tmp_path / "pre", arrays).train()
    clean = _trainer(tmp_path / "clean", arrays).train()
    assert _params_equal(clean["params"], resumed["params"])


def test_policy_trainer_export_folds_normalization(tmp_path):
    """The exported state consumes RAW feature rows: normalization is
    folded into the first layer, and the deployed numpy forward matches
    the training-side ranking."""
    arrays = synth_arrays()
    tr = _trainer(tmp_path, arrays, n_steps=600)
    result = tr.train()
    state = tr.export_state(result["params"])
    params = params_from_state(state["params"])
    X = arrays["features"]
    pick = np.argmin(mlp_forward(params, X), axis=1)
    best = np.argmin(arrays["costs"], axis=1)
    assert (pick == best).mean() > 0.9     # the rule is learnable
    # regret through the deployed path matches the trainer's measure
    assert tr.regret(result["params"], "train") < 0.05


def test_transition_dataset_holdout_split():
    arrays = synth_arrays()
    ds = TransitionDataset(arrays, holdout_cells=["b|y"])
    assert ds.n_train == 96 and len(ds.holdout_idx) == 96
    assert set(ds.cell[ds.holdout_idx]) == {1}
    with pytest.raises(ValueError):
        TransitionDataset(arrays, holdout_cells=["nope|nope"])
    x1, y1 = ds.batch_at(5, 16)
    x2, y2 = ds.batch_at(5, 16)
    np.testing.assert_array_equal(x1, x2)    # pure in (seed, step)
    assert y1.shape == (16, N_ALGORITHMS)


# ---------------------------------------------------------------------------
# LearnedHybrid
# ---------------------------------------------------------------------------

def test_learnedhybrid_window_is_net_topk():
    fz = LoopFeaturizer(get_system("broadwell"))
    fz.set_context(get_application("tc").loops(0)[0], 0)
    scores = np.arange(N_ALGORITHMS, dtype=float)
    scores[[9, 4, 11, 6]] = [-4, -3, -2, -1]       # net's top-4
    p = make_policy("LearnedHybrid", featurizer=fz,
                    state=const_state(scores), top_k=4, expert_steps=1)
    obs_kw = dict(loop_time=1.0, lib=5.0)
    from repro.core import Observation
    d = p.decide()
    assert d.phase == "expert"
    p.feedback(d, Observation(**obs_kw))
    d = p.decide()                                  # builds the RL window
    assert sorted(p.actions) == [4, 6, 9, 11]
    assert d.action in p.actions
    assert p.learning_steps == 1 + 16


def test_learnedhybrid_cold_uses_expert_window():
    p = make_policy("LearnedHybrid", top_k=4, expert_steps=1)
    from repro.core import Observation
    d = p.decide()
    p.feedback(d, Observation(loop_time=1.0, lib=5.0))
    p.decide()
    # no net, no context: HybridPolicy's contiguous expert window applies
    assert p.actions == list(range(p.actions[0], p.actions[0] + 4))


# ---------------------------------------------------------------------------
# distillation
# ---------------------------------------------------------------------------

def test_distill_ladder_recovers_threshold_rule(tmp_path):
    arrays = synth_arrays(n=400)
    tr = _trainer(tmp_path, arrays, n_steps=600)
    state = tr.export_state(tr.train()["params"])
    ladder = distill_ladder(state, arrays["features"], max_depth=2)
    assert ladder.teacher_agreement > 0.9
    # the ladder is the known generating rule: a split on feature 0
    pred = ladder.predict(arrays["features"])
    best = np.argmin(arrays["costs"], axis=1)
    assert (pred == best).mean() > 0.85
    rules = ladder.describe()
    assert 1 < len(rules) <= 4
    assert any(FEATURE_NAMES[0] in r for r in rules)


def test_distill_requires_trained_net():
    with pytest.raises(ValueError):
        distill_ladder(LearnedPolicy(), np.zeros((4, N_FEATURES)))


# ---------------------------------------------------------------------------
# campaign integration
# ---------------------------------------------------------------------------

def test_learned_lane_in_campaign_uses_default_state():
    """A trained default state turns campaign 'Learned' lanes into pure
    exploit lanes; the teardown resets the process default."""
    scores = np.zeros(N_ALGORITHMS)
    scores[5] = -1.0                       # the net always picks alg 5
    set_default_state(const_state(scores))
    try:
        run = run_selector("tc", "broadwell", "Learned", T=4, seed=0)
    finally:
        set_default_state(None)
    algs = {a for h in run.history.values() for a, _, _ in h}
    assert algs == {5}
