"""Per-architecture smoke tests: reduced configs of the same family run one
forward/train step and a two-token decode on CPU — shapes + no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_reduce, SHAPES, applicable
from repro.models import (decode_step, forward, init_decode_cache,
                          init_params, loss_fn, prefill)
from repro.launch.steps import make_train_step
from repro.optim.adamw import AdamWConfig

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_loss(arch):
    cfg = smoke_reduce(get_config(arch))
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, aux = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    if cfg.family == "moe":
        assert "expert_load" in aux
        assert aux["expert_load"].shape == (cfg.n_layers, cfg.n_experts)
        # all routed tokens accounted for
        total = int(aux["expert_load"].sum())
        assert total == cfg.n_layers * 2 * 32 * cfg.experts_per_token


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step(arch):
    cfg = smoke_reduce(get_config(arch))
    params = init_params(cfg, KEY)
    from repro.optim.adamw import adamw_init
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch(cfg)
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert bool(jnp.isfinite(m1["loss"])) and bool(jnp.isfinite(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 1.0   # not diverging
    assert int(o2.step) == 2
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, t: acc + float(jnp.abs(t).sum()),
        jax.tree.map(lambda a, b: (a.astype(jnp.float32)
                                   - b.astype(jnp.float32)), params, p1), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_two_tokens(arch):
    cfg = smoke_reduce(get_config(arch))
    params = init_params(cfg, KEY)
    B, MAXLEN = 2, 64
    cache = init_decode_cache(cfg, B, MAXLEN)
    cache["len"] = jnp.asarray(8, jnp.int32)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache = step(params, cache, tok)
    logits2, cache = step(params, cache, tok + 1)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all() & jnp.isfinite(logits2).all())
    assert int(cache["len"]) == 10


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-2.7b", "zamba2-7b",
                                  "olmoe-1b-7b", "whisper-small"])
def test_prefill_then_decode_consistency(arch):
    """Prefill(tokens) then decode(next) equals forward over tokens+next —
    validates cache correctness per family."""
    cfg = smoke_reduce(get_config(arch))
    # capacity drops would (legitimately) break prefill/forward equivalence
    cfg = dataclasses.replace(cfg, remat=False, capacity_factor=8.0)
    params = init_params(cfg, KEY)
    B, S = 1, 16
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    embeds = (jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
              if cfg.family == "encdec" else None)

    logits_p, cache = prefill(cfg, params, toks[:, :S], embeds=embeds)
    # pad the kv cache to allow one more token
    def pad_seq(a, axis):
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, 8)
        return jnp.pad(a, pad)
    if "k" in cache:
        cache["k"] = pad_seq(cache["k"], 2)
        cache["v"] = pad_seq(cache["v"], 2)
    logits_d, _ = decode_step(cfg, params, cache, toks[:, S])

    hidden, _, _ = forward(cfg, params, toks, embeds=embeds)
    from repro.models.model import logits_fn
    want = logits_fn(cfg, params, hidden[:, -1:, :])[:, 0]
    import numpy as np
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_applicability_rules():
    n_run, n_skip = 0, 0
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = applicable(cfg, shape)
            if ok:
                n_run += 1
            else:
                n_skip += 1
                assert shape.name == "long_500k"
                assert not cfg.sub_quadratic
    assert n_run + n_skip == 40          # the assigned 40 cells
    assert n_skip == 8                   # 8 pure full-attention archs


def test_param_counts_sane():
    approx = {"qwen3-32b": 32e9, "granite-8b": 8e9, "mistral-nemo-12b": 12e9,
              "llama3.2-3b": 3.2e9, "mamba2-2.7b": 2.7e9,
              "olmoe-1b-7b": 7e9, "grok-1-314b": 314e9,
              "qwen2-vl-72b": 72e9, "zamba2-7b": 7e9,
              "whisper-small": 0.24e9}
    for arch, want in approx.items():
        got = get_config(arch).n_params()
        assert 0.5 * want < got < 1.9 * want, (arch, got, want)
    # MoE active < total
    assert get_config("olmoe-1b-7b").active_params() < \
        get_config("olmoe-1b-7b").n_params() / 4
