"""Perturbation & drift injection layer (repro.sim.perturb) and the
reactive re-pricing policies built on it.

Covers, per ISSUE 8:
- bit-equality by construction: neutral perturbations are exact no-ops on
  both backends, and enabling a perturbation on one lane never shifts any
  other lane's noise stream (fold seeds exclude the perturbation);
- the injected physics: PE slowdowns hurt STATIC far more than dynamic
  scheduling, failed PEs are routed around, noise bursts inflate sigma,
  workload drift transforms loop profiles (N / cov / phase);
- synthetic heterogeneous systems (SystemModel.pe_speeds + registry);
- schedule-cache hygiene under perturbation (weighted 5-tuple keys never
  collide with clean 4-tuple entries);
- blind vs two-pass-aware candidate pricing (LoopWhatIf);
- the PageHinkley drift detector and the reactive policies: ReactiveSim's
  EMA fidelity corrections beat frozen SimPolicy on a perturbed cell, and
  ReactiveHybrid re-prunes its RL window when the reward stream shifts.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import PageHinkley, make_policy
from repro.core.api import Observation
from repro.core.jaxsched import ADAPTIVE_SCHEDULABLE, weighted_adaptive_schedule
from repro.core.simpolicy import Candidate, SimAssistedHybrid
from repro.sim import (CellSpec, HETERO_SYSTEMS, InstancePerturb, LoopWhatIf,
                       NoiseBurst, PEFailure, PESlowdown, PerturbationSpec,
                       ReplayBatch, SYSTEMS, WorkloadDrift, drift_spec,
                       get_application, get_system, hetero_system,
                       pe_slowdown_spec, run_selector, run_selector_sequential)
from repro.sim.backends import InstanceSpec, get_backend
from repro.sim.backends.base import combined_pe_scale, sigma_scale_of
from repro.sim.backends.jax_batched import (ADAPTIVE_REWEIGHT_ENV,
                                            JaxBatchedBackend,
                                            resolve_adaptive_reweight)
from repro.sim.workloads import profile_digest

BACKENDS = ["python", "jax"]


def _slow(P, k=4, factor=8.0):
    return InstancePerturb(pe_scale=tuple([1.0] * (P - k) + [factor] * k))


# ---------------------------------------------------------------------------
# InstancePerturb / PerturbationSpec resolution
# ---------------------------------------------------------------------------

def test_instance_perturb_neutral_and_key():
    assert InstancePerturb().neutral
    assert InstancePerturb(pe_scale=(1.0, 1.0), sigma_scale=1.0).neutral
    p = InstancePerturb(pe_scale=(1.0, 2.0))
    assert not p.neutral
    assert p.key() == ((1.0, 2.0), 1.0)
    assert p.key() != InstancePerturb().key()


def test_combined_pe_scale_composes_system_and_perturb():
    base = get_system("broadwell")
    assert combined_pe_scale(base, None) is None
    het = hetero_system(base, "t", (1.0,) * 16 + (2.0,) * 4)
    s = combined_pe_scale(het, None)
    assert s is not None and s[-1] == 2.0
    both = combined_pe_scale(het, _slow(20, k=4, factor=3.0))
    assert both[-1] == 6.0 and both[0] == 1.0
    assert sigma_scale_of(None) == 1.0
    assert sigma_scale_of(InstancePerturb(sigma_scale=2.5)) == 2.5


def test_perturbation_spec_windows_and_resolution():
    spec = PerturbationSpec(
        slowdowns=(PESlowdown(pes=(0,), factor=4.0, t0=2, t1=5),),
        noise_bursts=(NoiseBurst(factor=3.0, t0=4),))
    assert spec.instance_perturb(0, 8) is None
    ip = spec.instance_perturb(2, 8)
    assert ip.pe_scale[0] == 4.0 and ip.sigma_scale == 1.0
    ip = spec.instance_perturb(4, 8)          # both windows active
    assert ip.pe_scale[0] == 4.0 and ip.sigma_scale == 3.0
    ip = spec.instance_perturb(5, 8)          # slowdown window closed
    assert ip.pe_scale is None and ip.sigma_scale == 3.0
    with pytest.raises(ValueError):
        WorkloadDrift(kind="entropy")


# ---------------------------------------------------------------------------
# backend injection: bit-equality + physics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_neutral_perturb_bit_equal(backend):
    bk = get_backend(backend)
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    algs = (1, 2, 4, 5, 7, 11)
    clean = bk.run_batch([p], system,
                         [InstanceSpec(0, a, 0, (9, a)) for a in algs])
    neut = bk.run_batch(
        [p], system,
        [InstanceSpec(0, a, 0, (9, a), perturb=InstancePerturb())
         for a in algs])
    assert np.array_equal(clean.loop_time, neut.loop_time)
    assert np.array_equal(clean.lib, neut.lib)


@pytest.mark.parametrize("backend", BACKENDS)
def test_pe_slowdown_hurts_static_more_than_dynamic(backend):
    bk = get_backend(backend)
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    ip = _slow(system.P, k=4, factor=8.0)

    def t(alg, perturb):
        spec = InstanceSpec(0, alg, 0, (11, alg), perturb=perturb)
        return float(bk.run_batch([p], system, [spec]).loop_time[0])

    static_ratio = t(0, ip) / t(0, None)
    ss_ratio = t(1, ip) / t(1, None)
    steal_ratio = t(5, ip) / t(5, None)
    # STATIC is stuck with its pre-assigned ranges on the slow PEs;
    # self-scheduling (chunk-of-1) and work stealing route around them
    assert static_ratio > 4.0
    assert ss_ratio < 0.25 * static_ratio
    assert steal_ratio < 0.25 * static_ratio


@pytest.mark.parametrize("backend", BACKENDS)
def test_pe_failure_is_routed_around_by_dynamic(backend):
    bk = get_backend(backend)
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    spec = PerturbationSpec(failures=(PEFailure(pes=(18, 19)),))
    ip = spec.instance_perturb(0, system.P)

    def t(alg, perturb):
        s = InstanceSpec(0, alg, 0, (13, alg), perturb=perturb)
        return float(bk.run_batch([p], system, [s]).loop_time[0])

    # dead PEs make STATIC astronomically slow; chunk-of-1 self-scheduling
    # degrades gracefully (loses 2 of 20 PEs plus one stranded iteration)
    assert t(0, ip) > 100.0 * t(0, None)
    assert t(1, ip) < 2.0 * t(1, None)
    assert t(5, ip) < 2.0 * t(5, None)


@pytest.mark.parametrize("backend", BACKENDS)
def test_noise_burst_inflates_sigma(backend):
    bk = get_backend(backend)
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    burst = InstancePerturb(sigma_scale=8.0)

    def run(perturb, n=6):
        specs = [InstanceSpec(0, 2, 0, (17, i), perturb=perturb)
                 for i in range(n)]
        return bk.run_batch([p], system, specs).loop_time

    clean = run(None)
    noisy = run(burst)
    assert not np.array_equal(clean, noisy)
    # the burst only widens the noise term: dispersion across seeds grows
    assert noisy.std() > clean.std()


@pytest.mark.parametrize("backend", BACKENDS)
def test_seed_stream_isolation_across_lanes(backend):
    """Perturbing lane A must not shift lane B's draws: the fold seed
    excludes the perturbation, so B is bit-identical in both batches."""
    bk = get_backend(backend)
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    ip = _slow(system.P)
    a_clean = InstanceSpec(0, 2, 0, (23, 0))
    b_clean = InstanceSpec(0, 4, 0, (23, 1))
    r0 = bk.run_batch([p], system, [a_clean, b_clean])
    r1 = bk.run_batch([p], system,
                      [dataclasses.replace(a_clean, perturb=ip), b_clean])
    assert r1.loop_time[0] != r0.loop_time[0]      # A did change
    assert r1.loop_time[1] == r0.loop_time[1]      # B bit-identical
    assert r1.lib[1] == r0.lib[1]


@pytest.mark.parametrize("backend", BACKENDS)
def test_run_instance_perturb_kwarg(backend):
    bk = get_backend(backend)
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    r0 = bk.run_instance(p, system, 2, 0, np.random.default_rng(3))
    r1 = bk.run_instance(p, system, 2, 0, np.random.default_rng(3),
                         perturb=InstancePerturb())
    assert r0.loop_time == r1.loop_time
    r2 = bk.run_instance(p, system, 2, 0, np.random.default_rng(3),
                         perturb=_slow(system.P))
    assert r2.loop_time != r0.loop_time


# ---------------------------------------------------------------------------
# campaign wiring: lockstep == sequential, clean lanes unaffected
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_lockstep_matches_sequential_under_perturbation(backend):
    pz = pe_slowdown_spec(20, frac=0.2, factor=6.0, t0=2)
    kw = dict(T=6, seed=0, backend=backend)
    seq = run_selector_sequential("hacc", "broadwell", "ExpertSel",
                                  perturb=pz, **kw)
    bat = run_selector("hacc", "broadwell", "ExpertSel", perturb=pz, **kw)
    if backend == "python":
        assert seq.total == bat.total
        assert seq.history == bat.history
    else:
        # the repo's JAX equivalence contract (test_replay): identical
        # selections, times to float32-accumulation tolerance
        for nm in bat.history:
            assert [h[0] for h in bat.history[nm]] == \
                [h[0] for h in seq.history[nm]]
            np.testing.assert_allclose(
                [h[1] for h in bat.history[nm]],
                [h[1] for h in seq.history[nm]], rtol=1e-6)
        np.testing.assert_allclose(bat.total, seq.total, rtol=1e-6)


def test_replaybatch_clean_lane_bit_equal_next_to_perturbed():
    pz = pe_slowdown_spec(20, frac=0.2, factor=6.0, t0=0)
    clean = CellSpec(app="hacc", system="broadwell", selector="ExpertSel")
    pert = CellSpec(app="hacc", system="broadwell", selector="QLearn",
                    reward="LT", perturb=pz)
    solo = ReplayBatch([clean], T=6, seed=0, backend="python").run()[0]
    both = ReplayBatch([clean, pert], T=6, seed=0, backend="python").run()
    assert both[0].total == solo.total
    assert both[0].history == solo.history
    assert both[1].total != solo.total


def test_drifted_lane_does_not_alias_clean_siblings_profiles():
    """Two lanes on the same app, one drifted: the drifted lane must see
    transformed profiles while the clean lane's run stays bit-equal."""
    dz = drift_spec("N", t0=0, factor=2.0)
    clean = CellSpec(app="tc", system="broadwell", selector="ExpertSel")
    drifted = CellSpec(app="tc", system="broadwell", selector="ExpertSel",
                       perturb=dz)
    solo = ReplayBatch([clean], T=4, seed=0, backend="python").run()[0]
    both = ReplayBatch([clean, drifted], T=4, seed=0, backend="python").run()
    assert both[0].total == solo.total
    assert both[1].total > 1.5 * solo.total       # doubled N


# ---------------------------------------------------------------------------
# workload drift transforms
# ---------------------------------------------------------------------------

def test_drift_n_scales_iterations_and_work():
    app = get_application("tc")
    base = app.loops(0)[0]
    dl = drift_spec("N", t0=0, factor=2.0).loops(app, 0)[0]
    assert dl.N == 2 * base.N
    assert np.isclose(dl.total, 2.0 * base.total, rtol=1e-6)
    # inactive before its window opens
    assert drift_spec("N", t0=3, factor=2.0).loops(app, 0)[0].N == base.N


def test_drift_cov_preserves_total_work():
    app = get_application("tc")
    base = app.loops(0)[0]
    dl = drift_spec("cov", t0=0, factor=1.8).loops(app, 0)[0]
    assert dl.N == base.N
    assert np.isclose(dl.total, base.total, rtol=1e-9)
    assert profile_digest(dl) != profile_digest(base)
    dens0 = np.diff(base.prefix_grid)
    dens1 = np.diff(dl.prefix_grid)
    assert dens1.std() / dens1.mean() > dens0.std() / dens0.mean()


def test_drift_phase_fast_forwards_the_app():
    app = get_application("sphynx")       # time-varying loops
    shifted = drift_spec("phase", t0=0, phase_shift=7).loops(app, 3)[0]
    direct = app.loops(10)[0]
    assert profile_digest(shifted) == profile_digest(direct)


# ---------------------------------------------------------------------------
# heterogeneous systems
# ---------------------------------------------------------------------------

def test_hetero_registry_and_validation():
    assert set(SYSTEMS) == {"broadwell", "cascadelake", "epyc"}
    for name, s in HETERO_SYSTEMS.items():
        assert get_system(name) is s
        assert len(s.pe_speeds) == s.P and max(s.pe_speeds) > 1.0
    base = get_system("broadwell")
    assert base.pe_speeds is None
    with pytest.raises(ValueError):
        hetero_system(base, "bad", (1.0,) * 3)
    with pytest.raises(ValueError):
        hetero_system(base, "bad", (0.0,) * base.P)
    with pytest.raises(KeyError, match="unknown system"):
        get_system("m1_ultra")


@pytest.mark.parametrize("backend", BACKENDS)
def test_hetero_system_changes_execution(backend):
    bk = get_backend(backend)
    p = get_application("hacc").loops(0)[0]
    base = get_system("broadwell")
    het = get_system("broadwell_het")
    spec = [InstanceSpec(0, 0, 0, (29,))]
    t_base = float(bk.run_batch([p], base, spec).loop_time[0])
    t_het = float(bk.run_batch([p], het, spec).loop_time[0])
    assert t_het > 1.2 * t_base


# ---------------------------------------------------------------------------
# weighted adaptive schedules + cache hygiene
# ---------------------------------------------------------------------------

def test_weighted_adaptive_schedule_covers_all_iterations():
    P = 8
    w = np.ones(P)
    w[-2:] = 0.25               # two PEs at quarter speed
    w *= P / w.sum()
    for alg in sorted(ADAPTIVE_SCHEDULABLE):
        sizes, pes = weighted_adaptive_schedule(alg, 10_000, P, 0, w)
        assert sizes.sum() == 10_000
        assert sizes.min() >= 1
        assert pes.min() >= 0 and pes.max() < P
        # slow PEs get less work than fast ones
        work = np.bincount(pes, weights=sizes, minlength=P)
        assert work[-1] < work[0]
    with pytest.raises(ValueError):
        weighted_adaptive_schedule(2, 100, P, 0, w)


def test_sched_cache_clean_entries_survive_weighted_runs():
    """A perturbed (weighted) schedule must never poison the clean cache
    entry for the same (alg, N, P, cp): re-running the clean spec after a
    perturbed one is bit-identical to the first clean run."""
    bk = JaxBatchedBackend()
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    clean = [InstanceSpec(0, a, 0, (31, a)) for a in (7, 11)]
    pert = [InstanceSpec(0, a, 0, (31, a), perturb=_slow(system.P))
            for a in (7, 11)]
    r0 = bk.run_batch([p], system, clean)
    rp = bk.run_batch([p], system, pert)
    r1 = bk.run_batch([p], system, clean)
    assert np.array_equal(r0.loop_time, r1.loop_time)
    assert np.array_equal(r0.lib, r1.lib)
    assert not np.array_equal(rp.loop_time, r0.loop_time)


def test_adaptive_reweight_resolution(monkeypatch):
    monkeypatch.delenv(ADAPTIVE_REWEIGHT_ENV, raising=False)
    assert resolve_adaptive_reweight() is True
    monkeypatch.setenv(ADAPTIVE_REWEIGHT_ENV, "0")
    assert resolve_adaptive_reweight() is False
    assert resolve_adaptive_reweight(True) is True
    monkeypatch.setenv(ADAPTIVE_REWEIGHT_ENV, "1")
    assert resolve_adaptive_reweight(False) is False


def test_adaptive_reweight_moves_work_off_slow_pes():
    """With reweighting the adaptive surrogate assigns slow PEs smaller
    chunks (LB4OMP's measured-weights behavior); frozen schedules pay the
    full slowdown on the critical path."""
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    ip = _slow(system.P, k=4, factor=8.0)
    spec = [InstanceSpec(0, 11, 0, (37,), perturb=ip)]
    on = JaxBatchedBackend(adaptive_reweight=True)
    off = JaxBatchedBackend(adaptive_reweight=False)
    t_on = float(on.run_batch([p], system, spec).loop_time[0])
    t_off = float(off.run_batch([p], system, spec).loop_time[0])
    assert t_on < 0.75 * t_off


# ---------------------------------------------------------------------------
# candidate pricing: blind by default, aware under two_pass
# ---------------------------------------------------------------------------

def test_whatif_blind_vs_two_pass_aware_pricing():
    system = get_system("broadwell")
    p = get_application("hacc").loops(0)[0]
    cands = [Candidate(a) for a in range(12)]
    ip = _slow(system.P)

    blind = LoopWhatIf(system, backend="python")
    blind.set_context(p, 0)
    clean_prices = [o.loop_time for o in blind.price(cands)]
    blind.set_context(p, 0, perturb=ip)
    assert [o.loop_time for o in blind.price(cands)] == clean_prices
    assert blind.last_clean is None

    aware = LoopWhatIf(system, backend="python", two_pass=True)
    aware.set_context(p, 0, perturb=ip)
    aware_prices = [o.loop_time for o in aware.price(cands)]
    assert aware_prices != clean_prices
    assert [o.loop_time for o in aware.last_clean] == clean_prices
    # perturbed entries live under their own cache key: rebinding the clean
    # context returns the original prices bit-for-bit
    aware.set_context(p, 0)
    assert [o.loop_time for o in aware.price(cands)] == clean_prices
    # a neutral perturbation is dropped at set_context time
    aware.set_context(p, 0, perturb=InstancePerturb())
    assert aware._perturb is None


# ---------------------------------------------------------------------------
# drift detection + reactive policies
# ---------------------------------------------------------------------------

def test_page_hinkley_detects_shift_not_stationary():
    det = PageHinkley(delta=0.05, threshold=0.6, min_obs=8)
    rng = np.random.default_rng(0)
    fired = [det.update(x) for x in rng.normal(1.0, 0.05, 200)]
    assert not any(fired)
    assert any(det.update(x) for x in rng.normal(3.0, 0.05, 20))
    assert det.n_detections == 1
    # reset-on-detect: the mean re-learns at the new level, and the detector
    # re-arms for the next shift (downward this time)
    for x in rng.normal(3.0, 0.05, 40):
        det.update(x)
    assert det.n_detections == 1          # stationary again: no false alarm
    assert any(det.update(x) for x in rng.normal(0.2, 0.05, 40))
    assert det.n_detections == 2


def test_reactive_sim_beats_frozen_on_perturbed_cell():
    pz = pe_slowdown_spec(20, frac=0.2, factor=8.0, t0=10)
    kw = dict(T=40, seed=0, backend="python", reward="LT")
    frozen = run_selector("hacc", "broadwell", "SimPolicy", perturb=pz, **kw)
    reactive = run_selector("hacc", "broadwell", "ReactiveSim", perturb=pz,
                            **kw)
    aware = run_selector("hacc", "broadwell", "AwareSim", perturb=pz, **kw)
    assert reactive.total < 0.9 * frozen.total
    assert aware.total < reactive.total
    # on the clean cell the variants stay within a few percent of each other
    f0 = run_selector("hacc", "broadwell", "SimPolicy", **kw)
    r0 = run_selector("hacc", "broadwell", "ReactiveSim", **kw)
    assert abs(r0.total - f0.total) < 0.05 * f0.total


class _StubPricer:
    """Candidate simulator with externally mutable prices."""

    def __init__(self, times):
        self.times = np.asarray(times, float)

    def price(self, cands):
        return [Observation(loop_time=float(self.times[c.alg]))
                for c in cands]


def test_reactive_hybrid_reprunes_window_on_drift():
    times = np.full(12, 1.0)
    times[[2, 3]] = 0.1                   # initial predicted top-2
    stub = _StubPricer(times)
    h = SimAssistedHybrid(stub, top_k=2, expert_steps=1, reactive=True,
                          reward="LT", n_actions=12)
    assert h.name == "ReactiveHybrid"
    # expert phase + 2x2 exploration, then stable exploitation
    for _ in range(25):
        d = h.decide()
        h.feedback(d, Observation(loop_time=0.1, lib=1.0))
    assert sorted(h.actions) == [2, 3]
    assert h.drift_events == 0
    # the world shifts: measured cost jumps AND the simulator now predicts
    # a different top-2 — the detector must fire and re-prune mid-flight
    stub.times = np.full(12, 1.0)
    stub.times[[8, 9]] = 0.05
    for _ in range(20):
        d = h.decide()
        h.feedback(d, Observation(loop_time=5.0, lib=1.0))
        if h.drift_events:
            break
    assert h.drift_events >= 1
    assert sorted(h.actions) == [8, 9]


def test_reactive_policies_via_make_policy():
    stub = _StubPricer(np.ones(12))
    p = make_policy("reactivesim", simulator=stub)
    assert p.name == "ReactiveSim" and p.reactive and p.detector is not None
    q = make_policy("simpolicy", simulator=stub)
    assert q.name == "SimPolicy" and not q.reactive and q.detector is None
    r = make_policy("awaresim", simulator=stub)
    assert r.name == "SimPolicy" and not r.reactive
    s = make_policy("reactivehybrid", simulator=stub)
    assert s.name == "ReactiveHybrid" and s.reactive
