"""Unit + property tests for the 12-algorithm scheduling portfolio."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed
    from _hypothesis_fallback import given, settings, st

from repro.core import (ALGORITHM_NAMES, N_ALGORITHMS, alg_index,
                        apply_chunk_floor, exp_chunk, make_algorithm)
from repro.core.jaxsched import chunk_schedule


def drain(alg_idx, N, P, chunk_param, report=True):
    alg = make_algorithm(alg_idx)
    alg.reset(N, P, chunk_param)
    sizes = []
    pe = 0
    while True:
        c = alg.next_chunk(pe % P)
        if c == 0:
            break
        if report:
            alg.report(pe % P, c, c * 1e-6, c * 1e-6 + 1e-7)
        sizes.append(c)
        pe += 1
        assert len(sizes) <= N + P, "non-termination"
    return sizes


# ---------------------------------------------------------------------------
# exact paper anchors
# ---------------------------------------------------------------------------

def test_exp_chunk_reproduces_paper_781():
    # Figs. 1-2: N = 1e6, P = 20 with chunk parameters 781 (= N/(2^6 * 20))
    assert exp_chunk(1_000_000, 20) == 781


def test_portfolio_order_matches_table2():
    assert ALGORITHM_NAMES == ["STATIC", "SS", "GSS", "AutoLLVM", "TSS",
                               "StaticSteal", "mFAC2", "AWF_B", "AWF_C",
                               "AWF_D", "AWF_E", "mAF"]


def test_gss_follows_eq3():
    # Cs_i = ceil(R_i / P)
    sizes = drain(alg_index("GSS"), 1000, 4, 0)
    R = 1000
    for c in sizes:
        assert c == -(-R // 4)
        R -= c


def test_ss_is_unit_chunks():
    sizes = drain(alg_index("SS"), 100, 4, 0)
    assert sizes == [1] * 100


def test_tss_first_chunk_is_n_over_2p():
    sizes = drain(alg_index("TSS"), 10_000, 8, 0)
    assert sizes[0] == 625  # N/(2P)
    assert all(a >= b for a, b in zip(sizes[:-1], sizes[1:]))  # linear decrease


def test_mfac2_halves_batches():
    P = 4
    sizes = drain(alg_index("mFAC2"), 1024, P, 0)
    # batch j: P chunks of ceil(R_j / 2P): 128,128,128,128, 64,...
    assert sizes[:4] == [128] * 4
    assert sizes[4:8] == [64] * 4


def test_static_chunk_param_direct():
    sizes = drain(alg_index("STATIC"), 100, 4, 30)
    assert sizes == [30, 30, 30, 10]


def test_chunk_floor_semantics():
    # non-direct algorithms: delivered = max(alg, user), clipped by remaining
    assert apply_chunk_floor(2, 5, 20, 1000) == 20
    assert apply_chunk_floor(2, 50, 20, 1000) == 50
    assert apply_chunk_floor(2, 50, 20, 30) == 30
    # SS: user chunk is direct
    assert apply_chunk_floor(1, 1, 64, 1000) == 64


# ---------------------------------------------------------------------------
# properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(alg=st.integers(0, N_ALGORITHMS - 1),
       N=st.integers(1, 5000),
       P=st.integers(1, 32),
       chunk=st.sampled_from([0, 1, 7, 64]))
def test_work_conservation(alg, N, P, chunk):
    """Every algorithm delivers exactly N iterations, all chunks >= 1."""
    sizes = drain(alg, N, P, chunk)
    assert sum(sizes) == N
    assert all(c >= 1 for c in sizes)


@settings(max_examples=20, deadline=None)
@given(N=st.integers(100, 20000), P=st.integers(2, 16))
def test_nonadaptive_decreasing(N, P):
    """GSS/TSS/mFAC2 chunk sizes never increase (non-adaptive monotonicity)."""
    for name in ("GSS", "TSS", "mFAC2"):
        sizes = drain(alg_index(name), N, P, 0)
        assert all(a >= b for a, b in zip(sizes[:-1], sizes[1:])), name


@settings(max_examples=20, deadline=None)
@given(N=st.integers(10, 2000), P=st.integers(1, 8),
       chunk=st.integers(1, 50))
def test_floor_respected(N, P, chunk):
    """With a chunk parameter, every chunk except possibly the last is
    >= chunk (GSS: threshold semantics)."""
    sizes = drain(alg_index("GSS"), N, P, chunk)
    assert all(c >= min(chunk, N) for c in sizes[:-1])
    assert sum(sizes) == N


@settings(max_examples=15, deadline=None)
@given(N=st.integers(16, 4096), P=st.integers(1, 16),
       chunk=st.sampled_from([0, 8]),
       alg=st.sampled_from([0, 1, 2, 3, 4, 6]))
def test_jax_schedule_matches_host(alg, N, P, chunk):
    """Pure-JAX lax.while_loop schedule == host classes (non-adaptive; TSS
    included now that both sides use exact integer arithmetic)."""
    sizes, count = chunk_schedule(alg, N, P, chunk, max_chunks=8192)
    got = list(np.asarray(sizes[: int(count)]))
    want = drain(alg, N, P, chunk, report=False)
    assert got == want


def test_exp_chunk_bounds():
    for N in (100, 10_000, 2_000_000_000):
        for P in (2, 20, 128):
            c = exp_chunk(N, P)
            assert 1 <= c <= max(1, N // (2 * P))
