"""Lockstep multi-cell replay: cross-backend equivalence, the golden Fig. 5
regression table, campaign-statistic properties, and the lane seed-coupling
regression.

The equivalence contract (``ReplayBatch`` vs ``run_selector_sequential``):

* Python backend — bit-exact.  Batching across cells must not change a
  single bit of any lane's Q-tables, selection traces, or per-step times,
  because each lane owns its rng stream and its per-loop policies.
* JAX backend — identical to the *sequential JAX* replay (noise depends
  only on per-instance fold seeds, never on batch composition), and in
  agreement with the Python reference on well-separated selections.
"""

import json
import os
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # also covers the `python tests/test_replay.py` golden-regen entry,
    # which runs without pytest's test-dir sys.path insertion
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_fallback import given, settings, st

from repro.core import ALGORITHM_NAMES
from repro.sim import (CampaignResult, CellSpec, FixedRun, PortfolioSweep,
                       ReplayBatch, SelectorRun, run_campaign,
                       run_selector, run_selector_sequential)
from repro.sim.campaign import _digest, _lane_digest

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                           "golden_fig5_t4.json")

#: the T=4 equivalence grid: two cells on two different machine models in
#: ONE batch (exercises the per-system lockstep grouping), every selector
#: family, both chunk modes, and the reward axis.
GRID = [CellSpec(app, system, sel, mode, reward)
        for app, system in (("mandelbrot", "broadwell"), ("tc", "epyc"))
        for mode in ("default", "expChunk")
        for sel, reward in (("RandomSel", None), ("ExhaustiveSel", None),
                            ("ExpertSel", None), ("QLearn", "LT"),
                            ("QLearn", "LIB"), ("SARSA", "LIB"),
                            ("Hybrid", "LT"))]


def _policy_states(run: SelectorRun):
    """Comparable per-loop policy state: Q-tables for RL policies (via
    ``state_dict``), the ladder position for expert-phase policies."""
    out = {}
    for nm in run.history:
        policy = run.service.policy(nm)
        state = policy.state_dict()
        if state is None:
            # expert-phase policies: compare the fuzzy ladder position
            expert = getattr(policy, "_expert", policy)
            state = {"current": getattr(expert, "current", None)}
        out[nm] = state
    return out


# ---------------------------------------------------------------------------
# lockstep vs sequential: Python backend, bit-exact
# ---------------------------------------------------------------------------

def test_lockstep_bitexact_on_python_backend():
    runs = ReplayBatch(GRID, T=4, seed=0, backend="python").run()
    for spec, run in zip(GRID, runs):
        ref = run_selector_sequential(
            spec.app, spec.system, spec.selector, chunk_mode=spec.chunk_mode,
            reward=spec.reward, T=4, seed=0, backend="python")
        # selection traces, per-step times and libs: tuple-for-tuple equal
        assert run.history == ref.history, spec
        assert run.total == ref.total, spec
        # Q-tables (and expert ladder positions) bit-exact
        assert _policy_states(run) == _policy_states(ref), spec


def test_single_lane_run_selector_is_lockstep():
    """``run_selector`` now routes through ``ReplayBatch``; a one-lane batch
    must equal the sequential reference."""
    r = run_selector("sphynx", "cascadelake", "ExhaustiveSel", T=6)
    ref = run_selector_sequential("sphynx", "cascadelake", "ExhaustiveSel",
                                  T=6)
    assert r.history == ref.history
    assert r.total == ref.total


# ---------------------------------------------------------------------------
# lockstep vs sequential: JAX backend
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_lockstep_jax_matches_sequential_jax():
    """Noise depends only on per-instance fold seeds, so batching across
    lanes must not perturb the JAX replay either."""
    runs = ReplayBatch(GRID, T=4, seed=0, backend="jax").run()
    for spec, run in zip(GRID, runs):
        ref = run_selector_sequential(
            spec.app, spec.system, spec.selector, chunk_mode=spec.chunk_mode,
            reward=spec.reward, T=4, seed=0, backend="jax")
        for nm in run.history:
            assert [h[0] for h in run.history[nm]] == \
                [h[0] for h in ref.history[nm]], (spec, nm)
            t_batch = np.array([h[1] for h in run.history[nm]])
            t_seq = np.array([h[1] for h in ref.history[nm]])
            np.testing.assert_allclose(t_batch, t_seq, rtol=1e-6,
                                       err_msg=str((spec, nm)))


@pytest.mark.slow
def test_lockstep_jax_agrees_with_python_on_separated_cell():
    """TC/EPYC separates the portfolio by ~40 %: ExhaustiveSel's committed
    argmax selection must not depend on the noise realization, so the JAX
    lockstep replay and the Python reference elect the same algorithm."""
    T = 16
    lanes = [CellSpec("tc", "epyc", "ExhaustiveSel"),
             CellSpec("tc", "epyc", "QLearn", reward="LT")]
    runs = ReplayBatch(lanes, T=T, seed=0, backend="jax").run()
    exhaustive, qlearn = runs

    ref = run_selector_sequential("tc", "epyc", "ExhaustiveSel",
                                  T=T, seed=0, backend="python")
    # after the 12-instance search both backends commit to the same winner;
    # compare the window before the (noise-sensitive) LIB-drift retrigger
    # can fire (min_samples=3 monitored instances)
    assert [h[0] for h in exhaustive.history["L0"][12:15]] == \
        [h[0] for h in ref.history["L0"][12:15]]
    t_jax = exhaustive.history["L0"][12][1]
    t_py = ref.history["L0"][12][1]
    assert abs(t_jax - t_py) / t_py < 0.25

    # QLearn is still in its deterministic explore-first circuit at T=16:
    # the action trace must be identical across backends
    ref_q = run_selector_sequential("tc", "epyc", "QLearn", reward="LT",
                                    T=T, seed=0, backend="python")
    assert [h[0] for h in qlearn.history["L0"]] == \
        [h[0] for h in ref_q.history["L0"]]


# ---------------------------------------------------------------------------
# lane seed coupling (regression): reward is part of the lane identity
# ---------------------------------------------------------------------------

def test_reward_is_part_of_lane_noise_stream():
    # the digest separates reward lanes but leaves reward-less selectors on
    # their historical streams (Figs. 7-8 traces unchanged)
    assert _lane_digest("QLearn", "LT") != _lane_digest("QLearn", "LIB")
    assert _lane_digest("RandomSel", None) == _digest("RandomSel")

    r_lt = run_selector("hacc", "broadwell", "QLearn", reward="LT", T=3)
    r_lib = run_selector("hacc", "broadwell", "QLearn", reward="LIB", T=3)
    # explore-first visits the same actions in the same order ...
    assert [h[0] for h in r_lt.history["L0"]] == \
        [h[0] for h in r_lib.history["L0"]]
    # ... but the two lanes must not share a noise realization
    times_lt = [h[1] for h in r_lt.history["L0"]]
    times_lib = [h[1] for h in r_lib.history["L0"]]
    assert times_lt != times_lib


# ---------------------------------------------------------------------------
# golden Fig. 5 regression table
# ---------------------------------------------------------------------------

GOLDEN_CELLS = [("mandelbrot", "broadwell"), ("mandelbrot", "epyc"),
                ("tc", "broadwell"), ("tc", "epyc")]


def _key_str(key) -> str:
    sel, mode, reward = key
    return f"{sel}|{mode}|{reward or ''}"


def compute_golden() -> dict:
    """The golden campaign: two apps x two systems, T=4, reps=1, seed=0 on
    the reference backend — small enough to recompute in CI, rich enough
    that silent drift in ANY campaign statistic (sweep medians, oracle,
    selector replays, degradation arithmetic) shows up."""
    results = run_campaign(GOLDEN_CELLS, T=4, reps=1, seed=0,
                           backend="python", selector_backend="python")
    out = {}
    for (app, system), cell in results.items():
        out[f"{app}/{system}"] = {
            "oracle_total": cell.oracle_total,
            "cov": cell.sweep.cov(),
            "degradation": {_key_str(k): v
                            for k, v in cell.degradation().items()},
            "totals": {_key_str(k): r.total
                       for k, r in cell.selector_runs.items()},
        }
    return out


@pytest.mark.slow
def test_golden_fig5_table():
    assert os.path.exists(GOLDEN_PATH), \
        "golden table missing; regenerate with: python tests/test_replay.py"
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    fresh = compute_golden()
    assert set(fresh) == set(golden)
    for cell, want in golden.items():
        got = fresh[cell]
        assert got["oracle_total"] == pytest.approx(want["oracle_total"],
                                                    rel=1e-9), cell
        assert got["cov"] == pytest.approx(want["cov"], rel=1e-9), cell
        assert set(got["degradation"]) == set(want["degradation"]), cell
        for k, v in want["degradation"].items():
            assert got["degradation"][k] == pytest.approx(v, rel=1e-9,
                                                          abs=1e-9), (cell, k)
        for k, v in want["totals"].items():
            assert got["totals"][k] == pytest.approx(v, rel=1e-9), (cell, k)


# ---------------------------------------------------------------------------
# campaign-statistic properties (hypothesis / fallback shim)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 40), n_loops=st.integers(1, 3))
def test_selection_shares_properties(seed, n_loops):
    """Shares are a probability distribution, and restricting to one loop
    is consistent with counting only that loop's instances."""
    rng = np.random.default_rng(seed)
    history = {}
    for i in range(n_loops):
        n = int(rng.integers(1, 40))
        history[f"L{i}"] = [(int(rng.integers(0, len(ALGORITHM_NAMES))),
                             float(rng.random()), float(rng.random() * 30))
                            for _ in range(n)]
    run = SelectorRun("QLearn", "default", "LT", 0.0, history)
    assert sum(run.selection_shares().values()) == pytest.approx(1.0)
    for nm, h in history.items():
        shares = run.selection_shares(nm)
        assert sum(shares.values()) == pytest.approx(1.0)
        counts = {}
        for a, _, _ in h:
            counts[ALGORITHM_NAMES[a]] = counts.get(ALGORITHM_NAMES[a], 0) + 1
        assert set(shares) == set(counts)
        for name, frac in shares.items():
            assert frac == pytest.approx(counts[name] / len(h))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 40), t_steps=st.integers(1, 6),
       n_algs=st.integers(2, 6))
def test_degradation_properties(seed, t_steps, n_algs):
    """The Oracle lane degrades by exactly 0 %, and any selector that picks
    per-instance from the sweep's portfolio degrades by >= 0 %."""
    rng = np.random.default_rng(seed)
    runs = {(a, "default"): FixedRun(
        times=0.1 + rng.random((t_steps, 1)),
        libs=np.zeros((t_steps, 1))) for a in range(n_algs)}
    sweep = PortfolioSweep(app="x", system="y", runs=runs)
    oracle_total = sweep.oracle_total()

    keys = list(runs)
    hist = []
    for t in range(t_steps):
        k = keys[int(rng.integers(0, n_algs))]
        hist.append((k[0], float(runs[k].times[t, 0]), 0.0))
    selector_runs = {
        ("AnySel", "default", None): SelectorRun(
            "AnySel", "default", None, sum(h[1] for h in hist),
            {"L0": hist}),
        ("Oracle", "default", None): SelectorRun(
            "Oracle", "default", None, float(sweep.oracle_times().sum()),
            {"L0": []}),
    }
    cell = CampaignResult(app="x", system="y", sweep=sweep,
                          oracle_total=oracle_total,
                          selector_runs=selector_runs)
    deg = cell.degradation()
    assert deg[("Oracle", "default", None)] == pytest.approx(0.0, abs=1e-9)
    assert all(v >= -1e-9 for v in deg.values())


if __name__ == "__main__":
    # regenerate the golden table after an INTENDED statistics change
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(compute_golden(), f, indent=2, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")
