"""Serving-engine primitives: ContinuousBatcher accounting, WaveWhatIf
busy-state pricing, and the DispatchSimulator busy/region surface the fleet
layer builds on."""

import numpy as np
import pytest

from repro.core.simpolicy import Candidate, SimUnavailable
from repro.data import synthetic_requests
from repro.data.pipeline import Request
from repro.serving import ContinuousBatcher, DispatchSimulator
from repro.serving.engine import WaveWhatIf


# ---------------------------------------------------------------------------
# ContinuousBatcher: deque refill / eos / completion accounting
# ---------------------------------------------------------------------------

def _fake_step(params, cache, tokens):
    # deterministic "decode": logits whose argmax echoes the input tokens
    logits = np.eye(8, dtype=np.float32)[np.asarray(tokens) % 8]
    return logits, cache


def _batcher(slots):
    return ContinuousBatcher(_fake_step, init_cache_fn=None,
                             batch_slots=slots)


def _reqs(gens):
    return [Request(i, 16, g, 0.0) for i, g in enumerate(gens)]


def test_batcher_refill_and_completion_accounting():
    b = _batcher(2)
    b.submit(_reqs([2, 1, 3, 2, 1]))
    out = b.run(None, np.zeros(2), np.zeros(2, np.int32))
    # tokens_out counts one token per active slot per step == total gen
    assert out["tokens"] == 2 + 1 + 3 + 2 + 1
    assert out["completed"] == 5
    # list scheduling on 2 slots; completions ordered by finish step then
    # slot index (rid3 lands in slot 0, so it reports before rid2)
    assert out["steps"] == 5
    assert [rid for rid, _ in b.completed] == [1, 0, 3, 2, 4]
    assert all(a is None for a in b.active)
    assert not b.queue


def test_batcher_max_steps_leaves_partial_state():
    b = _batcher(2)
    b.submit(_reqs([2, 1, 3]))
    out = b.run(None, np.zeros(2), np.zeros(2, np.int32), max_steps=2)
    assert out["steps"] == 2
    # the two short requests finished; the refilled long one is mid-decode
    assert [rid for rid, _ in b.completed] == [1, 0]
    assert sum(a is not None for a in b.active) == 1
    # a second run drains the rest without resubmission
    out2 = b.run(None, np.zeros(2), np.zeros(2, np.int32))
    assert len(b.completed) == 3
    assert out2["completed"] == 3       # completed list is cumulative
    assert b.tokens_out == 2 + 1 + 3    # and so is the token counter


def test_batcher_refill_is_fifo():
    b = _batcher(1)
    b.submit(_reqs([1, 1, 1]))
    b.run(None, np.zeros(1), np.zeros(1, np.int32))
    assert [rid for rid, _ in b.completed] == [0, 1, 2]


# ---------------------------------------------------------------------------
# WaveWhatIf: candidate-set pricing against the replica busy-state
# ---------------------------------------------------------------------------

@pytest.fixture
def wave():
    sim = DispatchSimulator(4, selector="Fixed",
                            selector_kw={"algorithm": 1}, seed=0)
    reqs = synthetic_requests(32, seed=3)
    return sim, WaveWhatIf(sim), reqs


def test_wavewhatif_requires_bound_wave(wave):
    _sim, w, _reqs = wave
    with pytest.raises(SimUnavailable):
        w.candidates()
    with pytest.raises(SimUnavailable):
        w.price([Candidate(0)])


def test_wavewhatif_candidates_cover_portfolio_and_chunk_variant(wave):
    _sim, w, reqs = wave
    w.set_requests(reqs)
    cands = w.candidates()
    # 12 portfolio algorithms at the dispatcher's chunk param plus the
    # exp_chunk variant of each (chunk_param defaults to 0 != exp_chunk)
    assert len(cands) == 24
    assert sorted({c.alg for c in cands}) == list(range(12))
    assert len({c.chunk_param for c in cands}) == 2


def test_wavewhatif_price_matches_batched_what_if(wave):
    sim, w, reqs = wave
    w.set_requests(reqs)
    cands = [Candidate(0), Candidate(2, 4), Candidate(6), Candidate(4, 4)]
    obs = w.price(cands)
    # grouped by chunk param under the hood, but order-preserving
    by_cp = {cp: sim.what_if(reqs, algs=[c.alg for c in cands
                                         if c.chunk_param == cp],
                             chunk_param=cp)
             for cp in (None, 4)}
    expect = [by_cp[None][0], by_cp[4][0], by_cp[None][1], by_cp[4][1]]
    assert np.allclose([o.loop_time for o in obs], expect)


def test_wavewhatif_prices_reflect_busy_state(wave):
    sim, w, reqs = wave
    w.set_requests(reqs)
    cands = w.candidates()
    idle = np.array([o.loop_time for o in w.price(cands)])
    # skew the replica busy-state: predicted makespans can only grow
    sim.busy = np.array([0.0, 0.05, 0.1, 0.2])
    busy = np.array([o.loop_time for o in w.price(cands)])
    assert np.all(busy >= idle - 1e-12)
    assert np.any(busy > idle + 1e-9)


# ---------------------------------------------------------------------------
# DispatchSimulator: busy-state surface + per-region service identity
# ---------------------------------------------------------------------------

def test_dispatch_busy_roundtrip_and_validation():
    sim = DispatchSimulator(4, selector="Fixed",
                            selector_kw={"algorithm": 1})
    offsets = np.array([0.0, 1.0, 2.0, 3.0])
    sim.busy = offsets
    got = sim.busy
    assert np.array_equal(got, offsets)
    got[0] = 99.0  # the property hands out a copy
    assert sim.busy[0] == 0.0
    with pytest.raises(ValueError):
        sim.busy = np.zeros(3)


def test_dispatch_busy_state_shifts_wave_makespan():
    reqs = synthetic_requests(64, seed=1)
    mk = []
    for offsets in (np.zeros(4), np.array([0.0, 0.1, 0.2, 0.4])):
        sim = DispatchSimulator(4, selector="Fixed",
                                selector_kw={"algorithm": 1}, seed=0)
        sim.busy = offsets
        mk.append(sim.run_wave(list(reqs)).makespan)
    assert mk[1] > mk[0]


def test_dispatch_region_names_selection_service_region():
    sim = DispatchSimulator(2, selector="Fixed",
                            selector_kw={"algorithm": 0}, region="regionX")
    sim.run_wave(synthetic_requests(8, seed=0))
    assert sim.service.regions == ["regionX"]
