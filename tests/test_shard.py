"""Mesh-sharded campaign lanes: helper semantics and sharded-vs-single-
device bit-equality.

The multi-device equality checks need jax to boot with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, which only takes
effect at process start — so they run ``tests/_shard_subproc.py`` in a
subprocess (one process covers lockstep Q-tables/traces, the portfolio
``run_batch`` fan-out and ``what_if_routes``/``what_if_wave`` pricing,
including lane counts that do not divide the mesh extent).  Everything
single-device (mesh construction, lane padding, env resolution, async
double-buffered dispatch) is tested in-process.
"""

from __future__ import annotations

import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import campaign_mesh, make_host_mesh
from repro.sim import CellSpec, ReplayBatch, sweep_portfolio
from repro.sim.backends.jax_batched import (JaxBatchedBackend,
                                            resolve_async_dispatch,
                                            resolve_data_parallel,
                                            resolve_event_core)

REPO = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def test_make_host_mesh_rejects_non_divisible_model_parallel():
    with pytest.raises(ValueError, match="not divisible"):
        make_host_mesh(model_parallel=3)
    with pytest.raises(ValueError, match="model_parallel"):
        make_host_mesh(model_parallel=0)


def test_make_host_mesh_data_parallel_clamp():
    # requesting more lanes than devices clamps to what exists; requesting
    # fewer uses exactly that many
    m = make_host_mesh(data_parallel=64)
    assert m.shape["data"] <= 64
    m1 = make_host_mesh(data_parallel=1)
    assert m1.shape["data"] == 1 and m1.shape["model"] == 1
    with pytest.raises(ValueError, match="data_parallel"):
        make_host_mesh(data_parallel=0)


def test_campaign_mesh_is_data_only():
    m = campaign_mesh()
    assert m.axis_names == ("data", "model")
    assert m.shape["model"] == 1


def test_lane_padding_helpers():
    from repro.distributed.sharding import lane_count, lane_spec, pad_lanes

    m = campaign_mesh(data_parallel=1)
    assert lane_count(m) == 1
    assert pad_lanes(7, m) == 7
    assert tuple(lane_spec(m)) == ("data",)


def test_resolve_data_parallel(monkeypatch):
    monkeypatch.delenv("REPRO_DATA_PARALLEL", raising=False)
    import jax
    assert resolve_data_parallel() == len(jax.devices())
    assert resolve_data_parallel(1) == 1
    assert resolve_data_parallel(10**6) == len(jax.devices())  # clamp
    monkeypatch.setenv("REPRO_DATA_PARALLEL", "1")
    assert resolve_data_parallel() == 1
    with pytest.raises(ValueError):
        resolve_data_parallel(0)


def test_resolve_async_dispatch(monkeypatch):
    monkeypatch.delenv("REPRO_ASYNC_DISPATCH", raising=False)
    assert resolve_async_dispatch() is True
    assert resolve_async_dispatch(False) is False
    monkeypatch.setenv("REPRO_ASYNC_DISPATCH", "0")
    assert resolve_async_dispatch() is False


def test_resolve_event_core_auto():
    # on this container (CPU) the platform default must stay the while-loop
    # reference; "auto" is accepted explicitly and via the env default
    import jax
    expect = "pallas" if jax.default_backend() == "tpu" else "while_loop"
    assert resolve_event_core("auto") == expect
    with pytest.raises(ValueError, match="auto"):
        resolve_event_core("triton")


# ---------------------------------------------------------------------------
# async double-buffered dispatch (single device)
# ---------------------------------------------------------------------------

def test_async_dispatch_bit_equal_single_device():
    sync = JaxBatchedBackend(data_parallel=1, async_dispatch=False)
    asyn = JaxBatchedBackend(data_parallel=1, async_dispatch=True)
    s_ref = sweep_portfolio("sphynx", "epyc", T=2, reps=2, backend=sync)
    s_asy = sweep_portfolio("sphynx", "epyc", T=2, reps=2, backend=asyn)
    for key in s_ref.runs:
        assert (s_ref.runs[key].times == s_asy.runs[key].times).all()
        assert (s_ref.runs[key].libs == s_asy.runs[key].libs).all()


def test_async_dispatch_bit_equal_lockstep():
    lanes = [CellSpec("tc", "epyc", "QLearn", "default", "LT"),
             CellSpec("tc", "epyc", "ExpertSel", "expChunk", None)]
    runs = {}
    for flag in (False, True):
        bk = JaxBatchedBackend(data_parallel=1, async_dispatch=flag)
        runs[flag] = ReplayBatch(lanes, T=3, seed=0, backend=bk).run()
    for a, b in zip(runs[False], runs[True]):
        assert a.total == b.total
        assert a.history == b.history


# ---------------------------------------------------------------------------
# multi-device bit-equality (subprocess: forced 8 virtual CPU devices)
# ---------------------------------------------------------------------------

def test_sharded_bit_equality_8_virtual_devices():
    """Lockstep Q-tables/traces, portfolio sweeps and what-if prices must be
    identical on a (data: 8) mesh, a non-divisible (data: 3) mesh and the
    single-device path — see ``tests/_shard_subproc.py``."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env.pop("REPRO_DATA_PARALLEL", None)
    env.pop("REPRO_ASYNC_DISPATCH", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "_shard_subproc.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARD-OK" in proc.stdout, proc.stdout + proc.stderr
