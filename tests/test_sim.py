"""DES engine invariants + reproduction of the paper's qualitative claims."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra not installed
    from _hypothesis_fallback import given, settings, st

from repro.core import alg_index, exp_chunk
from repro.sim import (get_application, get_system, run_instance,
                       run_selector, sweep_portfolio)


def _first_profile(app_name, t=0):
    return get_application(app_name).loops(t)[0]


# ---------------------------------------------------------------------------
# engine invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(alg=st.integers(0, 11),
       app=st.sampled_from(["mandelbrot", "hacc", "sphynx"]),
       sysname=st.sampled_from(["broadwell", "cascadelake"]),
       chunked=st.booleans())
def test_makespan_bounds(alg, app, sysname, chunked):
    """makespan >= serial_work / P (no free lunch) and
    makespan <= serial work + overhead (no starvation)."""
    profile = _first_profile(app)
    system = get_system(sysname)
    cp = exp_chunk(profile.N, system.P) if chunked else 0
    r = run_instance(profile, system, alg, cp, np.random.default_rng(0))
    lower = profile.total / system.P * 0.5          # inflation-free floor
    assert r.loop_time >= lower * 0.9
    assert r.loop_time < profile.total * 10 + 1.0
    assert 0.0 <= r.lib <= 100.0
    assert np.isfinite(r.finish).all()
    assert len(r.finish) == system.P


def test_chunk_recording():
    profile = _first_profile("sphynx")
    system = get_system("broadwell")
    r = run_instance(profile, system, alg_index("GSS"), 0,
                     np.random.default_rng(0), record_chunks=True)
    assert sum(r.chunk_sizes) == profile.N
    assert all(a >= b for a, b in zip(r.chunk_sizes[:-1], r.chunk_sizes[1:]))


# ---------------------------------------------------------------------------
# paper claims (DESIGN.md C1-C8)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stream_cl():
    profile = _first_profile("stream")
    system = get_system("cascadelake")
    rng = lambda: np.random.default_rng(0)
    t = {}
    for name in ("STATIC", "SS", "GSS", "StaticSteal"):
        t[name] = run_instance(profile, system, alg_index(name), 0,
                               rng()).loop_time
    t["STATIC_exp"] = run_instance(profile, system, 0,
                                   exp_chunk(profile.N, system.P),
                                   rng()).loop_time
    t["SS_exp"] = run_instance(profile, system, 1,
                               exp_chunk(profile.N, system.P),
                               rng()).loop_time
    return t


def test_stream_static_wins(stream_cl):
    """C6/C4: STATIC-default is the best STREAM schedule; chunked STATIC is
    slightly worse; SS/StaticSteal without chunk are orders of magnitude
    worse (Fig. 6)."""
    t = stream_cl
    assert t["STATIC"] < t["STATIC_exp"]
    assert t["SS"] > 50 * t["STATIC"]
    assert t["StaticSteal"] > 10 * t["STATIC"]
    assert t["SS_exp"] < 2 * t["STATIC"]     # expChunk rescues SS


def test_tc_needs_small_chunks():
    """§4.2: for TC only SS(+chunk) and STATIC+expChunk perform well; GSS's
    huge first chunk is a disaster."""
    profile = _first_profile("tc")
    system = get_system("epyc")
    rng = lambda: np.random.default_rng(0)
    cp = exp_chunk(profile.N, system.P)
    ss = run_instance(profile, system, alg_index("SS"), cp, rng()).loop_time
    st_exp = run_instance(profile, system, 0, cp, rng()).loop_time
    st_def = run_instance(profile, system, 0, 0, rng()).loop_time
    gss = run_instance(profile, system, alg_index("GSS"), 0, rng()).loop_time
    assert ss < 0.5 * gss
    assert st_exp < 0.5 * st_def
    assert st_def > 2 * ss


def test_sphynx_dynamic_beats_static():
    profile = _first_profile("sphynx", t=250)
    system = get_system("cascadelake")
    rng = lambda: np.random.default_rng(0)
    static = run_instance(profile, system, 0, 0, rng())
    mfac2 = run_instance(profile, system, alg_index("mFAC2"), 0, rng())
    assert static.lib > 25.0                  # imbalanced under STATIC
    assert mfac2.loop_time < static.loop_time
    assert mfac2.lib < static.lib


def test_hacc_is_insensitive():
    """C6: HACCKernels' c.o.v. is near zero — scheduling barely matters."""
    sweep = sweep_portfolio("hacc", "broadwell", T=4, reps=1)
    assert sweep.cov() < 0.15


def test_stream_cov_is_large():
    sweep = sweep_portfolio("stream", "cascadelake", T=4, reps=1)
    assert sweep.cov() > 1.0


# ---------------------------------------------------------------------------
# selector end-to-end on the simulator
# ---------------------------------------------------------------------------

def test_exhaustive_close_to_oracle_on_sphynx():
    """C5 (reduced scale): ExhaustiveSel lands within 35 % of Oracle."""
    T = 60
    sweep = sweep_portfolio("sphynx", "cascadelake", T=T, reps=1)
    run = run_selector("sphynx", "cascadelake", "ExhaustiveSel",
                       chunk_mode="expChunk", T=T)
    oracle = sweep.oracle_times()[:T].sum()
    deg = (run.total - oracle) / oracle * 100
    assert deg < 35.0


def test_rl_learning_phase_share():
    """C3: explore-first burns 144/500 = 28.8 % of the instances."""
    run = run_selector("hacc", "broadwell", "QLearn", reward="LT", T=150)
    hist = run.history["L0"]
    assert len(hist) == 150
    algs = [a for a, _, _ in hist]
    # during the first 144 instances the agent explores (many algorithms)
    assert len(set(algs[:144])) == 12
    # afterwards it exploits (alpha decays over ~10 instances, so allow a
    # few switches before the table freezes)
    assert len(set(algs[144:])) <= 4


def test_oracle_beats_everyone():
    T = 30
    sweep = sweep_portfolio("mandelbrot", "broadwell", T=T, reps=1)
    oracle = sweep.oracle_times()[:T].sum()
    for (alg, mode), fixed in sweep.runs.items():
        assert oracle <= fixed.times[:T].sum() + 1e-9
